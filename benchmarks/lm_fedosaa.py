"""Beyond-paper: FedOSAA on a real transformer LM (smollm-135m reduced).
Filled in once the model zoo lands; returns [] if models aren't available."""
from __future__ import annotations


def run(quick: bool = True) -> list[dict]:
    try:
        from benchmarks._lm_fedosaa_impl import run_impl
    except ImportError:
        return []
    return run_impl(quick=quick)


if __name__ == "__main__":
    from benchmarks.common import print_csv
    print_csv(run())
