"""Figure 3 / Appendix D.4: FedOSAA-AVG negative control. AA cannot rescue
FedAvg — without a gradient-correction term both fail to reach w*."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    n, k = (20_000, 20) if quick else (58_100, 100)
    rounds = 25 if quick else 50
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    rows = []
    for eta in (0.1, 1.0):
        for L in (5, 10):
            for algo in ("fedavg", "fedosaa_avg", "fedosaa_svrg"):
                hp = AlgoHParams(eta=eta, local_epochs=L)
                rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                       f"fig3/{algo}/eta{eta}_L{L}"))
    save_results("fig3_fedavg_control", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
