"""Shared harness for the paper-reproduction benchmarks.

Every fig*/table* module exposes ``run(quick: bool) -> list[dict]``; rows are
printed by benchmarks/run.py as ``name,us_per_call,derived`` CSV and dumped to
results/<module>.json for EXPERIMENTS.md.

Scale note: the paper uses covtype (N=581k, K=100, N_k=5810) and w8a (N=49.7k,
K=16). Full scale runs fine but is slow on the 1-core CPU container; `quick`
uses N=20k, K=20 for covtype-like and N=10k, K=8 for w8a-like, which preserves
every qualitative ordering (verified against a full-scale spot check).
"""
from __future__ import annotations

import json
import os
from functools import lru_cache

import numpy as np

from repro.core import AlgoHParams, run_federated, solve_reference
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


@lru_cache(maxsize=16)
def logreg_setup(
    dataset: str = "covtype",
    n: int = 20_000,
    k: int = 20,
    scheme: str = "iid",
    gamma: float = 1e-3,
    seed: int = 0,
    dtype: str = "float32",
):
    """dtype="float64" (requires jax_enable_x64, see ext_compression.py)
    removes the ~1e-5 f32 fixed-point floor of the local-step methods for
    benchmarks that chase the paper's deep rel-error targets."""
    import jax.numpy as jnp

    X, y = make_binary_classification(dataset, n=n, seed=seed)
    clients = partition(X, y, num_clients=k, scheme=scheme, seed=seed)
    prob = make_logreg_problem(clients, gamma=gamma, dtype=jnp.dtype(dtype))
    wstar = solve_reference(prob, iters=100)
    return prob, wstar


def bench_algo(
    prob, wstar, algo: str, hp: AlgoHParams, rounds: int, label: str,
    channel=None, stop_rel_error: float | None = None, runtime: str = "vmap",
    chunk: int | None = None, faults=None, async_cfg=None,
) -> dict:
    """``us_per_call`` is History.wall_time's own per-round timer — the same
    clock benchmarks/bench_round.py uses (device-side round + the driver's
    metric sync, excluding the w* solve and History assembly; compile time
    lands in round 0 either way). ``chunk`` routes the rounds through the
    device-resident engine (core/engine.py); ``faults`` a repro/robust
    FaultPlan through the compiled round (benchmarks/ext_robustness.py);
    ``async_cfg`` an AsyncConfig deadline gate over the plan's simulated
    latencies (benchmarks/ext_async.py) — async rows additionally record
    arrivals/staleness curves."""
    h = run_federated(prob, algo, hp, rounds, w_star=wstar, channel=channel,
                      stop_rel_error=stop_rel_error, runtime=runtime,
                      chunk=chunk, faults=faults, async_cfg=async_cfg)
    n_rounds = len(h.rounds)
    extra = {}
    if async_cfg is not None and h.arrivals is not None:
        extra = {
            "arrivals_curve": [float(v) for v in h.arrivals],
            "staleness_max_curve": [float(v) for v in h.staleness_max],
        }
    return {
        **extra,
        "name": label,
        "us_per_call": 1e6 * float(h.wall_time[-1]) / max(n_rounds, 1),
        "derived": float(h.rel_error[-1]),
        "algo": algo,
        "rounds": n_rounds,
        "final_loss": float(h.loss[-1]),
        "final_grad_norm": float(h.grad_norm[-1]),
        "channel": h.channel,
        "comm_bytes": float(h.comm_bytes[-1]),
        # fp32-equivalent floats (bytes/4): the paper's Table 1 unit, kept so
        # historical result files stay comparable
        "comm_floats": float(h.comm_floats[-1]),
        "rel_error_curve": [float(v) for v in h.rel_error],
        "loss_curve": [float(v) for v in h.loss],
    }


def save_results(module: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{module}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def print_csv(rows: list[dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.6e}")
