"""Table 1: communication cost per aggregation round — verified against the
runtime counters of every algorithm (rounds × d floats)."""
from __future__ import annotations

import time

import jax

from repro.core import AlgoHParams, init_state, make_round_fn
from repro.core.algorithms import ALGORITHMS, COMM_TABLE, comm_floats_per_round

from benchmarks.common import logreg_setup, print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    prob, _ = logreg_setup("covtype", n=5_000, k=8)
    d = 54
    rows = []
    hp = AlgoHParams(eta=1.0, local_epochs=3, dane_newton_iters=2, dane_cg_iters=10)
    for algo in ALGORITHMS:
        state = init_state(prob, jax.random.PRNGKey(0))
        fn = jax.jit(make_round_fn(algo, prob, hp))
        state, m = fn(state)           # compile
        t0 = time.perf_counter()
        state, m = fn(state)
        jax.block_until_ready(m.loss)
        wall = time.perf_counter() - t0
        cost = COMM_TABLE[algo]
        measured_bytes = float(m.comm_bytes)
        measured_floats = measured_bytes / 4.0   # fp32-equivalent (identity ch.)
        rows.append({
            "name": f"table1/{algo}",
            "us_per_call": 1e6 * wall,
            "derived": measured_floats / d,  # == Table 1 'cost' column (×d)
            "round_trips": cost.round_trips,
            "table_units": cost.float_units,
            "comm_bytes": measured_bytes,
            "matches_table": abs(measured_floats
                                 - comm_floats_per_round(algo, d)) < 1e-3,
        })
    save_results("table1_comm", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
