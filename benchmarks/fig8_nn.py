"""Figure 8 / Appendix D.5: NN training (MLP1/MLP3 on MNIST-like data).

Reproduced phenomena: FedOSAA accelerates MLP1 but can fail on MLP3 (rapid
gradient-norm decrease => attraction to a stationary point); we report final
training accuracy and grad-norm trajectories for K=1 and K=10."""
from __future__ import annotations

import time

import numpy as np

from repro.core import AlgoHParams, run_federated
from repro.data import make_mnist_like, partition
from repro.models.mlp import make_mlp_problem, mlp_accuracy

from benchmarks.common import print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    n = 4_000 if quick else 10_000
    rounds = 15 if quick else 40
    X, y = make_mnist_like(n=n, seed=0)
    rows = []
    for depth, tag in ((1, "mlp1"), (3, "mlp3")):
        for K in (1, 10):
            clients = partition(X, y.astype(np.float32), num_clients=K, scheme="iid")
            prob = make_mlp_problem(clients, hidden_layers=depth)
            for algo in ("fedsvrg", "fedosaa_svrg"):
                hp = AlgoHParams(eta=0.1, local_epochs=10)
                t0 = time.perf_counter()
                h = run_federated(prob, algo, hp, rounds)
                wall = time.perf_counter() - t0
                acc = mlp_accuracy(prob, h.final_params, X, y)
                rows.append({
                    "name": f"fig8/{tag}/K{K}/{algo}",
                    "us_per_call": 1e6 * wall / max(len(h.rounds), 1),
                    "derived": acc,
                    "final_grad_norm": float(h.grad_norm[-1]),
                    "grad_norm_curve": [float(v) for v in h.grad_norm],
                    "loss_curve": [float(v) for v in h.loss],
                })
    save_results("fig8_nn", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
