"""Beyond-paper: the fault matrix — FedOSAA-SVRG under injected faults
(repro/robust), with and without the residual-clipped AA defense, across wire
codecs.

Fault kinds (FaultPlan):
  drop      mid-round client dropout: the client computes, its uplink never
            lands (weights renormalize; its state rows bit-freeze)
  stale     the client's delta is measured against a lagged anchor w^{t-s}
  sign_flip / noise
            byzantine UPLINK perturbations — these poison the aggregate
            itself, which a per-client history screen cannot see; the matrix
            records them undefended-vs-defended to document exactly that
            (the defense rows match the undefended rows: clip_rtol is not a
            robust-aggregation rule and does not pretend to be)
  history   byzantine HISTORY column: the client's last AA secant column is
            replaced with garbage at scale ``byz_scale``. This is the fault
            clip_rtol defends: the screen drops the column before the Gram
            solve. At byz_scale=1e24 the f32 Gram accumulation overflows, the
            eigendecomposition goes NaN, and the UNDEFENDED run dies on the
            first poisoned aggregate — the canonical NaN-poison attack.
  dp        Gaussian noise composed after codec encode (client-side DP)

The run is float64 (same reason as ext_compression: the acceptance target is
rel-error 1e-6, below the f32 fixed-point floor).

Measured curiosity, kept in the matrix: the int8 channel accidentally
SANITIZES the undefended byz-history run (int8/history/off converges) — the
quantizer's cast of the poisoned client's non-finite delta never reproduces
NaN on the wire, so the aggregate stays finite. The acceptance pair is
therefore pinned on the identity codec, where the NaN reaches the server.

Acceptance (committed in results/ext_robustness.json, validated by
scripts/check_ext_robustness.py, smoke-gated in scripts/ci.sh):
  * 1 byzantine history client of K=10: the undefended run fails to reach
    rel-error 1e-4 within the round budget (it goes non-finite), while the
    clip_rtol=1e-3 run reaches <= 1e-6 within 1.5x the clean run's rounds.
  * clean-run parity: defense on vs off is identical at rtol 1e-6 on a fault-
    free run (measured: bit-exact — the screen keeps every honest column and
    the masked solve is python-gated).
  * determinism: two runs of the same FaultPlan produce bit-identical loss
    curves (every draw is keyed by (plan.seed, round, global client id)).

  PYTHONPATH=src python -m benchmarks.ext_robustness            # quick
  PYTHONPATH=src python -m benchmarks.ext_robustness --full
  PYTHONPATH=src python -m benchmarks.ext_robustness --smoke    # CI gate
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.core import AlgoHParams
from repro.core.anderson import AAConfig
from repro.robust import FaultPlan

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

TARGET = 1e-6
FAIL_TARGET = 1e-4       # the undefended byz-history run must NOT reach this
CLIP_RTOL = 1e-3
BYZ_HISTORY_SCALE = 1e24  # past the f32 Gram overflow: undefended goes NaN
ALGO = "fedosaa_svrg"

CODECS = [("identity", None), ("int8", "int8")]


def _plans(k: int) -> list[tuple[str, FaultPlan | None]]:
    byz = max(1, k // 10)    # 1-of-10 quick, 10-of-100 full
    return [
        ("clean", None),
        ("drop0.2", FaultPlan(drop_rate=0.2)),
        ("stale0.2", FaultPlan(stale_rate=0.2)),
        ("sign_flip", FaultPlan(byz_clients=byz, byz_mode="sign_flip",
                                byz_scale=5.0)),
        ("noise", FaultPlan(byz_clients=byz, byz_mode="noise", byz_scale=5.0)),
        ("history", FaultPlan(byz_clients=byz, byz_mode="history",
                              byz_scale=BYZ_HISTORY_SCALE)),
        ("dp1e-3", FaultPlan(dp_sigma=1e-3)),
    ]


def _row(prob, wstar, hp, cap, tag, channel, faults):
    r = bench_algo(prob, wstar, ALGO, hp, cap, tag, channel=channel,
                   stop_rel_error=1e-8, faults=faults)
    curve = np.asarray(r["rel_error_curve"])
    hit = np.nonzero(curve < TARGET)[0]
    r["target"] = TARGET
    r["rounds_to_target"] = int(hit[0]) + 1 if len(hit) else None
    r["finite"] = bool(np.isfinite(r["final_loss"]))
    return r


def _rounds_to(curve, t):
    curve = np.asarray(curve)
    hit = np.nonzero(curve < t)[0]
    return int(hit[0]) + 1 if len(hit) else None


def _summary(rows: list[dict], det_identical: bool) -> dict:
    by = {r["name"]: r for r in rows}
    clean_off = by["ext_robustness/identity/clean/off"]
    clean_on = by["ext_robustness/identity/clean/on"]
    und = by["ext_robustness/identity/history/off"]
    dfd = by["ext_robustness/identity/history/on"]
    clean_rounds = clean_off["rounds_to_target"]
    dfd_rounds = dfd["rounds_to_target"]
    a = np.asarray(clean_off["loss_curve"])
    b = np.asarray(clean_on["loss_curve"])
    n = min(len(a), len(b))
    parity = float(np.max(np.abs(a[:n] - b[:n]) / np.maximum(np.abs(a[:n]),
                                                             1e-30)))
    return {
        "name": "ext_robustness/summary",
        "us_per_call": 0.0,
        "derived": dfd["derived"],
        # acceptance: True / True / <= 1.5 / <= 1e-6 / True
        "byz_history_undefended_failed":
            _rounds_to(und["rel_error_curve"], FAIL_TARGET) is None,
        "byz_history_defended_reached_target": dfd_rounds is not None,
        "defended_rounds_vs_clean":
            (dfd_rounds / clean_rounds
             if dfd_rounds is not None and clean_rounds else None),
        "clean_defense_parity_max_rel": parity,
        "fault_determinism_bit_identical": det_identical,
        "clean_rounds_to_target": clean_rounds,
        "defended_rounds_to_target": dfd_rounds,
        "undefended_final_finite": und["finite"],
    }


def _determinism_check(prob, wstar, hp, faults, cap=6) -> bool:
    """Two runs of the same FaultPlan must be bit-identical."""
    runs = [bench_algo(prob, wstar, ALGO, hp, cap, "det", faults=faults)
            for _ in range(2)]
    a, b = (np.asarray(r["loss_curve"]) for r in runs)
    return len(a) == len(b) and bool(np.all(a == b))


def run(quick: bool = True) -> list[dict]:
    n, k = (10_000, 10) if quick else (58_100, 100)
    cap = 40 if quick else 60
    was_x64 = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        prob, wstar = logreg_setup("covtype", n=n, k=k, dtype="float64")
        off = AlgoHParams(eta=1.0, local_epochs=10)
        on = AlgoHParams(eta=1.0, local_epochs=10,
                         aa=AAConfig(clip_rtol=CLIP_RTOL))
        rows = []
        for cname, channel in CODECS:
            for fname, plan in _plans(k):
                for dname, hp in (("off", off), ("on", on)):
                    rows.append(_row(
                        prob, wstar, hp, cap,
                        f"ext_robustness/{cname}/{fname}/{dname}",
                        channel, plan))
        det = _determinism_check(
            prob, wstar, on,
            FaultPlan(drop_rate=0.2, stale_rate=0.2, byz_clients=1,
                      byz_mode="history", byz_scale=BYZ_HISTORY_SCALE,
                      dp_sigma=1e-4))
        rows.append(_summary(rows, det))
    finally:
        jax.config.update("jax_enable_x64", was_x64)
    save_results("ext_robustness", rows)
    return rows


def smoke() -> int:
    """Tiny CI gate (seconds): every fault kind executes finitely on both
    defense settings, the clean run is bit-identical defense-on vs -off, a
    repeated fault plan is bit-deterministic, and the byz-history acceptance
    pair behaves (undefended non-finite, defended finite). Writes nothing —
    the committed results/ext_robustness.json is validated separately by
    scripts/check_ext_robustness.py."""
    prob, wstar = logreg_setup("covtype", n=2_000, k=8)
    off = AlgoHParams(eta=1.0, local_epochs=5)
    on = AlgoHParams(eta=1.0, local_epochs=5, aa=AAConfig(clip_rtol=CLIP_RTOL))
    failures = []
    by = {}
    for fname, plan in _plans(8):
        for dname, hp in (("off", off), ("on", on)):
            r = by[fname, dname] = bench_algo(
                prob, wstar, ALGO, hp, 8, f"smoke/{fname}/{dname}",
                faults=plan)
            print_csv([r])
            finite = np.isfinite(r["final_loss"])
            if fname != "history" and not finite:
                failures.append(f"{r['name']}: loss went non-finite")
    # clean parity: the screen must not move a fault-free run at all
    a = np.asarray(by["clean", "off"]["loss_curve"])
    b = np.asarray(by["clean", "on"]["loss_curve"])
    if not np.array_equal(a, b):
        failures.append("clean run differs defense-on vs defense-off")
    # byz-history acceptance pair
    if np.isfinite(by["history", "off"]["final_loss"]):
        failures.append("undefended byz-history run stayed finite "
                        "(the attack no longer lands)")
    if not np.isfinite(by["history", "on"]["final_loss"]):
        failures.append("defended byz-history run went non-finite "
                        "(the clip screen no longer protects)")
    if not _determinism_check(
            prob, wstar, on,
            FaultPlan(drop_rate=0.3, dp_sigma=1e-4), cap=4):
        failures.append("repeated FaultPlan runs are not bit-identical")
    for f in failures:
        print(f"SMOKE FAIL: {f}")
    print("ext_robustness smoke:", "FAIL" if failures else "OK")
    return 1 if failures else 0


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    print_csv(run(quick="--full" not in sys.argv))
