"""Round-engine benchmark: measured wall-time/round, before vs after.

The seed driver paid per-round dispatch + host-sync overhead on every
aggregation round (one jit call, one blocking metric transfer, an UN-jitted
host rel-error — core/server.py pre-engine). This benchmark measures that
cost directly against the device-resident round engine (core/engine.py) on
the quick covtype setup, and commits the numbers to ``BENCH_round.json`` at
the repo root — the perf trajectory future PRs extend.

Methodology: every mode runs the same problem from the same initial state;
compile + warmup excluded; the three modes are re-measured INTERLEAVED for
several repetitions and the per-mode minimum is reported (robust to the
noisy-neighbor variance of this shared container — spreads of 2–3× between
repetitions were observed on idle cores).

XLA:CPU runtime note (measured here, recorded in ROADMAP): the default
thunk runtime executes compiled-loop bodies on a serial path — the SAME
round costs ~1.6× more inside a lax.scan than as a standalone jit, and the
sharded runtime's collectives degrade ~10×. This module therefore pins
``--xla_cpu_use_thunk_runtime=false`` (set below, before jax initializes)
for BOTH the before and after modes, so the comparison isolates
chunking+donation rather than the runtime regression. TPU is unaffected
(the thunk runtime is CPU-only).

Three timed modes per (algo × runtime × channel × local_impl) cell:

  seed_loop — faithful re-enactment of the seed per-round loop: jit dispatch
              per round, per-round host metric sync, eagerly-dispatched
              host rel-error — and the SEED trajectory form
              (``LOCAL_IMPL_SEED``: autodiff residuals with the pre-PR5
              concatenate epilogue + standalone r_L dispatch), so the
              committed "vs seed" numbers stay comparable across PRs;
  loop      — this PR's per-round loop (rel-error jitted once; still one
              dispatch + one sync per round);
  engine    — chunked lax.scan with donated state, metrics stacked on
              device, ONE host sync per chunk.

The ``local_impl`` axis covers the fused dual-gradient local-trajectory
path (kernels/local_update) on every eligible vmap cell: "tree" is the
autodiff residual (two loss autodiffs = four X sweeps per local step),
"pallas" the fused path — which on CPU executes the bit-exact fused jnp
oracle (ref.py), the same algorithm the TPU kernel runs (one X sweep per
step, hoisted anchor coefficients), so its win here is algorithmic
(sweep/FLOP reduction), not a kernel-emulation artifact. GIANT and the
sharded runtime have no fused path and carry "tree" rows only.

A separate micro-row exercises ``aa_impl="pallas"`` END-TO-END (full
fedosaa rounds through the fused single-pass Gram/update kernels, interpret
mode on CPU) and records its parity against the tree path — correctness
evidence, not a CPU speed claim: the fused kernels' win is HBM traffic on
TPU, while interpret mode is a Python-loop emulation. A second micro-row
does the same for ``local_impl="pallas"``: rel-error traces of full fused
rounds against the tree path (both reach the same floor; round-level
trajectories through the unregularized AA Gram solve are ulp-chaotic, see
tests/test_local_update.py) plus the ops-level trajectory parity.

  PYTHONPATH=src python -m benchmarks.bench_round            # full grid
  PYTHONPATH=src python -m benchmarks.bench_round --smoke    # CI gate
"""
from __future__ import annotations

# BEFORE any jax import — see the XLA:CPU runtime note in the docstring.
import os

XLA_CPU_FLAG = "--xla_cpu_use_thunk_runtime=false"
if XLA_CPU_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        XLA_CPU_FLAG + " " + os.environ.get("XLA_FLAGS", "")).strip()

import json      # noqa: E402
import sys       # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    AlgoHParams,
    init_state,
    make_chunk_runner,
    make_round_fn,
)
from repro.core.sharded import make_sharded_round_fn  # noqa: E402
from repro.launch.mesh import make_host_mesh          # noqa: E402
from repro.utils import tree_math as tm               # noqa: E402

from benchmarks.common import logreg_setup            # noqa: E402

#: the committed perf-trajectory artifact (full grid only; see SMOKE_PATH)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_round.json")
#: --smoke output: a scratch path, so CI/dev gate runs never clobber the
#: committed full-grid trajectory with 2-rep smoke numbers
SMOKE_PATH = os.path.join(os.path.dirname(__file__), "results",
                          "BENCH_round_smoke.json")

ALGOS = ("fedosaa_svrg", "fedosaa_scaffold", "giant")
RUNTIMES = ("vmap", "sharded")
CHANNELS = ("identity", "int8")


def _local_impls(algo: str, runtime: str) -> tuple:
    """The local_impl axis of one (algo, runtime) cell: fused rows exist
    only where the fused path can activate (trajectory algos, vmap)."""
    from repro.core import TRAJECTORY_ALGOS

    if runtime == "vmap" and algo in TRAJECTORY_ALGOS:
        return ("tree", "pallas")
    return ("tree",)


def _hp(local_impl: str = "tree", cohort: int | None = None) -> AlgoHParams:
    # fig6's quick-covtype hyperparameters for every cell (η=1, L=10 —
    # L doubles as GIANT's CG iteration count), so the timer bases agree
    # across benchmarks
    return AlgoHParams(eta=1.0, local_epochs=10, local_impl=local_impl,
                       cohort_size=cohort)


def _make_round_fn(algo, prob, hp, runtime, channel, mesh):
    if runtime == "sharded":
        return make_sharded_round_fn(algo, prob, hp, mesh, channel=channel)
    return make_round_fn(algo, prob, hp, channel)


def _fresh_state(prob, hp, channel, algo):
    return init_state(prob, jax.random.PRNGKey(0), hp, channel, algo)


class _Cell:
    """One (algo × runtime × channel × local_impl) cell: three interleavable
    timed modes over identical rounds from identical states. The seed-loop
    re-enactment always runs the seed trajectory form (LOCAL_IMPL_SEED);
    loop and engine run the cell's local_impl. Sibling
    tree/pallas cells of one (algo, runtime, channel) share ONE seed-loop
    measurement (it is the same computation), taken interleaved with both —
    see _bench_cell."""

    def __init__(self, prob, wstar, algo, runtime, channel, mesh, rounds,
                 chunk, local_impl="tree", seed_cell=None, cohort=None):
        # cohort cells time the sampled-cohort round (AlgoHParams.cohort_size)
        # in loop/engine; the seed replay below stays DENSE — "vs seed"
        # then measures cohort compute reduction + engine against the true
        # pre-cohort driver
        hp = _hp(local_impl, cohort)
        self.prob, self.hp, self.algo, self.channel = prob, hp, algo, channel
        self.rounds, self.chunk = rounds, chunk
        self.wstar = wstar
        self.wstar_norm = float(tm.tree_norm(wstar))
        round_fn = _make_round_fn(algo, prob, hp, runtime, channel, mesh)
        self.jf = jax.jit(round_fn)
        # the seed replay runs the SEED trajectory form (concatenate
        # epilogue + standalone r_L dispatch, LOCAL_IMPL_SEED) so the
        # committed "vs seed" trajectory stays comparable across PRs —
        # PR 5 folded that epilogue into the scan for every live path
        from repro.core.algorithms import LOCAL_IMPL_SEED

        self.jf_seed = seed_cell.jf_seed if seed_cell is not None else (
            jax.jit(_make_round_fn(algo, prob, _hp(LOCAL_IMPL_SEED),
                                   runtime, channel, mesh)))
        self.rel_fn = jax.jit(
            lambda p: tm.tree_norm(tm.tree_sub(p, wstar)))
        self.runner = make_chunk_runner(round_fn, chunk, w_star=wstar)

    def _state(self):
        return _fresh_state(self.prob, self.hp, self.channel, self.algo)

    def seed_loop(self) -> float:
        """The SEED per-round loop, re-enacted: jit per round, host metric
        sync per round, un-jitted (eagerly dispatched) host rel-error."""
        state, m = self.jf_seed(self._state())
        jax.block_until_ready(m.loss)
        t0 = time.perf_counter()
        for _ in range(self.rounds):
            state, m = self.jf_seed(state)
            m_host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), m)
            diff = tm.tree_norm(tm.tree_sub(state.params, self.wstar))
            rel = float(diff) / max(self.wstar_norm, 1e-30)
        elapsed = time.perf_counter() - t0
        del m_host, rel
        return elapsed / self.rounds

    def loop(self) -> float:
        """This PR's per-round loop: rel-error jitted once and reused."""
        state, m = self.jf(self._state())
        float(self.rel_fn(state.params))
        jax.block_until_ready(m.loss)
        t0 = time.perf_counter()
        for _ in range(self.rounds):
            state, m = self.jf(state)
            m_host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), m)
            rel = float(self.rel_fn(state.params)) / max(self.wstar_norm, 1e-30)
        elapsed = time.perf_counter() - t0
        del m_host, rel
        return elapsed / self.rounds

    def engine(self) -> float:
        """The chunked engine: donated scan, one host sync per chunk."""
        out = self.runner(self._state(), np.int32(self.chunk))
        jax.device_get(out[1:])
        n_chunks = max(self.rounds // self.chunk, 1)
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            out = self.runner(out[0], np.int32(self.chunk))
            jax.device_get(out[1:])
        elapsed = time.perf_counter() - t0
        return elapsed / (n_chunks * self.chunk)


def _bench_cell(prob, wstar, algo, runtime, channel, mesh, rounds, chunk,
                reps, local_impls=("tree",), cohort=None):
    """Bench every local_impl of one (algo, runtime, channel) together:
    ONE seed-loop baseline (the LOCAL_IMPL_SEED seed trajectory replay —
    identical for every row) and per-impl loop/engine modes, all
    interleaved across the reps so sibling tree/pallas rows see the same
    machine load. Returns one row per impl."""
    cells, seed_cell = {}, None
    for li in local_impls:
        cells[li] = _Cell(prob, wstar, algo, runtime, channel, mesh, rounds,
                          chunk, li, seed_cell, cohort)
        seed_cell = seed_cell or cells[li]
    modes = {"seed_loop": cells[local_impls[0]].seed_loop}
    for li in local_impls:
        modes[f"loop:{li}"] = cells[li].loop
        modes[f"engine:{li}"] = cells[li].engine
    for f in modes.values():   # warmup/compile every mode first
        f()
    times = {k: [] for k in modes}
    for _ in range(reps):      # interleaved, min-taking (see docstring)
        for k, f in modes.items():
            times[k].append(f())
    t_seed = min(times["seed_loop"])
    rows = []
    for li in local_impls:
        t_loop = min(times[f"loop:{li}"])
        t_eng = min(times[f"engine:{li}"])
        rows.append({
            "algo": algo,
            "runtime": runtime,
            "channel": channel,
            "local_impl": li,
            "cohort": cohort,
            "rounds_timed": rounds,
            "chunk": chunk,
            "reps": reps,
            "seed_loop_s_per_round": t_seed,
            "loop_s_per_round": t_loop,
            "engine_s_per_round": t_eng,
            "seed_loop_rounds_per_sec": 1.0 / t_seed,
            "engine_rounds_per_sec": 1.0 / t_eng,
            "engine_speedup_vs_seed_loop": t_seed / t_eng,
            "engine_speedup_vs_loop": t_loop / t_eng,
        })
    return rows


def _pallas_row(prob, wstar, rounds):
    """aa_impl="pallas" end-to-end: full fedosaa_svrg rounds through the
    fused kernels (interpret mode on CPU), parity-checked against "tree"."""
    import dataclasses

    hp = AlgoHParams(eta=1.0, local_epochs=10, aa_impl="tree")
    results = {}
    for impl in ("tree", "pallas"):
        rf = make_round_fn("fedosaa_svrg", prob,
                           dataclasses.replace(hp, aa_impl=impl))
        runner = make_chunk_runner(rf, rounds, w_star=wstar, donate=False)
        state = _fresh_state(prob, hp, None, "fedosaa_svrg")
        state, done, ms, rels, lives = runner(state, np.int32(rounds))
        results[impl] = (np.asarray(jax.device_get(rels)),
                         jax.device_get(state.params))
    rel_t, p_t = results["tree"]
    rel_p, p_p = results["pallas"]
    max_param_diff = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(jax.tree.leaves(p_t), jax.tree.leaves(p_p))
    )
    return {
        "algo": "fedosaa_svrg",
        "runtime": "vmap",
        "aa_impl": "pallas",
        "interpret_mode": jax.default_backend() != "tpu",
        "rounds": rounds,
        "rel_error_tree": [float(v) for v in rel_t],
        "rel_error_pallas": [float(v) for v in rel_p],
        "max_abs_param_diff_vs_tree": max_param_diff,
    }


def _local_row(prob, wstar, rounds):
    """local_impl="pallas" end-to-end: full fedosaa_svrg rounds through the
    fused dual-gradient trajectory (the bit-exact jnp oracle on CPU, the
    kernel on TPU), recorded as rel-error traces against the tree path plus
    the ops-level trajectory parity at the round-0 state. The traces reach
    the same floor; per-round params are NOT compared — the unregularized
    AA Gram solve amplifies last-ulp trajectory reorderings arbitrarily
    (PR 4 finding; pinned in f64 in tests/test_local_update.py instead)."""
    import dataclasses

    from repro.core.algorithms import _svrg_trajectory

    hp = _hp("tree")
    rels = {}
    for impl in ("tree", "pallas"):
        rf = make_round_fn("fedosaa_svrg", prob,
                           dataclasses.replace(hp, local_impl=impl))
        runner = make_chunk_runner(rf, rounds, w_star=wstar, donate=False)
        state = _fresh_state(prob, hp, None, "fedosaa_svrg")
        state, done, ms, rel, lives = runner(state, np.int32(rounds))
        rels[impl] = np.asarray(jax.device_get(rel))
    w0 = prob.init(jax.random.PRNGKey(0))
    g = prob.global_grad(w0)
    batch = prob.clients.client(0)
    rng = jax.random.PRNGKey(1)
    wt_t, rt_t = _svrg_trajectory(prob, hp, w0, g, batch, rng)
    wt_p, rt_p = _svrg_trajectory(prob, dataclasses.replace(hp, local_impl="pallas"),
                                  w0, g, batch, rng)
    traj_diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                    for a, b in ((wt_t, wt_p), (rt_t, rt_p)))
    return {
        "algo": "fedosaa_svrg",
        "runtime": "vmap",
        "local_impl": "pallas",
        "executor": "kernel" if jax.default_backend() == "tpu" else "fused-ref",
        "rounds": rounds,
        "rel_error_tree": [float(v) for v in rels["tree"]],
        "rel_error_pallas": [float(v) for v in rels["pallas"]],
        "trajectory_max_abs_diff_vs_tree": traj_diff,
    }


def run(smoke: bool = False) -> dict:
    rounds = 4 if smoke else 16
    chunk = 2 if smoke else 8
    reps = 2 if smoke else 7   # 7: the noisy-neighbor spikes of this shared
                               # container occasionally last a whole 5-rep
                               # cell; min-of-7 keeps sibling rows comparable
    prob, wstar = logreg_setup("covtype", n=10_000, k=10)
    mesh = make_host_mesh()
    algos = ("fedosaa_svrg",) if smoke else ALGOS
    channels = ("identity",) if smoke else CHANNELS
    rows = []
    for algo in algos:
        for runtime in RUNTIMES:
            for channel in channels:
                cell_rows = _bench_cell(prob, wstar, algo, runtime, channel,
                                        mesh, rounds, chunk, reps,
                                        _local_impls(algo, runtime))
                for row in cell_rows:
                    rows.append(row)
                    print(f"{algo:18s} {runtime:7s} {channel:8s} "
                          f"{row['local_impl']:6s} "
                          f"seed {row['seed_loop_s_per_round']*1e3:7.2f} "
                          f"ms/round -> engine "
                          f"{row['engine_s_per_round']*1e3:7.2f}"
                          f"  ({row['engine_speedup_vs_seed_loop']:.2f}x)")
    # cohort cells: the sampled-cohort round (C=4 of K=10) against the SAME
    # dense seed-loop baseline — the participation-as-memory-model row of
    # the trajectory (benchmarks/ext_cohort.py sweeps the K axis)
    for runtime in (("vmap",) if smoke else RUNTIMES):
        for row in _bench_cell(prob, wstar, "fedosaa_svrg", runtime,
                               "identity", mesh, rounds, chunk, reps,
                               ("tree",), cohort=4):
            rows.append(row)
            print(f"{'fedosaa_svrg':18s} {runtime:7s} {'identity':8s} "
                  f"{row['local_impl']:6s} cohort=4 "
                  f"seed {row['seed_loop_s_per_round']*1e3:7.2f} "
                  f"ms/round -> engine "
                  f"{row['engine_s_per_round']*1e3:7.2f}"
                  f"  ({row['engine_speedup_vs_seed_loop']:.2f}x)")
    pallas = _pallas_row(prob, wstar, rounds=2 if smoke else 4)
    print(f"aa_impl=pallas parity: max |Δparams| vs tree "
          f"{pallas['max_abs_param_diff_vs_tree']:.2e}")
    local = _local_row(prob, wstar, rounds=4 if smoke else 8)
    print(f"local_impl=pallas trajectory parity vs tree: "
          f"{local['trajectory_max_abs_diff_vs_tree']:.2e}; final rel-error "
          f"tree {local['rel_error_tree'][-1]:.2e} vs pallas "
          f"{local['rel_error_pallas'][-1]:.2e}")
    headline = next(
        r for r in rows
        if (r["algo"], r["runtime"], r["channel"], r["local_impl"])
        == ("fedosaa_svrg", "vmap", "identity", "pallas"))
    out = {
        "bench": "round_engine",
        "setup": {"dataset": "covtype-quick", "n": 10_000, "k": 10,
                  "eta": 1.0, "local_epochs": 10,
                  "backend": jax.default_backend(),
                  "xla_flags": os.environ.get("XLA_FLAGS", ""),
                  "timing": "interleaved reps, per-mode min",
                  "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")},
        "smoke": smoke,
        "rows": rows,
        "aa_impl_pallas": pallas,
        "local_impl_pallas": local,
        "headline": {
            "cell": "fedosaa_svrg/vmap/identity/local_impl=pallas",
            "engine_speedup_vs_seed_loop":
                headline["engine_speedup_vs_seed_loop"],
            "seed_loop_s_per_round": headline["seed_loop_s_per_round"],
            "engine_s_per_round": headline["engine_s_per_round"],
        },
    }
    path = SMOKE_PATH if smoke else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"headline: {out['headline']['engine_speedup_vs_seed_loop']:.2f}x "
          f"({path})")
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
