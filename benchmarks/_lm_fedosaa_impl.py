"""Beyond-paper: FedOSAA on a transformer LM (smollm-family reduced).

Reproduces the paper's Appendix-D.5 finding on a REAL language model instead
of an MLP: vanilla (undamped) FedOSAA-SVRG converges but can underperform
FedSVRG on non-convex training; damping (App. A) closes the gap. Derived
metric = final training loss.
"""
from __future__ import annotations

import time

from repro.configs import get_arch
from repro.core import AlgoHParams, run_federated
from repro.core.anderson import AAConfig
from repro.core.lm import make_lm_clients, make_lm_problem
from repro.data import make_lm_tokens
from repro.models.decoder import build_model

from benchmarks.common import save_results


def run_impl(quick: bool = True) -> list[dict]:
    rounds = 8 if quick else 40
    cfg = get_arch("smollm-135m").reduced()
    model = build_model(cfg)
    toks = make_lm_tokens(16, 128, cfg.vocab_size)
    clients = make_lm_clients(toks, 4)
    problem = make_lm_problem(model, clients)

    specs = [
        ("fedsvrg", AAConfig()),
        ("fedosaa_svrg", AAConfig(tikhonov=1e-8)),              # vanilla
        ("fedosaa_svrg", AAConfig(tikhonov=1e-8, damping=0.5)), # App. A damped
    ]
    rows = []
    for algo, aacfg in specs:
        hp = AlgoHParams(eta=0.3, local_epochs=5, aa=aacfg)
        t0 = time.time()
        h = run_federated(problem, algo, hp, rounds)
        tag = "damped" if aacfg.damping != 1.0 else (
            "vanilla" if algo.startswith("fedosaa") else "baseline")
        rows.append({
            "name": f"lm_fedosaa/{algo}/{tag}",
            "us_per_call": 1e6 * (time.time() - t0) / max(len(h.rounds), 1),
            "derived": float(h.loss[-1]),
            "loss_curve": [float(v) for v in h.loss],
            "grad_norm_curve": [float(v) for v in h.grad_norm],
        })
    save_results("lm_fedosaa", rows)
    return rows
