"""Beyond-paper: cross-round AA history (paper App. A option 1).

Clients keep the last H (s,y) secant pairs across aggregation rounds and
prepend them to the fresh trajectory columns in the AA solve. Stale columns
are secant pairs of a NEARBY Jacobian, so the Krylov space is enriched at
zero extra gradient cost — the regularized/filtered LS absorbs the
inconsistency. Derived = final relative error.
"""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    n, k = (10_000, 10) if quick else (58_100, 100)
    rounds = 15 if quick else 40
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    rows = []
    specs = [
        ("L10", AlgoHParams(eta=1.0, local_epochs=10)),
        ("L10_carry5", AlgoHParams(eta=1.0, local_epochs=10, carry_history=5)),
        ("L5", AlgoHParams(eta=1.0, local_epochs=5)),
        ("L5_carry5", AlgoHParams(eta=1.0, local_epochs=5, carry_history=5)),
        ("L3", AlgoHParams(eta=1.0, local_epochs=3)),
        ("L3_carry7", AlgoHParams(eta=1.0, local_epochs=3, carry_history=7)),
    ]
    for tag, hp in specs:
        rows.append(bench_algo(prob, wstar, "fedosaa_svrg", hp, rounds,
                               f"ext_carry/{tag}"))
    save_results("ext_carry_history", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
