"""Benchmark driver: one module per paper table/figure + the roofline harness.

Prints ``name,us_per_call,derived`` CSV per the repo contract.

Usage:
  PYTHONPATH=src python -m benchmarks.run               # quick (CI) scale
  PYTHONPATH=src python -m benchmarks.run --full        # paper scale
  PYTHONPATH=src python -m benchmarks.run --only fig2   # subset
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "table1_comm",
    "fig1_lr_sweep",
    "fig1_epochs_sweep",
    "fig1_batch_sweep",
    "fig2_distributions",
    "fig3_fedavg_control",
    "fig45_gamma_clients",
    "fig6_walltime",
    "fig7_illcond",
    "fig8_nn",
    "ext_stability",      # beyond-paper: damping/filtering/moving-average
    "ext_carry_history",  # beyond-paper: cross-round AA history (App. A opt. 1)
    "ext_compression",    # beyond-paper: wire codecs × algorithms (repro/comm)
    "lm_fedosaa",         # beyond-paper: FedOSAA on a transformer LM
    "roofline",           # deliverable g: derived from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", type=str, default="", help="substring filter")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            rows = mod.run(quick=not args.full)
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.6e}")
            print(f"# {mod_name}: {len(rows)} rows in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures.append(mod_name)
            print(f"# {mod_name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
