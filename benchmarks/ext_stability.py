"""Beyond-paper: ablation of the Appendix-A stability options on a stochastic
(mini-batch) FedOSAA-SVRG run, where vanilla AA is known to stagnate at the
gradient-noise floor (App. C.2 / [36]).

Knobs: Tikhonov regularization, spectral filtering, damping. The derived
metric is final relative error — lower is better; the interesting comparison
is against the vanilla (tik=1e-10, no filter, damping=1) row.
"""
from __future__ import annotations

from repro.core import AlgoHParams
from repro.core.anderson import AAConfig

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    n, k = (10_000, 10) if quick else (58_100, 100)
    rounds = 25 if quick else 50
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    rows = []
    # the docstring pins the reference row at tik=1e-10 / no filter /
    # damping=1 — construct it explicitly and assert it still matches the
    # dataclass defaults so a future AAConfig default change can't silently
    # move the ablation's baseline
    vanilla = AAConfig(tikhonov=1e-10, filter_rtol=0.0, damping=1.0,
                       residual_ema=0.0)
    assert vanilla == AAConfig(), (
        "AAConfig defaults moved away from the documented vanilla baseline "
        f"(tik=1e-10, no filter, damping=1): {AAConfig()}")
    variants = [
        ("vanilla", vanilla),
        ("tikhonov", AAConfig(tikhonov=1e-6)),
        ("filter", AAConfig(filter_rtol=1e-6)),
        ("damped", AAConfig(damping=0.5)),
        ("ema", AAConfig(residual_ema=0.5)),
        ("combo", AAConfig(tikhonov=1e-6, filter_rtol=1e-6, damping=0.7)),
        ("combo_ema", AAConfig(tikhonov=1e-6, damping=0.7, residual_ema=0.5)),
    ]
    for bs, tag in ((32, "B32"), (None, "full")):
        for name, aacfg in variants:
            hp = AlgoHParams(eta=0.5, local_epochs=10, batch_size=bs, aa=aacfg)
            rows.append(bench_algo(prob, wstar, "fedosaa_svrg", hp, rounds,
                                   f"ext_stability/{tag}/{name}"))
    save_results("ext_stability", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
