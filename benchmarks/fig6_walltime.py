"""Figure 6: computation-time comparison. DANE's exact local solves cost
orders of magnitude more per round than everything else (paper: 51 s vs 0.8 s
per round on covtype); us_per_call is the direct analogue.

Timing rides the same per-round clock as benchmarks/bench_round.py
(History.wall_time via bench_algo), and every algorithm runs through the
device-resident round engine (chunk=4) so the comparison measures round
COMPUTE, not per-round dispatch overhead."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

ROUND_CHUNK = 4


def run(quick: bool = True) -> list[dict]:
    n, k = (10_000, 10) if quick else (58_100, 100)
    rounds = 8 if quick else 20
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    rows = []
    specs = [
        ("fedsvrg", AlgoHParams(eta=1.0, local_epochs=10)),
        ("fedosaa_svrg", AlgoHParams(eta=1.0, local_epochs=10)),
        ("giant", AlgoHParams(local_epochs=10)),
        ("newton_gmres", AlgoHParams(local_epochs=10)),
        ("lbfgs", AlgoHParams(eta=1.0, local_epochs=10)),
        ("dane", AlgoHParams(dane_newton_iters=10, dane_cg_iters=50)),
    ]
    for algo, hp in specs:
        rows.append(bench_algo(prob, wstar, algo, hp, rounds, f"fig6/{algo}",
                               chunk=ROUND_CHUNK))
    save_results("fig6_walltime", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
