"""Beyond-paper: wire compression × algorithm — rel-error vs cumulative bytes.

The paper's pitch is "fewer aggregation rounds"; this benchmark converts it
into measured "fewer bytes" by sweeping the repro/comm channel (fp32, bf16,
int8-SR+EF+diff-coding, topk+EF) across the headline algorithms on the
synthetic logistic-regression suite, running every pair to rel-error 1e-6 (or
a round cap) and recording the codec-exact cumulative wire bytes.

The suite runs in float64 (the paper's plots reach rel-error 1e-10; f32
local-step iterations have a fixed-point bias floor around 1e-5 — measured
here before the switch: every η-GD method stalled at 1.3–1.5e-5 while Newton
reached 5e-7). The "full-precision" baseline channel is therefore ``fp32``
(a 4-byte f32 wire over f64 compute), not ``identity``.

Headline numbers (quick suite, covtype n=20k K=20, η=1, L=10, committed in
results/ext_compression.json):
  * fedosaa_svrg over the int8 channel reaches 1e-6 in 19 rounds / 2204 B —
    0.95× the rounds of fp32 fedosaa_svrg (20 rounds / 8640 B) because
    int8-SR noise rides on deltas/diffs that vanish at the optimum, and 39×
    fewer cumulative bytes than fp32 fedsvrg (a LOWER bound: fedsvrg is
    still at 2.7e-3 when the 200-round cap / 86.4 kB hits). Asserted in the
    summary row: bytes_vs_fp32_fedsvrg ≥ 3.5, rounds_vs_fp32_fedosaa ≤ 1.3.
  * bf16 is numerically free down to 1e-6 for fedosaa_svrg (17 rounds, half
    the bytes) on both runtimes (sharded host-mesh row: 16 rounds).
  * topk compresses the delta uplink only (see repro/comm/codecs.py:
    sparsified absolute-gradient uploads floor out even under error
    feedback), so its 2-round-trip methods pay fp32 for the gradient leg;
    it converges exactly (fedosaa_svrg 162 rounds) but on this tiny d=54
    model the index overhead makes it the worst codec — it exists for the
    d ≥ 10^6 regime.
  * The Newton family (GIANT / Newton-GMRES / DANE) rides the same stateful
    wire as everyone else via the declarative uplink schemas
    (repro/comm/schema.py): the gradient uplink is difference-coded against
    a carried reference and the direction/delta uplink carries error
    feedback. That un-floored the lossy rows — pre-schema, stateless Newton
    uplinks floored at bf16 1.2e-4 / int8 6.7e-4; now int8 giant reaches
    the 1e-6 target in 9 rounds / 1044 B (final 1.1e-7, vs fp32's 6 rounds
    / 2592 B) and int8 newton_gmres in 8 rounds / 928 B — recorded as
    ``*_reached_target`` acceptance booleans in the summary row (the CI
    gate for this is the --smoke Newton check; the full run is record-only).
    topk converges exactly for the family too (EF'd direction, fp32
    gradient leg), just slowly on this tiny model.

A sharded-runtime row runs the bf16 channel under shard_map on the host mesh
(the 2×16×16 multi-pod trace lives in results/dryrun/fl_round__*bf16*.json —
produced by `python -m repro.launch.dryrun --fl-round fedosaa_svrg
--multi-pod --fl-rounds 5 --comm-codec bf16`).

  PYTHONPATH=src python -m benchmarks.ext_compression            # quick
  PYTHONPATH=src python -m benchmarks.ext_compression --full
  PYTHONPATH=src python -m benchmarks.ext_compression --smoke    # CI gate
"""
from __future__ import annotations

import sys

import jax

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

TARGET = 1e-6

CHANNELS = [
    ("fp32", "fp32"),
    ("bf16", "bf16"),
    ("int8", "int8"),
    ("topk", "topk:0.05"),
]

ALGOS = ["fedosaa_svrg", "fedosaa_scaffold", "fedsvrg", "scaffold", "giant",
         "newton_gmres", "dane"]


def _row(prob, wstar, algo, hp, cap, tag, channel, runtime="vmap"):
    r = bench_algo(prob, wstar, algo, hp, cap, tag, channel=channel,
                   stop_rel_error=TARGET, runtime=runtime)
    r["target"] = TARGET
    r["target_reached"] = r["derived"] < TARGET
    # derived stays rel-error; the headline metric is cumulative bytes.
    # mb_curve pairs with rel_error_curve for the rel-error-vs-MB plot
    # (per-round wire cost is constant, so the cumulative curve is linear).
    r["cumulative_mb"] = r["comm_bytes"] / 1e6
    per_round_mb = r["comm_bytes"] / max(r["rounds"], 1) / 1e6
    r["mb_curve"] = [per_round_mb * (t + 1) for t in range(r["rounds"])]
    return r


def _summary(rows: list[dict]) -> dict:
    """Acceptance ratios: int8 fedosaa_svrg vs fp32 fedsvrg (bytes) and vs
    fp32 fedosaa_svrg (rounds); plus the stateful-Newton-wire acceptance —
    int8 GIANT/Newton-GMRES must reach the 1e-6 target (they floored at
    ~6.7e-4 on the pre-schema stateless wire)."""
    by = {r["name"]: r for r in rows}
    osaa_int8 = by["ext_compression/int8/fedosaa_svrg"]
    osaa_fp32 = by["ext_compression/fp32/fedosaa_svrg"]
    svrg_fp32 = by["ext_compression/fp32/fedsvrg"]
    bytes_ratio = svrg_fp32["comm_bytes"] / osaa_int8["comm_bytes"]
    rounds_ratio = osaa_int8["rounds"] / osaa_fp32["rounds"]
    return {
        "name": "ext_compression/summary",
        "us_per_call": 0.0,
        "derived": bytes_ratio,
        "int8_fedosaa_reached_target": osaa_int8["target_reached"],
        "bytes_vs_fp32_fedsvrg": bytes_ratio,          # acceptance: >= 3.5
        "rounds_vs_fp32_fedosaa": rounds_ratio,        # acceptance: <= 1.3
        "fp32_fedsvrg_reached_target": svrg_fp32["target_reached"],
        # stateful Newton wire (uplink schemas): acceptance — all True
        "int8_giant_reached_target":
            by["ext_compression/int8/giant"]["target_reached"],
        "int8_newton_gmres_reached_target":
            by["ext_compression/int8/newton_gmres"]["target_reached"],
        "bf16_giant_reached_target":
            by["ext_compression/bf16/giant"]["target_reached"],
    }


def run(quick: bool = True) -> list[dict]:
    n, k = (20_000, 20) if quick else (58_100, 100)
    cap = 200 if quick else 400
    was_x64 = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        prob, wstar = logreg_setup("covtype", n=n, k=k, dtype="float64")
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        rows = []
        for cname, channel in CHANNELS:
            for algo in ALGOS:
                rows.append(_row(prob, wstar, algo, hp, cap,
                                 f"ext_compression/{cname}/{algo}", channel))
        # sharded-runtime bf16 numerics on the host mesh (multi-pod trace:
        # results/dryrun/fl_round__fedosaa_svrg__bf16__2x16x16.json)
        rows.append(_row(prob, wstar, "fedosaa_svrg", hp, 25,
                         "ext_compression/bf16/fedosaa_svrg/sharded", "bf16",
                         runtime="sharded"))
        rows.append(_summary(rows))
    finally:
        jax.config.update("jax_enable_x64", was_x64)
    save_results("ext_compression", rows)
    return rows


def smoke() -> int:
    """Tiny CI gate (seconds, not minutes): every codec runs on every family
    kind — including the stateful Newton-family wire — byte accounting is
    consistent, and int8 does not break convergence. Returns a nonzero exit
    code on regression."""
    prob, wstar = logreg_setup("covtype", n=2_000, k=8)
    hp = AlgoHParams(eta=1.0, local_epochs=5)
    failures = []
    by = {}
    for cname, channel in [("fp32", None), ("bf16", "bf16"),
                           ("int8", "int8"), ("topk", "topk:0.25")]:
        for algo in ("fedosaa_svrg", "fedsvrg", "giant", "newton_gmres"):
            r = by[cname, algo] = bench_algo(prob, wstar, algo, hp, 10,
                                             f"smoke/{cname}/{algo}",
                                             channel=channel)
            print_csv([r])
            if not (r["derived"] == r["derived"]):          # nan guard
                failures.append(f"{r['name']}: rel-error is nan")
            if r["comm_bytes"] <= 0:
                failures.append(f"{r['name']}: no bytes accounted")
    fp32 = by["fp32", "fedosaa_svrg"]
    int8 = by["int8", "fedosaa_svrg"]
    if int8["comm_bytes"] >= 0.5 * fp32["comm_bytes"]:
        failures.append("int8 channel does not compress")
    if int8["derived"] > max(100 * fp32["derived"], 1e-3):
        failures.append(
            f"int8 fedosaa_svrg diverged from fp32: {int8['derived']:.2e} "
            f"vs {fp32['derived']:.2e}")
    # stateful Newton wire: int8 GIANT must track fp32 GIANT instead of
    # flooring an order of magnitude above it (pre-schema behavior)
    for algo in ("giant", "newton_gmres"):
        nf, n8 = by["fp32", algo], by["int8", algo]
        if n8["derived"] > max(10 * nf["derived"], 1e-4):
            failures.append(
                f"int8 {algo} floored vs fp32 (stateless wire regression?): "
                f"{n8['derived']:.2e} vs {nf['derived']:.2e}")
    for f in failures:
        print(f"SMOKE FAIL: {f}")
    print("ext_compression smoke:", "FAIL" if failures else "OK")
    return 1 if failures else 0


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    print_csv(run(quick="--full" not in sys.argv))
