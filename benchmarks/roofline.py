"""Roofline analysis (deliverable g): derive the three roofline terms from
the compiled dry-run artifacts and identify the per-pair bottleneck.

Terms (per device; the dry-run compiles the SPMD-partitioned per-device
program, so chips cancel):

  compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_s     = HLO_bytes_per_device / HBM_BW
  collective_s = collective_bytes_per_device / ICI_BW

CPU-backend caveat: compiled.cost_analysis() undercounts FLOPs on the CPU
backend (dot-generals lower to opaque runtime custom-calls), so HLO_FLOPs is
computed ANALYTICALLY per (arch, shape) — every matmul, attention-quadratic,
SSD-chunk, MoE-capacity and padding overhead term, plus the remat recompute
factor for training. cost_analysis bytes (memory term) and the HLO-parsed
collective bytes are taken from the compiled artifact directly.

Conventions (documented, consistent across all pairs):
* collective bytes = Σ result-shape bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute ops in the partitioned
  HLO (dryrun.collective_bytes). Result bytes ≈ wire bytes for AG/AR; for
  reduce-scatter this undercounts by the shard ratio — acceptable for
  bottleneck identification.
* ICI_BW = 45 GB/s effective per chip (v5e ~50 GB/s/link, one busy link
  direction assumed; 2D-torus overlap ignored -> conservative).
* MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (inference),
  GLOBAL; the 'useful ratio' divides by HLO_FLOPs × chips.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.analytic_flops import analytic_flops_global

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 45e9                # effective bytes/s / chip (documented above)

HERE = os.path.dirname(__file__)
DRYRUN_DIR = os.path.join(HERE, "results", "dryrun")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}
TRAIN_SHAPES = {"train_4k"}


def analyze(rec: dict) -> dict:
    from repro.configs import get_arch, get_shape
    from repro.launch.specs_io import effective_cfg

    shape = rec["shape"]
    chips = rec["chips"]
    shape_obj = get_shape(shape)
    model_shards = 16
    cfg = effective_cfg(get_arch(rec["arch"]), shape_obj).padded(model_shards)
    fb = analytic_flops_global(cfg, shape_obj)
    flops_dev = fb.total / chips

    coll = sum(v for k, v in rec["collectives"].items()
               if not k.endswith("_count"))
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = rec["bytes_accessed"] / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    tokens = SHAPE_TOKENS[shape]
    mult = 6 if shape in TRAIN_SHAPES else 2
    model_flops = mult * rec["active_params"] * tokens
    useful = model_flops / fb.total if fb.total else float("nan")
    bound_s = max(terms.values())
    return {
        **rec,
        "flops_analytic_device": flops_dev,
        "flops_cost_analysis_device": rec["flops"],
        "flop_breakdown": {k: getattr(fb, k) for k in
                           ("attn_proj", "attn_quadratic", "mlp", "moe",
                            "ssm", "embed_head", "elementwise", "optimizer")},
        "collective_bytes": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": useful,
        "roofline_bound_s": bound_s,
        # fraction of the bound that is useful compute — the hillclimb metric
        "roofline_fraction": (model_flops / chips / PEAK_FLOPS) / bound_s
                             if bound_s else float("nan"),
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        ag = row["collectives"].get("all-gather", 0)
        ar = row["collectives"].get("all-reduce", 0)
        if ag > ar:
            return ("all-gather dominates: reduce TP resharding (fuse "
                    "constraints, shard activations consistently) or widen "
                    "per-step compute (larger microbatch)")
        return ("all-reduce dominates: overlap grad/TP reductions with "
                "compute or move to reduce-scatter + local update")
    if d == "memory":
        return ("HBM-bound: fuse elementwise chains (Pallas), cut activation "
                "round-trips (remat policy), or raise arithmetic intensity "
                "(bigger tiles / batch)")
    return ("compute-bound (good): push MXU utilization — 128-aligned tile "
            "shapes, bf16 accumulation where safe")


def load(mesh: str) -> list[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(p) as f:
            rows.append(analyze(json.load(f)))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def run(quick: bool = True) -> list[dict]:
    """benchmarks/run.py entry: emits one CSV row per (arch × shape) with the
    roofline-bound time as us_per_call and the roofline fraction as derived."""
    rows = load("16x16")
    out = []
    for r in rows:
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": r["roofline_bound_s"] * 1e6,
            "derived": r["roofline_fraction"],
            "dominant": r["dominant"],
        })
    return out


def markdown_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | regime | compute | memory | collective | dominant "
        "| useful FLOPs | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['regime']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']*100:.0f}% | {r['roofline_fraction']*100:.1f}% "
            f"| {suggestion(r)} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.markdown:
        print(markdown_table(rows))
        return
    print("arch,shape,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_fraction")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4e},{r['memory_s']:.4e},"
              f"{r['collective_s']:.4e},{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
