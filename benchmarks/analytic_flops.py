"""Analytic per-device HLO-equivalent FLOPs per (arch × input shape).

Counts everything the compiled step actually executes — including the
overheads that separate HLO FLOPs from the 6·N·D model FLOPs:
  * attention score/PV quadratic terms (causal ⇒ ×0.5),
  * padded heads / padded vocab (TP divisibility),
  * MoE capacity over-dispatch (capacity_factor; dropless at decode),
  * SSD intra-chunk quadratic + inter-chunk combine,
  * training = 3× forward (fwd + 2× bwd) + 1× forward recompute (full remat),
  * FL local-step SGD/correction adds (3 flops/param),
conventions: 1 MAC = 2 FLOPs; elementwise/normalization terms are included
at 1 FLOP/element where they are O(tokens·d) (they matter for small archs).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs.registry import InputShape


@dataclasses.dataclass
class FlopBreakdown:
    attn_proj: float = 0.0
    attn_quadratic: float = 0.0
    mlp: float = 0.0
    moe: float = 0.0
    ssm: float = 0.0
    embed_head: float = 0.0
    elementwise: float = 0.0
    optimizer: float = 0.0

    @property
    def total(self) -> float:
        return (self.attn_proj + self.attn_quadratic + self.mlp + self.moe
                + self.ssm + self.embed_head + self.elementwise + self.optimizer)


def _attn_layer(cfg: ArchConfig, T: float, kv_len: float, causal_half: bool):
    hd = cfg.resolved_head_dim
    H, KV = cfg.eff_heads, cfg.eff_kv_heads
    d = cfg.d_model
    proj = 2 * T * d * (H * hd) * 2 + 2 * T * d * (KV * hd) * 2
    quad = 2 * T * kv_len * H * hd * 2          # scores + PV
    if causal_half:
        quad *= 0.5
    return proj, quad


def _mlp_layer(cfg: ArchConfig, T: float):
    return 2 * T * 3 * cfg.d_model * cfg.d_ff


def _moe_layer(cfg: ArchConfig, T: float, dropless: bool):
    # router + dispatched expert FFNs at capacity
    router = 2 * T * cfg.d_model * cfg.eff_experts
    eff_tokens = T * cfg.experts_per_token
    if not dropless:
        eff_tokens *= cfg.capacity_factor
    ffn = 2 * eff_tokens * 3 * cfg.d_model * cfg.moe_d_ff
    return router + ffn


def _ssm_layer(cfg: ArchConfig, T: float, decode: bool):
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    proj = 2 * T * d * (2 * di + 2 * st + nh) + 2 * T * di * d
    conv = 2 * T * (di + 2 * st) * cfg.ssm_conv_width
    if decode:
        # recurrent update: h·dA + dt·B⊗x + C·h  per head
        ssd = T * nh * hd * st * 3 * 2
    else:
        Q = cfg.ssm_chunk
        # intra-chunk per chunk/head: CBᵀ (2Q²st) + att·x (2Q²hd, tril ⇒ ×.5 skipped:
        # the kernel computes the full block) + state build (2Q·hd·st)
        per_tok_head = 2 * Q * st + 2 * Q * hd + 2 * hd * st
        # inter-chunk offsets: y_off C·state (2·hd·st per tok/head) + combine
        per_tok_head += 2 * hd * st
        ssd = T * nh * per_tok_head
    return proj + conv + ssd


def analytic_flops_global(cfg: ArchConfig, shape: InputShape,
                          fl_train: bool = True) -> FlopBreakdown:
    """GLOBAL flops for one step of this (arch, shape); divide by chips for
    the per-device roofline term. cfg must be the PADDED config."""
    fb = FlopBreakdown()
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    decode = kind == "decode"
    T = B * (1 if decode else S)              # tokens through the stack
    if cfg.sliding_window:
        kv_len = min(cfg.sliding_window, S)   # window bounds the kv extent
        causal_half = False
    else:
        kv_len = S
        causal_half = not decode              # causal averages to S/2

    V = cfg.eff_vocab
    d = cfg.d_model

    def add_attn(n_layers, mlp="dense"):
        p, q = _attn_layer(cfg, T, kv_len, causal_half)
        fb.attn_proj += n_layers * p
        fb.attn_quadratic += n_layers * q
        if mlp == "dense":
            fb.mlp += n_layers * _mlp_layer(cfg, T)
        elif mlp == "moe":
            fb.moe += n_layers * _moe_layer(cfg, T, dropless=decode)

    fam = cfg.family
    L = cfg.num_layers
    if fam in ("dense", "vlm", "audio"):
        add_attn(L)
    elif fam == "moe":
        add_attn(L, mlp="moe")
    elif fam == "ssm":
        fb.ssm += L * _ssm_layer(cfg, T, decode)
    else:  # hybrid
        period = cfg.shared_attn_period
        n_shared = L // period
        n_mamba = L - n_shared
        fb.ssm += n_mamba * _ssm_layer(cfg, T, decode)
        p, q = _attn_layer(cfg, T, kv_len, causal_half)
        fb.attn_proj += n_shared * p
        fb.attn_quadratic += n_shared * q
        fb.mlp += n_shared * _mlp_layer(cfg, T)

    # unembed: all positions in train; last position only otherwise
    head_T = T if kind == "train" else B
    fb.embed_head += 2 * head_T * d * V
    # norms/residuals/rope: ~12 elementwise ops per token·d per layer
    fb.elementwise += 12 * T * d * L

    mult = 1.0
    if kind == "train":
        mult = 4.0        # fwd + 2×bwd + full-remat fwd recompute
        if fl_train:
            fb.optimizer += 3 * cfg.param_count()   # corrected-SGD update
    for f in ("attn_proj", "attn_quadratic", "mlp", "moe", "ssm",
              "embed_head", "elementwise"):
        setattr(fb, f, getattr(fb, f) * mult)
    return fb
