"""Beyond-paper: what a preemption-tolerant checkpoint costs per chunk —
the save-overlap economics of repro/checkpoint (async per-shard saves
dispatched from the engine's chunk-boundary host sync, policy.py) vs the
two blocking alternatives.

Three runs of the same compiled FedOSAA-SVRG engine schedule (chunked
device-resident rounds, core/engine.py), differing only in the checkpoint
policy at the chunk boundary:

  * ``none``        — no checkpointing: the floor every mode is billed
                      against;
  * ``async``       — the tentpole path: the boundary snapshots addressable
                      shards (host copies of arrays the next chunk is about
                      to donate) and hands serialization + atomic commit to
                      a background thread that overlaps the next chunk's
                      device execution;
  * ``sync_gather`` — the naive baseline the async path replaces: a full
                      ``jax.device_get`` of the state plus a blocking
                      legacy npz save, all inside the boundary.

Per-chunk wall is measured from History.wall_time diffs at chunk
boundaries; the first chunk (compile) is excluded and the median of the
rest is the per-mode cost. ``every`` equals the chunk size, so EVERY
boundary pays its mode's save — the measured overhead is the worst-case
cadence, real runs save less often.

Acceptance (committed in results/ext_checkpoint.json, validated by
scripts/check_ext_checkpoint.py, smoke-gated in scripts/ci.sh):
  * the async mode's median per-chunk overhead over ``none`` is <= 10%
    (the ISSUE's ceiling for "checkpointing is effectively free");
  * every mode converges identically (same loss curve — checkpointing
    must not perturb the math);
  * each checkpointing run commits the expected number of checkpoints and
    reports non-zero checkpoint_bytes in its v4 footer.

  PYTHONPATH=src python -m benchmarks.ext_checkpoint           # quick
  PYTHONPATH=src python -m benchmarks.ext_checkpoint --full
  PYTHONPATH=src python -m benchmarks.ext_checkpoint --smoke   # CI gate
"""
from __future__ import annotations

import shutil
import sys
import tempfile

import numpy as np

from repro.checkpoint import CheckpointPolicy, list_checkpoints
from repro.core import AAConfig, AlgoHParams, run_federated
from repro.obs import MemorySink

from benchmarks.common import logreg_setup, print_csv, save_results

ALGO = "fedosaa_svrg"
OVERHEAD_BUDGET = 0.10   # async per-chunk overhead vs no-checkpoint floor

# carried history + int8 channel: the state a checkpoint actually has to
# serialize is every buffer class, not just params. local_epochs=10 keeps
# the chunk wall representative — on this 1-core container the save's CPU
# cannot truly overlap device compute, so the per-save cost is a constant
# that only amortizes against a realistically sized chunk (production
# chunks are seconds; a 35ms chunk would overstate the relative overhead).
HP = dict(eta=1.0, local_epochs=10, carry_history=2,
          aa=AAConfig(tikhonov=1e-6, damping=0.7))


def _chunk_walls(wall_time, chunk: int) -> list[float]:
    """Per-chunk walls from the cumulative per-round timer, compile chunk
    excluded."""
    w = np.asarray(wall_time, dtype=float)
    bounds = w[chunk - 1::chunk]
    walls = np.diff(np.concatenate([[0.0], bounds]))
    return [float(v) for v in walls[1:]]  # drop chunk 0 (compile)


def _run_mode(prob, wstar, hp, rounds: int, chunk: int, mode: str | None,
              tag: str) -> dict:
    sink = MemorySink()
    ckpt_dir = None
    policy = None
    if mode is not None:
        ckpt_dir = tempfile.mkdtemp(prefix=f"ext_ckpt_{mode}_")
        policy = CheckpointPolicy(directory=ckpt_dir, every=chunk, keep=0,
                                  mode=mode)
    try:
        h = run_federated(prob, ALGO, hp, rounds, w_star=wstar,
                          channel="int8", chunk=chunk, sinks=[sink],
                          checkpoint=policy)
        walls = _chunk_walls(h.wall_time, chunk)
        n_ckpts = (len(list_checkpoints(ckpt_dir)) if mode == "async"
                   or mode == "sync" else None)
        return {
            "name": tag,
            "us_per_call": 1e6 * float(np.median(walls)) / chunk,
            "derived": float(h.rel_error[-1]),
            "mode": mode or "none",
            "rounds": int(len(h.rounds)),
            "chunk": chunk,
            "chunk_wall_median_s": float(np.median(walls)),
            "chunk_wall_p90_s": float(np.quantile(walls, 0.9)),
            "chunk_walls_s": walls,
            "final_loss": float(h.loss[-1]),
            "loss_curve": [float(v) for v in h.loss],
            "checkpoints_committed": n_ckpts,
            "checkpoint_save_ms": sink.footer["checkpoint_save_ms"],
            "checkpoint_bytes": sink.footer["checkpoint_bytes"],
            "checkpoint_failures": sink.footer["checkpoint_failures"],
        }
    finally:
        if ckpt_dir is not None:
            shutil.rmtree(ckpt_dir, ignore_errors=True)


def _summary(rows: list[dict]) -> dict:
    by = {r["mode"]: r for r in rows}
    floor = by["none"]["chunk_wall_median_s"]

    def overhead(mode: str) -> float:
        return (by[mode]["chunk_wall_median_s"] - floor) / floor

    same_math = all(
        len(r["loss_curve"]) == len(by["none"]["loss_curve"])
        and bool(np.all(np.asarray(r["loss_curve"])
                        == np.asarray(by["none"]["loss_curve"])))
        for r in rows)
    return {
        "name": "ext_checkpoint/summary",
        "us_per_call": 0.0,
        "derived": overhead("async"),
        # acceptance: <= OVERHEAD_BUDGET / True / True
        "async_overhead": overhead("async"),
        "sync_gather_overhead": overhead("sync_gather"),
        "loss_curves_identical_across_modes": same_math,
        "async_saves_committed": by["async"]["checkpoints_committed"],
        "async_checkpoint_bytes": by["async"]["checkpoint_bytes"],
        "none_chunk_wall_s": floor,
        "async_chunk_wall_s": by["async"]["chunk_wall_median_s"],
        "sync_gather_chunk_wall_s": by["sync_gather"]["chunk_wall_median_s"],
        "overhead_budget": OVERHEAD_BUDGET,
    }


def run(quick: bool = True) -> list[dict]:
    n, k = (20_000, 32) if quick else (58_100, 100)
    rounds, chunk = (42, 6) if quick else (48, 6)
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    hp = AlgoHParams(**HP)

    def best_of(mode, tag, reps=2):
        # best-of-N medians: the shared 1-core container injects tens-of-ms
        # noise spikes per run; the floor is the honest per-mode cost
        runs = [_run_mode(prob, wstar, hp, rounds, chunk, mode, tag)
                for _ in range(reps)]
        return min(runs, key=lambda r: r["chunk_wall_median_s"])

    rows = [
        best_of(None, "ext_checkpoint/none"),
        best_of("async", "ext_checkpoint/async"),
        best_of("sync_gather", "ext_checkpoint/sync_gather"),
    ]
    rows.append(_summary(rows))
    save_results("ext_checkpoint", rows)
    return rows


def smoke() -> int:
    """Tiny CI gate (seconds): all three modes run the same math, the
    checkpointing modes commit saves with clean footers. Writes nothing —
    the committed results/ext_checkpoint.json is validated by
    scripts/check_ext_checkpoint.py."""
    prob, wstar = logreg_setup("covtype", n=2_000, k=8)
    hp = AlgoHParams(**HP)
    rows = [
        _run_mode(prob, wstar, hp, 8, 4, None, "smoke/none"),
        _run_mode(prob, wstar, hp, 8, 4, "async", "smoke/async"),
        _run_mode(prob, wstar, hp, 8, 4, "sync_gather",
                  "smoke/sync_gather"),
    ]
    print_csv(rows)
    failures = []
    base = rows[0]["loss_curve"]
    for r in rows:
        if not np.isfinite(r["final_loss"]):
            failures.append(f"{r['name']}: non-finite final loss")
        if not np.all(np.asarray(r["loss_curve"]) == np.asarray(base)):
            failures.append(f"{r['name']}: checkpointing perturbed the math")
        if r["checkpoint_failures"]:
            failures.append(f"{r['name']}: {r['checkpoint_failures']} "
                            "checkpoint failures")
    if rows[1]["checkpoints_committed"] != 2:
        failures.append("async mode did not commit one save per chunk "
                        f"(got {rows[1]['checkpoints_committed']})")
    if rows[1]["checkpoint_bytes"] <= 0:
        failures.append("async footer reports zero checkpoint_bytes")
    for f in failures:
        print(f"SMOKE FAIL: {f}")
    print("ext_checkpoint smoke:", "FAIL" if failures else "OK")
    return 1 if failures else 0


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    print_csv(run(quick="--full" not in sys.argv))
