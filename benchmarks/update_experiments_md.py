"""Regenerate the roofline table inside EXPERIMENTS.md from the latest
dry-run artifacts (run after `repro.launch.dryrun --all`)."""
from __future__ import annotations

import os
import re

from benchmarks.roofline import load, markdown_table

HERE = os.path.dirname(__file__)
MD = os.path.join(HERE, "..", "EXPERIMENTS.md")


def main() -> None:
    rows = load("16x16")
    table = markdown_table(rows)
    with open(MD) as f:
        text = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    pattern = re.compile(re.escape(marker) + r".*?(?=\n\nReading the table)",
                         re.DOTALL)
    replacement = marker + "\n\n" + table
    new = pattern.sub(lambda _: replacement, text, count=1)
    with open(MD, "w") as f:
        f.write(new)
    print(f"inserted {len(rows)}-row roofline table into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
