"""Figure 2: IID / Imbalance / Label-skew comparison of FedOSAA against
first-order (FedAvg, FedSVRG, SCAFFOLD) and second-order (L-BFGS, GIANT,
Newton-GMRES) methods. K=10 as in the paper."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

ALGOS = ("fedavg", "fedsvrg", "scaffold", "lbfgs", "giant", "newton_gmres",
         "fedosaa_svrg", "fedosaa_scaffold")


def run(quick: bool = True) -> list[dict]:
    n = 20_000 if quick else 58_100
    rounds = 20 if quick else 40
    rows = []
    for scheme in ("iid", "imbalance", "label_skew"):
        prob, wstar = logreg_setup("covtype", n=n, k=10, scheme=scheme)
        # paper: label-skew needs a smaller local lr for FedOSAA stability
        eta = 0.5 if scheme == "label_skew" else 1.0
        for algo in ALGOS:
            hp = AlgoHParams(eta=eta, local_epochs=10)
            rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                   f"fig2/{scheme}/{algo}"))
    save_results("fig2_distributions", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
