"""Figure 1(c,f): vary minibatch size B_k. FedOSAA-SVRG tolerates small
batches; FedOSAA-SCAFFOLD fails in mini-batch scenarios (inaccurate server
control variate) — both effects are reported."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    n, k = (20_000, 20) if quick else (58_100, 100)
    rounds = 20 if quick else 40
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    n_k = n // k
    batches = (5, 64, n_k)   # n_k == full batch (no stochasticity)
    rows = []
    for b in batches:
        bs = None if b >= n_k else b
        hp = AlgoHParams(eta=1.0, local_epochs=10, batch_size=bs)
        for algo in ("fedosaa_svrg", "fedsvrg", "fedosaa_scaffold"):
            rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                   f"fig1_batch/{algo}/B{b}"))
    save_results("fig1_batch_sweep", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
