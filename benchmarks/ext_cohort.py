"""Beyond-paper: cohort-resident rounds — compute scales with C, not K.

The cohort memory model (core/client_store.py + the cohort plan in
core/algorithms.py) lets a round gather a sampled C-client cohort out of the
K-sized client store, compute on [C, ...] tensors only, and scatter the
updated rows back. This benchmark measures what that buys as the client
population grows: FedOSAA-SVRG engine rounds (core/engine.py, donated
lax.scan chunks) at fixed cohort size C=16 while K sweeps {32, 512, 4096},
against the dense all-K round at each K.

Two quantities per (K, mode) cell, both on the engine path:

  ms/round        — warm wall-time, interleaved reps, per-mode min (the
                    bench_round.py methodology; same thunk-runtime pin);
  peak live bytes — XLA's own compiled-memory analysis of the chunk
                    executable (argument + output + temp − aliased), i.e.
                    what the compiled round body actually holds live. The
                    cohort row's temp bytes stay O(C·d) while the dense
                    row's grow with K; the O(K·d) client store itself sits
                    in the donated *argument* bytes either way.

The dense K=4096 cell is the honest baseline: it is exactly what every
round would cost without the cohort axis. Full runs commit the sweep to
``benchmarks/results/ext_cohort.json``; ``--smoke`` (the CI gate) runs a
reduced sweep to a scratch path so it never clobbers the committed numbers.

Standalone (the XLA flag must precede jax init, so this module is not part
of benchmarks/run.py's MODULES):

  PYTHONPATH=src python -m benchmarks.ext_cohort           # full sweep
  PYTHONPATH=src python -m benchmarks.ext_cohort --smoke   # CI gate
"""
from __future__ import annotations

# BEFORE any jax import — the thunk runtime serializes compiled-loop bodies
# on CPU (see bench_round.py's XLA:CPU runtime note; measured in ROADMAP).
import os

XLA_CPU_FLAG = "--xla_cpu_use_thunk_runtime=false"
if XLA_CPU_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        XLA_CPU_FLAG + " " + os.environ.get("XLA_FLAGS", "")).strip()

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    AlgoHParams,
    init_state,
    make_chunk_runner,
    make_round_fn,
    solve_reference,
)
from repro.data import make_binary_classification, partition  # noqa: E402
from repro.models.logreg import make_logreg_problem           # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
#: the committed sweep (full mode only)
OUT_PATH = os.path.join(RESULTS_DIR, "ext_cohort.json")
#: --smoke scratch output — never clobbers the committed sweep
SMOKE_PATH = os.path.join(RESULTS_DIR, "ext_cohort_smoke.json")

ALGO = "fedosaa_svrg"
COHORT = 16


def _problem(num_clients: int):
    # 8 samples/client floor: the K=4096 convergence regime
    # (tests/test_cohort.py) — 2/client leaves local SVRG epochs too noisy
    n = max(2048, 8 * num_clients)
    X, y = make_binary_classification("synthetic_small", n=n, seed=0)
    clients = partition(X, y, num_clients=num_clients, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    return prob, solve_reference(prob, iters=100)


def _hp(cohort: int | None) -> AlgoHParams:
    return AlgoHParams(eta=0.5, local_epochs=2, cohort_size=cohort)


def _memory(compiled) -> dict:
    """XLA's compiled-memory analysis of one chunk executable."""
    m = compiled.memory_analysis()
    arg = int(m.argument_size_in_bytes)
    out = int(m.output_size_in_bytes)
    tmp = int(m.temp_size_in_bytes)
    alias = int(m.alias_size_in_bytes)
    return {
        "argument_bytes": arg,
        "output_bytes": out,
        "temp_bytes": tmp,
        "alias_bytes": alias,
        # what the executable holds live at once: donated args alias their
        # outputs, so the aliased bytes are counted a single time
        "peak_live_bytes": arg + out + tmp - alias,
    }


class _Mode:
    """One (K, cohort|dense) engine cell: a donated chunk runner plus its
    compiled-memory analysis, timed over warm chunks."""

    def __init__(self, prob, wstar, cohort, chunk):
        self.hp = _hp(cohort)
        self.chunk = chunk
        self.prob, self.wstar = prob, wstar
        round_fn = make_round_fn(ALGO, prob, self.hp)
        self.runner = make_chunk_runner(round_fn, chunk, w_star=wstar)
        state = init_state(prob, jax.random.PRNGKey(0), self.hp)
        self.memory = _memory(
            self.runner.lower(state, np.int32(chunk)).compile())
        out = self.runner(state, np.int32(chunk))   # compile + warm up
        jax.device_get(out[1:])
        self._warm = out[0]

    def time_rounds(self, rounds: int) -> float:
        n_chunks = max(rounds // self.chunk, 1)
        out = (self._warm,)
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            out = self.runner(out[0], np.int32(self.chunk))
            jax.device_get(out[1:])
        elapsed = time.perf_counter() - t0
        self._warm = out[0]
        return elapsed / (n_chunks * self.chunk)


def run(smoke: bool = False) -> dict:
    ks = (32, 128) if smoke else (32, 512, 4096)
    rounds = 2 if smoke else 8
    chunk = 2 if smoke else 4
    reps = 1 if smoke else 5
    rows = []
    for k in ks:
        prob, wstar = _problem(k)
        modes = {"cohort": _Mode(prob, wstar, COHORT, chunk),
                 "dense": _Mode(prob, wstar, None, chunk)}
        times = {name: [] for name in modes}
        for _ in range(reps):   # interleaved, min-taking (bench_round.py)
            for name, mode in modes.items():
                times[name].append(mode.time_rounds(rounds))
        t = {name: min(ts) for name, ts in times.items()}
        for name, mode in modes.items():
            rows.append({
                "algo": ALGO,
                "num_clients": k,
                "cohort": COHORT if name == "cohort" else None,
                "mode": name,
                "chunk": chunk,
                "rounds_timed": rounds,
                "reps": reps,
                "engine_s_per_round": t[name],
                **mode.memory,
            })
            print(f"K={k:5d} {name:6s} {t[name]*1e3:8.2f} ms/round  "
                  f"temp {mode.memory['temp_bytes']/2**10:9.1f} KiB  "
                  f"peak live {mode.memory['peak_live_bytes']/2**20:7.2f} MiB")
        rows[-2]["speedup_vs_dense"] = t["dense"] / t["cohort"]
        print(f"K={k:5d} cohort speedup vs dense: "
              f"{t['dense'] / t['cohort']:.2f}x")
    out = {
        "bench": "ext_cohort",
        "setup": {"algo": ALGO, "cohort_size": COHORT,
                  "dataset": "synthetic_small", "samples_per_client": 8,
                  "eta": 0.5, "local_epochs": 2,
                  "backend": jax.default_backend(),
                  "xla_flags": os.environ.get("XLA_FLAGS", ""),
                  "timing": "interleaved reps, per-mode min",
                  "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")},
        "smoke": smoke,
        "rows": rows,
    }
    path = SMOKE_PATH if smoke else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
