"""Beyond-paper: vmap vs shard_map round wall-time on the dryrun meshes.

Compares the single-process vmap runtime (core/algorithms.py) against the
distributed shard_map runtime (core/sharded.py) for one FedOSAA round, on the
512-host-device 2x16x16 dryrun mesh (and the single-pod 16x16). On emulated
host devices the sharded round is *slower* in wall-time — 512 thread-level
device emulations on a few cores — so ``derived`` here is the sharded/vmap
wall-time ratio, a dispatch+collective overhead measurement, not a speedup
claim; the roofline win only materializes on real pods where the K clients'
local epochs run on disjoint chips.

Standalone (needs the forced host device count BEFORE jax initializes, so it
is not part of benchmarks/run.py's MODULES):

  PYTHONPATH=src python -m benchmarks.ext_sharded_round
  PYTHONPATH=src python -m benchmarks.ext_sharded_round --full   # more rounds
"""
from __future__ import annotations

# MUST precede any jax import: the device count locks at first jax init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402

from repro.core import AlgoHParams, init_state, make_round_fn  # noqa: E402
from repro.core.sharded import make_sharded_round_fn, num_client_shards  # noqa: E402
from repro.data import make_binary_classification, partition   # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models.logreg import make_logreg_problem            # noqa: E402

from benchmarks.common import print_csv, save_results          # noqa: E402


def _time_round(fn, state, rounds: int) -> float:
    state, m = fn(state)                    # compile + warm up
    jax.block_until_ready(m.loss)
    t0 = time.perf_counter()
    for _ in range(rounds):
        state, m = fn(state)
    jax.block_until_ready(m.loss)
    return (time.perf_counter() - t0) / rounds


def run(quick: bool = True) -> list[dict]:
    rounds = 3 if quick else 10
    num_clients, n = (64, 2048) if quick else (64, 20_000)
    X, y = make_binary_classification("synthetic_small", n=n, seed=0)
    clients = partition(X, y, num_clients=num_clients, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    hp = AlgoHParams(eta=0.5, local_epochs=3)

    rows = []
    for algo in ("fedosaa_svrg", "fedosaa_scaffold"):
        state0 = init_state(prob, jax.random.PRNGKey(0), hp)
        t_vmap = _time_round(jax.jit(make_round_fn(algo, prob, hp)),
                             state0, rounds)
        for multi_pod in (False, True):
            mesh_tag = "2x16x16" if multi_pod else "16x16"
            if jax.device_count() < (512 if multi_pod else 256):
                print(f"# skip {algo}/{mesh_tag}: only "
                      f"{jax.device_count()} devices")
                continue
            mesh = make_production_mesh(multi_pod=multi_pod)
            t_shard = _time_round(
                jax.jit(make_sharded_round_fn(algo, prob, hp, mesh)),
                state0, rounds)
            rows.append({
                "name": f"ext_sharded_round/{algo}/{mesh_tag}",
                "us_per_call": 1e6 * t_shard,
                "derived": t_shard / t_vmap,     # host-emulation overhead ×
                "vmap_us_per_call": 1e6 * t_vmap,
                "client_shards": num_client_shards(mesh),
                "num_clients": num_clients,
                "rounds": rounds,
            })
    save_results("ext_sharded_round", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    print_csv(run(quick=not args.full))
