"""Figure 7: ill-conditioned problems (γ=1e-4, w8a-like). GIANT without line
search can diverge; FedOSAA without line search stays stable; GIANT+LS is
best but pays an extra communication round."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results


def run(quick: bool = True) -> list[dict]:
    n, k = (10_000, 16) if quick else (49_749, 16)
    rounds = 20 if quick else 40
    prob, wstar = logreg_setup("w8a", n=n, k=k, gamma=1e-4)
    rows = []
    specs = [
        ("fedosaa_svrg", AlgoHParams(eta=1.0, local_epochs=10), "no_ls"),
        ("fedsvrg", AlgoHParams(eta=1.0, local_epochs=10), "no_ls"),
        ("giant", AlgoHParams(local_epochs=10), "no_ls"),
        ("giant", AlgoHParams(local_epochs=10, line_search=True), "ls"),
        ("newton_gmres", AlgoHParams(local_epochs=10), "no_ls"),
    ]
    for algo, hp, tag in specs:
        rows.append(bench_algo(prob, wstar, algo, hp, rounds, f"fig7/{algo}/{tag}"))
    save_results("fig7_illcond", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
