"""Beyond-paper: straggler economics of the deadline gate — FedOSAA-SVRG
under heavy-tailed simulated latencies (FaultPlan.latency_*), barriered vs
deadline-gated (repro/robust/async_agg).

The question the benchmark answers: a synchronous round pays the SLOWEST
client's latency every round (the barrier), while the deadline-gated round
closes at ``AsyncConfig.deadline`` (extended in-graph only when fewer than
``min_arrivals`` latencies beat it) and folds the stragglers' buffered
updates into later rounds with staleness-discounted weight (1+s)^-alpha. The
gate trades rounds for wall-clock: it may need MORE rounds to a given
rel-error (stale folds are noisier than fresh barriered aggregates) but each
round is bounded by the deadline instead of the latency tail's max.

Wall-clock is SIMULATED, not measured: both runs execute the same compiled
math on the same container, so the honest comparison replays the fault
stream host-side (faults.realize is keyed by (seed, round, client id) — the
replay is exact) and charges the barriered run max_k latency_k(t) per round
and the gated run its effective deadline d_eff(t). d_eff depends only on the
latency draw and the min_arrivals order statistic, never on buffer ages, so
the replay needs no state.

The guard_history on/off pair is the measured AA-staleness decision the
tentpole left to the benchmark: with ``guard_history=True`` a stale-folded
round's AA history rows stay bit-frozen (the fold never enters recorded
residual history as a fresh secant); with False the stale fold writes
history like a fresh update. The committed rows record rounds-to-target for
both so the default (True) is a measurement, not a guess.

The run is float64 (same reason as ext_compression/ext_robustness: the
acceptance target is rel-error 1e-6, below the f32 fixed-point floor — and
f64 keeps the vmap/sharded AA Gram agreement tight enough to compare).

Acceptance (committed in results/ext_async.json, validated by
scripts/check_ext_async.py, smoke-gated in scripts/ci.sh):
  * the deadline-gated run reaches rel-error 1e-6 within 2x the barriered
    baseline's rounds,
  * while its simulated wall-clock-to-target is strictly below the
    barriered run's (the latency tail is what the barrier pays for),
  * an INACTIVE AsyncConfig is bitwise identical to no AsyncConfig at all
    on both runtimes (the gate compiles the byte-identical synchronous
    graph when off),
  * mixed latency+dropout gated runs are bit-deterministic across repeats,
    and the vmap/sharded arrival schedules are bit-identical.

  PYTHONPATH=src python -m benchmarks.ext_async            # quick
  PYTHONPATH=src python -m benchmarks.ext_async --full
  PYTHONPATH=src python -m benchmarks.ext_async --smoke    # CI gate
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AlgoHParams
from repro.robust import AsyncConfig, FaultPlan
from repro.robust.faults import realize

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

TARGET = 1e-6
ALGO = "fedosaa_svrg"
ROUND_MULTIPLE = 2.0     # gated rounds-to-target budget vs barriered

# heavy-tailed latency: lognormal sigma=1.5 → median 1.0, P99 ≈ 33; a
# deadline of 2.0 lets ~68% of clients land per round while the barrier
# pays the tail's max draw
LATENCY = dict(latency_dist="lognormal", latency_scale=1.0, latency_shape=1.5)
DEADLINE = 2.0


def _latency_plan(seed: int = 0, drop_rate: float = 0.0) -> FaultPlan:
    return FaultPlan(seed=seed, drop_rate=drop_rate, **LATENCY)


def _async_cfg(k: int, guard: bool = True) -> AsyncConfig:
    return AsyncConfig(deadline=DEADLINE, min_arrivals=max(2, k // 2),
                       staleness_alpha=0.5, guard_history=guard)


def _sim_walls(plan: FaultPlan, cfg: AsyncConfig, k: int,
               rounds: int) -> tuple[list[float], list[float]]:
    """Replay the keyed latency stream host-side: per-round (max latency,
    effective deadline). Exact — realize() is a pure function of
    (plan.seed, t, client id)."""
    barrier, gated = [], []
    m = min(cfg.min_arrivals, k) if cfg.min_arrivals > 0 else 0
    for t in range(rounds):
        lat = np.asarray(realize(plan, jnp.int32(t), k).latency, dtype=float)
        barrier.append(float(lat.max()))
        d = float(cfg.deadline)
        if m > 0:
            d = max(d, float(np.sort(lat)[m - 1]))
        gated.append(d)
    return barrier, gated


def _rounds_to(curve, t) -> int | None:
    curve = np.asarray(curve)
    hit = np.nonzero(curve < t)[0]
    return int(hit[0]) + 1 if len(hit) else None


def _row(prob, wstar, hp, cap, tag, faults=None, async_cfg=None,
         runtime="vmap") -> dict:
    r = bench_algo(prob, wstar, ALGO, hp, cap, tag, stop_rel_error=1e-8,
                   faults=faults, async_cfg=async_cfg, runtime=runtime)
    r["target"] = TARGET
    r["rounds_to_target"] = _rounds_to(r["rel_error_curve"], TARGET)
    r["finite"] = bool(np.isfinite(r["final_loss"]))
    return r


def _inactive_parity(prob, wstar, hp, runtime: str, cap: int = 6) -> bool:
    """AsyncConfig(deadline=0) must be bitwise = no AsyncConfig at all."""
    base = bench_algo(prob, wstar, ALGO, hp, cap, "parity/none",
                      runtime=runtime)
    off = bench_algo(prob, wstar, ALGO, hp, cap, "parity/inactive",
                     async_cfg=AsyncConfig(), runtime=runtime)
    a, b = (np.asarray(r["loss_curve"]) for r in (base, off))
    return len(a) == len(b) and bool(np.all(a == b))


def _determinism(prob, wstar, hp, faults, cfg, cap: int = 6) -> dict:
    """Mixed latency+dropout gated rounds: repeats bit-identical, and the
    vmap/sharded arrival schedules bit-identical."""
    runs = [bench_algo(prob, wstar, ALGO, hp, cap, "det", faults=faults,
                       async_cfg=cfg) for _ in range(2)]
    a, b = (np.asarray(r["loss_curve"]) for r in runs)
    repeat_ok = len(a) == len(b) and bool(np.all(a == b))
    sh = bench_algo(prob, wstar, ALGO, hp, cap, "det/sharded", faults=faults,
                    async_cfg=cfg, runtime="sharded")
    arr_v = np.asarray(runs[0]["arrivals_curve"])
    arr_s = np.asarray(sh["arrivals_curve"])
    n = min(len(arr_v), len(arr_s))
    sched_ok = bool(np.all(arr_v[:n] == arr_s[:n])) and bool(np.all(
        np.asarray(runs[0]["staleness_max_curve"])[:n]
        == np.asarray(sh["staleness_max_curve"])[:n]))
    lv = np.asarray(runs[0]["loss_curve"])[:n]
    ls = np.asarray(sh["loss_curve"])[:n]
    xrt = float(np.max(np.abs(lv - ls) / np.maximum(np.abs(lv), 1e-30)))
    return {"repeat_bit_identical": repeat_ok,
            "runtime_schedule_bit_identical": sched_ok,
            "runtime_loss_max_rel": xrt}


def _summary(rows, plan, cfg, k, parity_vmap, parity_sharded, det) -> dict:
    by = {r["name"]: r for r in rows}
    sync = by["ext_async/sync/latency"]
    gated = by["ext_async/gated/guard"]
    r_sync, r_gated = sync["rounds_to_target"], gated["rounds_to_target"]
    horizon = max(r_sync or 0, r_gated or 0, 1)
    barrier_w, gated_w = _sim_walls(plan, cfg, k, horizon)
    wall_sync = (sum(barrier_w[:r_sync]) if r_sync else None)
    wall_gated = (sum(gated_w[:r_gated]) if r_gated else None)
    return {
        "name": "ext_async/summary",
        "us_per_call": 0.0,
        "derived": gated["derived"],
        # acceptance: <= ROUND_MULTIPLE / True / True / True / True / True
        "gated_rounds_vs_barriered":
            (r_gated / r_sync if r_gated and r_sync else None),
        "gated_wall_below_barriered":
            (wall_gated < wall_sync
             if wall_gated is not None and wall_sync is not None else False),
        "inactive_parity_vmap_bit_identical": parity_vmap,
        "inactive_parity_sharded_bit_identical": parity_sharded,
        **det,
        "barriered_rounds_to_target": r_sync,
        "gated_rounds_to_target": r_gated,
        "noguard_rounds_to_target":
            by["ext_async/gated/noguard"]["rounds_to_target"],
        "barriered_sim_wall_to_target": wall_sync,
        "gated_sim_wall_to_target": wall_gated,
        "deadline": cfg.deadline,
        "min_arrivals": cfg.min_arrivals,
        "staleness_alpha": cfg.staleness_alpha,
        "round_multiple_budget": ROUND_MULTIPLE,
    }


def run(quick: bool = True) -> list[dict]:
    n, k = (10_000, 10) if quick else (58_100, 100)
    cap = 60 if quick else 80
    was_x64 = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        prob, wstar = logreg_setup("covtype", n=n, k=k, dtype="float64")
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        plan = _latency_plan()
        cfg = _async_cfg(k)
        rows = [
            _row(prob, wstar, hp, cap, "ext_async/sync/clean"),
            # the barrier waits for every client: latency changes the bill,
            # not the math — convergence matches clean
            _row(prob, wstar, hp, cap, "ext_async/sync/latency", faults=plan),
            _row(prob, wstar, hp, cap, "ext_async/gated/guard", faults=plan,
                 async_cfg=cfg),
            # the AA-staleness measurement: stale folds writing history
            _row(prob, wstar, hp, cap, "ext_async/gated/noguard", faults=plan,
                 async_cfg=_async_cfg(k, guard=False)),
        ]
        parity_v = _inactive_parity(prob, wstar, hp, "vmap")
        parity_s = _inactive_parity(prob, wstar, hp, "sharded")
        det = _determinism(prob, wstar, hp,
                           _latency_plan(seed=3, drop_rate=0.15), cfg)
        rows.append(_summary(rows, plan, cfg, k, parity_v, parity_s, det))
    finally:
        jax.config.update("jax_enable_x64", was_x64)
    save_results("ext_async", rows)
    return rows


def smoke() -> int:
    """Tiny CI gate (seconds): the gated run converges finitely under a
    heavy-tailed plan, the inactive gate is bitwise-off on both runtimes,
    and a mixed latency+dropout gated run is bit-deterministic across
    repeats and runtimes. Writes nothing — the committed
    results/ext_async.json is validated by scripts/check_ext_async.py."""
    prob, wstar = logreg_setup("covtype", n=2_000, k=8)
    hp = AlgoHParams(eta=1.0, local_epochs=5)
    plan = _latency_plan()
    cfg = _async_cfg(8)
    failures = []
    r = bench_algo(prob, wstar, ALGO, hp, 8, "smoke/gated", faults=plan,
                   async_cfg=cfg)
    print_csv([r])
    if not np.isfinite(r["final_loss"]):
        failures.append("gated run went non-finite")
    if r["loss_curve"][-1] >= r["loss_curve"][0]:
        failures.append("gated run is not decreasing the loss")
    if max(r["arrivals_curve"]) <= 0:
        failures.append("no round recorded any arrivals")
    if not _inactive_parity(prob, wstar, hp, "vmap", cap=4):
        failures.append("inactive AsyncConfig is not bitwise-off (vmap)")
    if not _inactive_parity(prob, wstar, hp, "sharded", cap=4):
        failures.append("inactive AsyncConfig is not bitwise-off (sharded)")
    det = _determinism(prob, wstar, hp,
                       _latency_plan(seed=3, drop_rate=0.2), cfg, cap=4)
    if not det["repeat_bit_identical"]:
        failures.append("repeated gated runs are not bit-identical")
    if not det["runtime_schedule_bit_identical"]:
        failures.append("vmap/sharded arrival schedules differ")
    for f in failures:
        print(f"SMOKE FAIL: {f}")
    print("ext_async smoke:", "FAIL" if failures else "OK")
    return 1 if failures else 0


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    print_csv(run(quick="--full" not in sys.argv))
