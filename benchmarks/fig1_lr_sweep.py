"""Figure 1(a,d): vary local learning rate η — FedOSAA-SVRG vs FedSVRG vs
Newton-GMRES, and FedOSAA-SCAFFOLD vs SCAFFOLD (covtype-like, K clients)."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

ETAS = (0.01, 0.1, 1.0, 2.0)


def run(quick: bool = True) -> list[dict]:
    n, k = (20_000, 20) if quick else (58_100, 100)
    rounds = 20 if quick else 40
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    rows = []
    for eta in ETAS:
        hp = AlgoHParams(eta=eta, local_epochs=10)
        for algo in ("fedsvrg", "fedosaa_svrg", "fedosaa_scaffold", "scaffold"):
            rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                   f"fig1_lr/{algo}/eta{eta}"))
        # Newton-GMRES has no η; bench once per sweep point for reference cost
        if eta == 1.0:
            rows.append(bench_algo(prob, wstar, "newton_gmres",
                                   AlgoHParams(local_epochs=10), rounds,
                                   "fig1_lr/newton_gmres/ref"))
    save_results("fig1_lr_sweep", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
