"""Figures 4 & 5: sweeps over regularization γ (conditioning) and number of
clients K, on both covtype-like and w8a-like data."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

ALGOS = ("fedsvrg", "fedosaa_svrg", "giant", "newton_gmres")


def run(quick: bool = True) -> list[dict]:
    rounds = 15 if quick else 40
    rows = []
    # γ sweep at fixed K (paper fig 4 row 2 / fig 5 row 1)
    for dataset, n, k in (("covtype", 20_000 if quick else 58_100, 10),
                          ("w8a", 10_000 if quick else 49_749, 16)):
        for gamma in (1e-2, 1e-3):
            prob, wstar = logreg_setup(dataset, n=n, k=k, gamma=gamma)
            for algo in ALGOS:
                hp = AlgoHParams(eta=1.0, local_epochs=10)
                rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                       f"fig45/{dataset}/gamma{gamma}/{algo}"))
    # K sweep at fixed γ (paper fig 4 row 1)
    for k in (10, 50) if quick else (10, 100):
        prob, wstar = logreg_setup("covtype", n=20_000 if quick else 58_100, k=k)
        for algo in ALGOS:
            hp = AlgoHParams(eta=1.0, local_epochs=10)
            rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                   f"fig45/covtype/K{k}/{algo}"))
    save_results("fig45_gamma_clients", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
