"""Figure 1(b,e): vary local epochs L. Key claim: FedOSAA-SVRG with L=3 is
comparable to FedSVRG with L=30 (10× local-computation saving)."""
from __future__ import annotations

from repro.core import AlgoHParams

from benchmarks.common import bench_algo, logreg_setup, print_csv, save_results

EPOCHS = (3, 10, 30)


def run(quick: bool = True) -> list[dict]:
    n, k = (20_000, 20) if quick else (58_100, 100)
    rounds = 20 if quick else 40
    prob, wstar = logreg_setup("covtype", n=n, k=k)
    rows = []
    for L in EPOCHS:
        hp = AlgoHParams(eta=1.0, local_epochs=L)
        for algo in ("fedsvrg", "fedosaa_svrg", "scaffold", "fedosaa_scaffold"):
            rows.append(bench_algo(prob, wstar, algo, hp, rounds,
                                   f"fig1_epochs/{algo}/L{L}"))
    save_results("fig1_epochs_sweep", rows)
    return rows


if __name__ == "__main__":
    print_csv(run())
