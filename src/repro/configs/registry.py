"""Registry of assigned architectures and benchmark input shapes."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig

_MODULES = {
    "smollm-135m": "smollm_135m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "internvl2-76b": "internvl2_76b",
    "mamba2-2.7b": "mamba2_2p7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-4b": "qwen3_4b",
    "zamba2-7b": "zamba2_7b",
    "granite-20b": "granite_20b",
    "minicpm-2b": "minicpm_2b",
    "musicgen-medium": "musicgen_medium",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
