"""Zamba2-7B — Mamba2 backbone with a weight-TIED shared attention+MLP block
applied every 6th layer. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    shared_attn_period=6,
    source="arXiv:2411.15242",
)
