"""Config registry: all assigned architectures + paper-experiment configs."""
from repro.configs.base import ArchConfig  # noqa: F401
from repro.configs.registry import ARCHS, INPUT_SHAPES, get_arch, get_shape  # noqa: F401
