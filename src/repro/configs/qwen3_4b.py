"""Qwen3-4B — dense with QK-RMSNorm and GQA. head_dim=128 (decoupled from
d_model/num_heads as in the Qwen3 family). [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128, qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)
