"""Llama-4 Scout 17B-active / 16 experts — MoE with top-1 routing, early
fusion. Backbone dims per model card. [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    num_experts=16, experts_per_token=1, moe_d_ff=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
