"""Architecture config system.

One ``ArchConfig`` per assigned architecture (see configs/<id>.py, each citing
its source), selectable via ``--arch``. ``reduced()`` produces the smoke-test
variant (≤2 layers, d_model ≤ 512, ≤4 experts) used by per-arch CPU tests;
``padded(model_shards)`` returns the tensor-parallel-ready variant (heads and
vocab rounded up for clean sharding — padded head outputs are exact no-ops at
init because their o_proj rows are zero; padded vocab logits are masked in the
loss).
"""
from __future__ import annotations

import dataclasses
import math

FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid", "audio")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int            # query heads; 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int                 # dense FFN dim (0 for pure ssm)
    vocab_size: int
    head_dim: int = 0         # 0 => d_model // num_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0         # per-expert FFN dim
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (zamba2-style shared attention block) ---
    shared_attn_period: int = 0   # apply the weight-tied attn block every Nth layer
    # --- attention variant ---
    sliding_window: int = 0       # 0 = full causal; >0 = window size
    rope_theta: float = 10_000.0
    # --- modality frontend stub (vlm/audio): embeddings arrive precomputed ---
    frontend_tokens: int = 0      # patches / audio frames per sample
    # --- serving options ---
    kv_quant: bool = False        # int8 KV cache (PerfH2 iter 2; default off = paper-faithful numerics)
    # --- bookkeeping ---
    dtype: str = "bfloat16"
    source: str = ""
    # --- padding applied? (set by .padded()) ---
    padded_vocab: int = 0
    padded_heads: int = 0
    padded_kv_heads: int = 0
    padded_experts: int = 0

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def eff_vocab(self) -> int:
        return self.padded_vocab or self.vocab_size

    @property
    def eff_heads(self) -> int:
        return self.padded_heads or self.num_heads

    @property
    def eff_kv_heads(self) -> int:
        return self.padded_kv_heads or self.num_kv_heads

    @property
    def eff_experts(self) -> int:
        return self.padded_experts or self.num_experts

    @property
    def d_inner(self) -> int:
        """SSM inner dim."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_decoder(self) -> bool:
        return True   # every assigned arch decodes (backbones for vlm/audio)

    def param_count(self) -> int:
        """Analytic parameter count (true, unpadded dims) — used for the
        6·N·D model-FLOPs roofline term."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = self.vocab_size * d                     # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size                # lm head
        if self.family in ("ssm",):
            per = self._ssm_layer_params()
            n += L * per
        elif self.family == "hybrid":
            n_shared = self.num_layers // max(self.shared_attn_period, 1)
            n_mamba = L - n_shared
            n += n_mamba * self._ssm_layer_params()
            n += self._attn_layer_params() + 2 * d * self.d_ff + d * self.d_ff  # one shared block
        else:
            attn = self._attn_layer_params()
            if self.family == "moe" or self.num_experts:
                mlp = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
            else:
                mlp = 3 * d * self.d_ff
            n += L * (attn + mlp)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k) for MODEL_FLOPS = 6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        attn = self._attn_layer_params()
        mlp = self.experts_per_token * 3 * d * self.moe_d_ff + d * self.num_experts
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return n + L * (attn + mlp)

    def _attn_layer_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        return d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d

    def _ssm_layer_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        in_proj = d * (2 * di + 2 * st + nh)   # x, z, B, C, dt
        out_proj = di * d
        conv = (di + 2 * st) * self.ssm_conv_width
        return in_proj + out_proj + conv + 2 * nh  # + A_log, D

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/block structure, toy size."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(1, heads // 2)) if self.num_kv_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv or heads,
            head_dim=64 if self.num_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=64 if self.ssm_state else 256,
            shared_attn_period=2 if self.shared_attn_period else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            dtype="float32",
            padded_vocab=0, padded_heads=0, padded_kv_heads=0,
        )

    def padded(self, model_shards: int) -> "ArchConfig":
        """Tensor-parallel-ready variant for an m-way 'model' axis."""
        if model_shards <= 1:
            return self
        pv = _round_up(self.vocab_size, model_shards * 128)
        ph, pkv = self.num_heads, self.num_kv_heads
        if self.num_heads:
            ph = _round_up(self.num_heads, model_shards)
            if self.num_kv_heads > 1 and self.num_kv_heads % model_shards != 0:
                # pad kv heads so the KV cache can shard over 'model' — at
                # 76B/32k-decode scale a replicated KV cache cannot fit HBM.
                # MQA (kv=1) stays replicated (standard TP-MQA; padding would
                # multiply kv params 16×). The GQA q->kv mapping uses TRUE
                # head counts (gather), so padded kv heads are never read.
                pkv = _round_up(self.num_kv_heads, model_shards)
        pe = self.num_experts
        if self.num_experts and self.num_experts % model_shards != 0:
            # §Perf H1: pad experts up to the model axis so the MoE runs
            # expert-parallel (all-to-all dispatch) instead of sharding the
            # tiny per-expert FFN dim (which costs an all-reduce of the full
            # [E,C,d] buffer per layer). Dummy experts are masked out of the
            # router softmax and are never routed to.
            pe = _round_up(self.num_experts, model_shards)
        return dataclasses.replace(
            self, padded_vocab=pv, padded_heads=ph, padded_kv_heads=pkv,
            padded_experts=pe,
        )

    def validate(self) -> None:
        assert self.family in FAMILIES, self.family
        if self.family in ("dense", "vlm", "audio"):
            assert self.num_heads > 0 and self.d_ff > 0
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0 and self.moe_d_ff > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.family == "hybrid":
            assert self.shared_attn_period > 0 and self.num_heads > 0
        if self.num_heads:
            pass  # head_dim may differ from d_model//heads (qwen3)
