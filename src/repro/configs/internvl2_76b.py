"""InternVL2-76B — InternViT vision encoder + InternLM2 LLM. We implement the
LANGUAGE BACKBONE (80L/8192/64H GQA-8); the ViT frontend is stubbed per spec:
input_specs() supplies precomputed patch embeddings. [arXiv:2404.16821]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    frontend_tokens=1024,     # ViT patch embeddings per image
    source="arXiv:2404.16821",
)
