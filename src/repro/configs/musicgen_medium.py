"""MusicGen-medium — decoder-only transformer over EnCodec tokens. The
EnCodec conv codec is stubbed per spec: input_specs() supplies precomputed
frame embeddings; the decoder predicts codebook tokens (vocab 2048).
[arXiv:2306.05284]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    frontend_tokens=512,     # EnCodec frames per conditioning segment
    source="arXiv:2306.05284",
)
