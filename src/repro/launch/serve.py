"""Batched serving runtime: slot-based continuous batching over the decoder.

A fixed pool of B slots share one KV-cache/SSM-state buffer; requests are
admitted into free slots (prefill via teacher-forced decode steps of the
prompt), generate until EOS/max_tokens, and release their slot — the
decode step always runs the full [B, 1] batch, so XLA compiles exactly one
serve_step regardless of request mix (the shape discipline a TPU serving
deployment needs).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.decoder import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [P] int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class SlotServer:
    """B-slot decode server. One compiled decode_step serves everything."""

    def __init__(self, model, params, batch_slots: int, cache_len: int,
                 eos_id: int | None = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.caches = jax.jit(
            lambda: model.init_caches(batch_slots, cache_len)
        )()
        self.decode = jax.jit(model.decode_step)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.slot_pending: list[list[int]] = [[] for _ in range(batch_slots)]
        self.steps = 0

    def admit(self, req: Request) -> bool:
        for s in range(self.B):
            if self.slot_req[s] is None:
                # slot reuse note: positions restart at 0 and stale cache
                # entries beyond the new request are masked by position
                # bookkeeping ONLY if the cache is re-zeroed; we reset pos
                # entries by writing fresh tokens over the prompt range and
                # relying on pos>=0 masking for untouched slots of longer
                # previous occupants — for strict isolation, reset the lane:
                self._reset_slot(s)
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_pending[s] = list(req.prompt)
                return True
        return False

    def _reset_slot(self, s: int) -> None:
        def reset(leaf):
            if leaf.ndim >= 2 and leaf.shape[1] == self.B:   # [L, B, ...]
                return leaf.at[:, s].set(
                    -1 if leaf.dtype == jnp.int32 and leaf.ndim == 3 else 0
                )
            return leaf
        self.caches = jax.tree.map(reset, self.caches)

    def step(self) -> None:
        """One global decode step: each active slot consumes its next pending
        (prompt) token or its last generated token."""
        tokens = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B, 1), np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[s]:
                tokens[s, 0] = self.slot_pending[s].pop(0)
            else:
                tokens[s, 0] = req.out[-1]
            pos[s, 0] = self.slot_pos[s]
        logits, self.caches = self.decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(pos)
        )
        nxt = np.asarray(
            jnp.argmax(logits[:, : self.model.cfg.vocab_size], axis=-1),
            np.int32,
        )
        self.steps += 1
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.slot_pos[s] += 1
            if self.slot_pending[s]:
                continue                      # still prefilling the prompt
            req.out.append(int(nxt[s]))
            hit_eos = self.eos_id is not None and req.out[-1] == self.eos_id
            if len(req.out) >= req.max_new_tokens or hit_eos or \
                    self.slot_pos[s] >= self.cache_len:
                req.done = True
                self.slot_req[s] = None

    def run(self, requests: list[Request]) -> dict:
        queue = list(requests)
        t0 = time.time()
        while queue or any(r is not None for r in self.slot_req):
            while queue and self.admit(queue[0]):
                queue.pop(0)
            self.step()
        dt = time.time() - t0
        toks = sum(len(r.out) for r in requests)
        return {"wall_s": dt, "tokens": toks, "steps": self.steps,
                "tok_per_s": toks / max(dt, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                args.new_tokens)
        for i in range(args.requests)
    ]
    srv = SlotServer(model, params,
                     batch_slots=args.slots,
                     cache_len=args.prompt_len + args.new_tokens + 1)
    stats = srv.run(reqs)
    print(f"served {len(reqs)} requests / {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s over {stats['steps']} steps "
          f"({stats['tok_per_s']:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
