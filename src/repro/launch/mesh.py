"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (smoke tests must see 1 CPU device; only dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16×16 = 256 chips single-pod; 2×16×16 = 512 two-pod.

    FL mapping: clients live on ("pod","data"); tensor parallelism on
    "model". The pod axis is the slowest (DCI links between pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths that still exercise pjit."""
    return jax.make_mesh((1, 1), ("data", "model"))
