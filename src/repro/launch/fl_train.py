"""Federated LM training driver: FedOSAA (or any core algorithm) over an
assigned architecture.

  PYTHONPATH=src python -m repro.launch.fl_train --arch smollm-135m --reduced \
      --algo fedosaa_svrg --rounds 20 --clients 4

``--reduced`` uses the smoke-scale variant (CPU-runnable); without it the
full config is built (TPU-scale — on this CPU container use the dry-run
instead). Compares against --baseline algo when given and writes a CSV.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import AlgoHParams, run_federated
from repro.core.lm import make_lm_clients, make_lm_problem
from repro.data import make_lm_tokens
from repro.models.decoder import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algo", default="fedosaa_svrg")
    ap.add_argument("--baseline", default="")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--docs-per-client", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eta", type=float, default=0.3)
    ap.add_argument("--local-epochs", type=int, default=5)
    ap.add_argument("--damping", type=float, default=1.0)
    ap.add_argument("--clip-rtol", type=float, default=0.0,
                    help="residual-clipped AA (AAConfig.clip_rtol): drop any "
                         "history column whose residual norm exceeds the "
                         "client's median by more than 1/clip_rtol before the "
                         "Gram solve — the byzantine-history defense "
                         "(repro/robust). 0 = screen off (bit-identical to "
                         "the unscreened step)")
    # -- fault injection (repro/robust) ----------------------------------
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="per-round per-client probability the uplink never "
                         "lands (FaultPlan.drop_rate): survivors' weights "
                         "renormalize, the dropped client's state rows stay "
                         "bit-frozen")
    ap.add_argument("--stale-rate", type=float, default=0.0,
                    help="per-round per-client probability the upload is "
                         "computed against an aged anchor w^{t-s} "
                         "(FaultPlan.stale_rate); consecutive draws compound")
    ap.add_argument("--byz-clients", type=int, default=0,
                    help="number of (lowest-id) persistently byzantine "
                         "clients (FaultPlan.byz_clients)")
    ap.add_argument("--byz-mode", choices=("sign_flip", "noise", "history"),
                    default="sign_flip",
                    help="byzantine perturbation: sign_flip/noise corrupt "
                         "the uplink, history poisons the recorded AA "
                         "column (the attack --clip-rtol screens)")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="client-side Gaussian DP noise scale, applied "
                         "post-codec so error feedback tracks the noised "
                         "wire (FaultPlan.dp_sigma)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="FaultPlan.seed: keys the whole injection stream — "
                         "equal seeds inject bit-identical rounds across "
                         "runs and runtimes")
    ap.add_argument("--latency-scale", type=float, default=0.0,
                    help="simulate per-client compute latency "
                         "(FaultPlan.latency_scale; 0 = off) — feeds the "
                         "--deadline gate")
    ap.add_argument("--latency-shape", type=float, default=1.0,
                    help="latency tail heaviness (lognormal sigma / pareto "
                         "index; FaultPlan.latency_shape)")
    ap.add_argument("--latency-dist", choices=("lognormal", "pareto"),
                    default="lognormal")
    # -- deadline-gated aggregation (repro/robust/async_agg) -------------
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="deadline-gate the round close (AsyncConfig."
                         "deadline): only clients whose simulated latency "
                         "beats the deadline land; late updates buffer and "
                         "fold in later with staleness-discounted weight. "
                         "0 = the barriered (synchronous) round")
    ap.add_argument("--min-arrivals", type=int, default=0,
                    help="extend the deadline in-graph whenever fewer "
                         "latencies beat it (AsyncConfig.min_arrivals)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="staleness discount exponent: a fold aged s rounds "
                         "weighs (1+s)^-alpha (AsyncConfig.staleness_alpha)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients active per round: <1.0 samples "
                         "a ⌈pK⌉-client cohort each round (weighted, without "
                         "replacement; weights renormalize) — the round then "
                         "computes O(C·d) over the O(K·d) client store")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="explicit per-round cohort size C (overrides "
                         "--participation): each round gathers C sampled "
                         "clients' data + state rows, computes on [C, ...] "
                         "tensors only, and scatters updates back — "
                         "non-sampled clients' state stays bit-frozen. "
                         "0 = derive from --participation")
    ap.add_argument("--comm-codec", default="identity",
                    help="wire-compression channel spec (repro/comm): "
                         "identity | bf16 | int8[:chunk] | topk[:ratio], "
                         "optional +ef/+noef and /<downlink-codec> — e.g. "
                         "int8, topk:0.05, bf16/bf16")
    ap.add_argument("--runtime", choices=("vmap", "sharded"), default="vmap",
                    help="'sharded' shard_maps the client fan-out over the "
                         "('pod','data') mesh axes (core/sharded.py)")
    ap.add_argument("--round-chunk", type=int, default=0,
                    help="compile this many rounds into ONE donated lax.scan "
                         "jit (core/engine.py): metrics stack on device and "
                         "the host syncs once per chunk. 0 = the per-round "
                         "loop")
    ap.add_argument("--aa-impl", choices=("auto", "tree", "pallas"),
                    default="auto",
                    help="AA-step implementation (AlgoHParams.aa_impl): "
                         "'pallas' ravels each client's leaves into flat "
                         "buffers and runs the fused single-pass kernels "
                         "(kernels/anderson); 'auto' = pallas on TPU, tree "
                         "elsewhere; the sharded runtime always uses tree")
    ap.add_argument("--local-impl", choices=("auto", "tree", "pallas"),
                    default="auto",
                    help="local-trajectory implementation "
                         "(AlgoHParams.local_impl): 'pallas' runs the fused "
                         "dual-gradient kernels (kernels/local_update) — "
                         "linear-design models only, so LM architectures "
                         "fall back to the autodiff path; 'auto' = pallas "
                         "on TPU where eligible; sharded always uses tree")
    ap.add_argument("--multi-pod", action="store_true",
                    help="with --runtime sharded: use the 2x16x16 two-pod "
                         "mesh instead of the single-pod 16x16 (requires "
                         "enough devices, e.g. the dryrun host-device env)")
    ap.add_argument("--out", default="")
    # -- preemption-tolerant checkpointing (repro/checkpoint) ------------
    ap.add_argument("--checkpoint-dir", default="",
                    help="checkpoint the full ServerState under this "
                         "directory (atomic per-shard saves with a manifest "
                         "commit marker — checkpoint/sharded_ckpt.py). "
                         "Under --round-chunk the save dispatches from the "
                         "chunk-boundary sync to a background thread and "
                         "overlaps the next chunk's compute")
    ap.add_argument("--checkpoint-every", type=int, default=10,
                    help="rounds between saves (saves land at the first "
                         "chunk boundary at/after each multiple)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="retention: GC committed checkpoints beyond the "
                         "newest N (0 = keep all)")
    ap.add_argument("--resume", default="none",
                    help="'auto': restore the newest COMPLETE checkpoint "
                         "under --checkpoint-dir (torn/partial saves are "
                         "skipped) and continue with contiguous round "
                         "numbering; 'none': fresh start; otherwise a path "
                         "to one ckpt_* directory. Resume REFUSES a "
                         "checkpoint whose manifest config (algo/runtime/"
                         "channel/cohort/faults/async) mismatches this run")
    ap.add_argument("--checkpoint-sync", action="store_true",
                    help="save inline at the boundary instead of on the "
                         "background thread (debugging/benchmark baseline)")
    ap.add_argument("--inject-kill-save", type=int, default=0, metavar="N",
                    help="crash-injection harness: hard-exit the process "
                         "(exit code 43, robust/fs_faults.KILL_EXIT_CODE) "
                         "mid-write during the N-th checkpoint save, before "
                         "its commit rename — the kill-resume recovery smoke "
                         "(scripts/kill_resume_smoke.py). 0 = off")
    # -- telemetry (repro/obs) -------------------------------------------
    ap.add_argument("--metrics-out", default="",
                    help="stream per-round telemetry rows to this JSONL file "
                         "(versioned schema — obs/sinks.py; validate with "
                         "scripts/check_metrics_jsonl.py). Drained at chunk "
                         "boundaries under --round-chunk, per round "
                         "otherwise; attaching it never changes the computed "
                         "rounds")
    ap.add_argument("--metrics-stdout", type=int, default=0, metavar="N",
                    help="print every N-th telemetry row to stdout (0 = off)")
    ap.add_argument("--no-alarms", action="store_true",
                    help="disable the default health monitors (non-finite "
                         "loss, AA Gram conditioning blowup, AA column "
                         "collapse, rel-error plateau — obs/alarms.py); they "
                         "are attached whenever any metrics sink is")
    ap.add_argument("--trace-rounds", type=int, default=0, metavar="N",
                    help="capture a jax.profiler trace window covering N "
                         "rounds starting at --trace-start (aligned outward "
                         "to chunk boundaries under --round-chunk); named "
                         "scopes attribute time to the round phases")
    ap.add_argument("--trace-start", type=int, default=0,
                    help="first round of the --trace-rounds window")
    ap.add_argument("--trace-dir", default="",
                    help="profiler trace output dir (default "
                         "<--out dir or .>/trace)")
    ap.add_argument("--trace-trigger", default="",
                    help="arm on-demand tracing: touching this file while "
                         "the run is in flight traces the next chunk (the "
                         "file is consumed per window)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    toks = make_lm_tokens(
        args.clients * args.docs_per_client, args.seq_len, cfg.vocab_size
    )
    clients = make_lm_clients(toks, args.clients)
    problem = make_lm_problem(model, clients)

    from repro.comm import make_channel
    from repro.core.anderson import AAConfig
    hp = AlgoHParams(eta=args.eta, local_epochs=args.local_epochs,
                     participation=args.participation,
                     cohort_size=args.cohort_size or None,
                     aa=AAConfig(damping=args.damping, tikhonov=1e-8,
                                 clip_rtol=args.clip_rtol),
                     aa_impl=args.aa_impl, local_impl=args.local_impl)
    channel = make_channel(args.comm_codec)
    chunk = args.round_chunk if args.round_chunk > 0 else None

    from repro.robust import AsyncConfig, FaultPlan
    faults = FaultPlan(
        seed=args.fault_seed, drop_rate=args.drop_rate,
        stale_rate=args.stale_rate, byz_clients=args.byz_clients,
        byz_mode=args.byz_mode, dp_sigma=args.dp_sigma,
        latency_dist=args.latency_dist, latency_scale=args.latency_scale,
        latency_shape=args.latency_shape)
    faults = faults if faults.active else None
    async_cfg = AsyncConfig(deadline=args.deadline,
                            min_arrivals=args.min_arrivals,
                            staleness_alpha=args.staleness_alpha)
    async_cfg = async_cfg if async_cfg.active else None
    if async_cfg is not None and (faults is None
                                  or not faults.simulates_latency):
        print("warning: --deadline without --latency-scale gates on all-zero "
              "latencies (every client on time)")

    ckpt_policy = None
    ckpt_fs = None
    resume = args.resume if args.resume != "none" else None
    if args.checkpoint_dir:
        from repro.checkpoint import CheckpointPolicy

        ckpt_policy = CheckpointPolicy(
            directory=args.checkpoint_dir, every=args.checkpoint_every,
            keep=args.checkpoint_keep,
            mode="sync" if args.checkpoint_sync else "async")
        if args.inject_kill_save > 0:
            from repro.robust.fs_faults import FaultyFs, FSFaultPlan

            ckpt_fs = FaultyFs(FSFaultPlan(
                kill_at_save=args.inject_kill_save, kill_after_writes=1,
                kill_hard=True))
    elif resume == "auto":
        ap.error("--resume auto needs --checkpoint-dir")

    mesh = None
    if args.runtime == "sharded":
        from repro.core.sharded import num_client_shards
        from repro.launch.mesh import make_host_mesh, make_production_mesh

        needed = 512 if args.multi_pod else 256
        mesh = (make_production_mesh(multi_pod=args.multi_pod)
                if jax.device_count() >= needed else make_host_mesh())
        shards = num_client_shards(mesh)
        if args.clients % shards:
            ap.error(
                f"--clients {args.clients} must divide over the {shards} "
                f"client shards of the {dict(mesh.shape)} mesh; use "
                f"--clients {shards} or a multiple"
            )
        print(f"sharded runtime on mesh {dict(mesh.shape)}")

    def build_sinks(algo: str):
        """Per-algo telemetry sinks + trace capture (repro/obs); fresh per
        run so each algo gets its own JSONL file and alarm state."""
        from repro.obs import (AlarmMonitor, JsonlSink, StdoutSink,
                               TraceCapture, TraceConfig)

        sinks = []
        if args.metrics_out:
            base, ext = os.path.splitext(args.metrics_out)
            path = (args.metrics_out if len(algos) == 1
                    else f"{base}.{algo}{ext or '.jsonl'}")
            sinks.append(JsonlSink(path))
        if args.metrics_stdout:
            sinks.append(StdoutSink(every=args.metrics_stdout))
        if sinks and not args.no_alarms:
            sinks.append(AlarmMonitor())
        tc = None
        if args.trace_rounds > 0 or args.trace_trigger:
            trace_dir = args.trace_dir or os.path.join(
                os.path.dirname(args.out) or ".", "trace")
            tc = TraceCapture(TraceConfig(
                trace_dir=trace_dir, start_round=args.trace_start,
                num_rounds=args.trace_rounds,
                trigger_file=args.trace_trigger or None))
        return sinks, tc

    results = {}
    algos = [args.algo] + ([args.baseline] if args.baseline else [])
    for algo in algos:
        sinks, trace_capture = build_sinks(algo)
        pol = ckpt_policy
        if pol is not None and len(algos) > 1:
            # per-algo subdir: the manifests carry per-algo config
            # fingerprints, so sharing one directory would make resume
            # refuse the second algo's checkpoints
            import dataclasses as _dc

            pol = _dc.replace(pol,
                              directory=os.path.join(pol.directory, algo))
        t0 = time.time()
        h = run_federated(problem, algo, hp, args.rounds,
                          runtime=args.runtime, mesh=mesh, channel=channel,
                          chunk=chunk, sinks=sinks,
                          trace_capture=trace_capture, faults=faults,
                          async_cfg=async_cfg,
                          checkpoint=pol, resume=resume,
                          checkpoint_fs=ckpt_fs)
        results[algo] = {
            "loss_curve": [float(v) for v in h.loss],
            "grad_norm_curve": [float(v) for v in h.grad_norm],
            "gram_cond_curve": [float(v) for v in h.gram_cond_max],
            "comm_bytes": float(h.comm_bytes[-1]),
            "channel": h.channel,
            "wall_s": time.time() - t0,
            # fault/async parameters travel with the artifact so a result
            # file is self-describing about what was injected
            "faults": (None if faults is None else {
                "seed": faults.seed, "drop_rate": faults.drop_rate,
                "stale_rate": faults.stale_rate,
                "byz_clients": faults.byz_clients,
                "byz_mode": faults.byz_mode, "dp_sigma": faults.dp_sigma,
                "latency_dist": faults.latency_dist,
                "latency_scale": faults.latency_scale,
                "latency_shape": faults.latency_shape,
            }),
            "async": (None if async_cfg is None else {
                "deadline": async_cfg.deadline,
                "min_arrivals": async_cfg.min_arrivals,
                "staleness_alpha": async_cfg.staleness_alpha,
                "arrivals_curve": [float(v) for v in h.arrivals],
                "staleness_max_curve": [float(v) for v in h.staleness_max],
            }),
        }
        print(f"{algo}: loss {h.loss[0]:.4f} -> {h.loss[-1]:.4f} "
              f"|g| {h.grad_norm[-1]:.2e} "
              f"wire {h.comm_bytes[-1]/2**20:.2f}MiB[{h.channel}] "
              f"({results[algo]['wall_s']:.0f}s)")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
