"""Centralized LM trainer (the non-federated baseline substrate): AdamW/WSD,
gradient clipping, checkpointing, optional mesh sharding.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 100 --batch 4 --seq-len 256 --schedule wsd
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_arch
from repro.data import make_lm_tokens
from repro.models.decoder import build_model
from repro.optim import adamw, clip_by_global_norm, constant, cosine, sgd, wsd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["constant", "cosine", "wsd"])
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    sched = {
        "constant": lambda: constant(args.lr),
        "cosine": lambda: cosine(args.lr, args.steps, warmup=args.steps // 20),
        "wsd": lambda: wsd(args.lr, args.steps),
    }[args.schedule]()
    opt = adamw(sched) if args.optimizer == "adamw" else sgd(sched, momentum=0.9)
    opt_state = opt.init(params)

    toks = make_lm_tokens(args.batch * 64, args.seq_len, cfg.vocab_size)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads = clip_by_global_norm(grads, args.clip)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    t0 = time.time()
    for i in range(args.steps):
        idx = (np.arange(args.batch) + i * args.batch) % toks.shape[0]
        batch = {"tokens": jnp.asarray(toks[idx])}
        params, opt_state, loss = step(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(loss):.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
