"""Step functions lowered by the dry-run and used by the real drivers.

``train_step`` is the FedOSAA *local* step: SVRG-corrected gradient descent
(the workhorse of Algorithm 1 lines 10–14) — forward, backward, correction
add, SGD update. The Anderson step operates on the parameter pytree once per
L local steps and is lowered separately (``aa_step``) so its sharding and
collective footprint are visible in their own right.

``serve_step`` / ``prefill_step`` are the inference paths for the decode /
prefill input shapes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.anderson import AAConfig, multisecant_update

Pytree = Any


def make_train_step(model, eta: float = 1e-2):
    def train_step(params, batch, correction):
        """One SVRG-corrected local GD step (Alg. 1 line 12–13).

        correction = ∇f(w^t) − ∇f_k(w^t) (precomputed pytree); the residual
        r = ∇f_k(w;ζ) + correction is also returned for the AA history.
        """
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        r = jax.tree.map(lambda g, c: g + c.astype(g.dtype), grads, correction)
        new_params = jax.tree.map(
            lambda w, ri: (w - eta * ri.astype(w.dtype)).astype(w.dtype), params, r
        )
        return new_params, r, loss

    return train_step


def make_aa_step(eta: float = 1e-2, history: int = 3):
    cfg = AAConfig(tikhonov=1e-8, damping=1.0)

    def aa_step(w, g, s_stack, y_stack):
        """One Anderson step over the full parameter pytree (Alg. 1 15–18)."""
        new_w, stats = multisecant_update(w, g, s_stack, y_stack, eta, cfg)
        return new_w, stats.theta

    return aa_step


def make_prefill_step(model, cache_len: int):
    def prefill_step(params, tokens, embeds=None):
        return model.prefill(params, tokens, embeds, cache_len=cache_len)

    return prefill_step


def make_serve_step(model):
    def serve_step(params, caches, tokens, pos):
        logits, new_caches = model.decode_step(params, caches, tokens, pos)
        return logits, new_caches

    return serve_step
