"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) step on the production
meshes — 16×16 single-pod and 2×16×16 multi-pod — with ShapeDtypeStruct
inputs (no allocation), and records memory/cost/collective statistics for the
roofline analysis (deliverable g).

Also dry-runs the distributed FL round (core/sharded.py): compiles one
shard_mapped FedOSAA round with the clients partitioned over the ("pod",
"data") mesh axes and executes it on the emulated host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all              # 40 pairs, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod  # 40 pairs, 512 chips
  PYTHONPATH=src python -m repro.launch.dryrun --fl-round fedosaa_svrg --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --fl-round all --multi-pod
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# at first init, and the dry-run needs 512 placeholder host devices.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, INPUT_SHAPES, get_arch, get_shape       # noqa: E402
from repro.launch.mesh import make_production_mesh                        # noqa: E402
from repro.launch.specs_io import (                                        # noqa: E402
    batch_specs_for, cache_len_for, caches_shape, effective_cfg, params_shape,
)
from repro.launch.steps import (                                           # noqa: E402
    make_aa_step, make_prefill_step, make_serve_step, make_train_step,
)
from repro.models.decoder import build_model                               # noqa: E402
from repro.sharding.specs import (                                         # noqa: E402
    batch_axis, cache_specs, make_plan, param_specs,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the partitioned HLO.
    (Result bytes ≈ bytes on the wire for AG/AR; a consistent, documented
    convention — see benchmarks/roofline.py.)"""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        out[op] = out.get(op, 0) + _shape_bytes(shape_txt)
        out[op + "_count"] = out.get(op + "_count", 0) + 1
    return out


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis(), normalized: older jax returns one dict per
    program in a list, newer returns the dict directly."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               include_aa: bool = True, extra_tag: str = "",
               plan_overrides=None) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = get_shape(shape_name)
    cfg0 = effective_cfg(get_arch(arch), shape)
    plan = make_plan(cfg0, mesh, multi_pod=multi_pod)
    if plan_overrides:
        plan = plan_overrides(plan)
    cfg = plan.cfg
    sh = plan.sharder()
    # PerfH3 iter 1 (REFUTED): disabling remat for small models makes HBM
    # traffic 2.7x WORSE (109.6 -> 298.6 GB on smollm/train_4k) — without
    # remat the quadratic attention scores are saved for backward. Remat
    # stays on for every train shape.
    model = build_model(cfg, sh, remat=(shape.kind == "train"))

    p_shape = params_shape(model)
    p_specs = param_specs(p_shape, plan)
    p_shard = _named(p_specs, mesh)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "regime": plan.regime,
        "attn_variant": "sliding_window" if cfg.sliding_window else
                        ("none" if not cfg.num_heads else "full_causal"),
        "batch_axis": str(batch_axis(plan, shape.global_batch)),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }

    if shape.kind == "train":
        step = make_train_step(model)
        batch_sds = batch_specs_for(cfg, shape)["batch"]
        ba = batch_axis(plan, shape.global_batch)
        b_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, P(ba, *([None] * (len(s.shape) - 1)))),
            batch_sds,
        )
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, b_shard, p_shard),
            out_shardings=(p_shard, p_shard, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(p_shape, batch_sds, p_shape)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, cache_len=cache_len_for(cfg, shape))
        io = batch_specs_for(cfg, shape)
        ba = batch_axis(plan, shape.global_batch)
        tok_shard = NamedSharding(mesh, P(ba, None))
        args = [p_shape, io["tokens"]]
        shards = [p_shard, tok_shard]
        if "embeds" in io:
            args.append(io["embeds"])
            shards.append(NamedSharding(mesh, P(ba, None, None)))
        jitted = jax.jit(step, in_shardings=tuple(shards))
        lowered = jitted.lower(*args)
    else:  # decode
        step = make_serve_step(model)
        C = cache_len_for(cfg, shape)
        c_shape = caches_shape(model, shape.global_batch, C)
        c_specs = cache_specs(c_shape, plan, shape.global_batch)
        c_shard = _named(c_specs, mesh)
        io = batch_specs_for(cfg, shape)
        ba = batch_axis(plan, shape.global_batch)
        tok_shard = NamedSharding(mesh, P(ba, None))
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, tok_shard, tok_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(p_shape, c_shape, io["tokens"], io["pos"])
        result["cache_len"] = C

    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 1)

    cost = _cost_dict(compiled)
    result["flops"] = float(cost.get("flops", 0.0))
    result["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception:
        result["memory"] = None
    result["collectives"] = collective_bytes(compiled.as_text())

    # AA step (the paper's contribution) lowered per train pair
    if shape.kind == "train" and include_aa:
        hist = 3
        s_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((hist,) + x.shape, x.dtype), p_shape
        )
        s_specs = jax.tree.map(
            lambda sp: P(None, *sp), p_specs, is_leaf=lambda x: isinstance(x, P)
        )
        s_shard = _named(s_specs, mesh)
        aa = jax.jit(
            make_aa_step(history=hist),
            in_shardings=(p_shard, p_shard, s_shard, s_shard),
            out_shardings=(p_shard, None),
        )
        aa_lowered = aa.lower(p_shape, p_shape, s_shape, s_shape)
        aa_compiled = aa_lowered.compile()
        aa_cost = _cost_dict(aa_compiled)
        result["aa_step"] = {
            "flops": float(aa_cost.get("flops", 0.0)),
            "bytes_accessed": float(aa_cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes(aa_compiled.as_text()),
        }
    return result


#: Newton-family dryrun workload: these methods need full-rank local
#: Hessians (n/K ≥ d) and enough CG iterations — on the 2048-sample default
#: the 32-sample clients are rank-deficient and the full Newton step
#: diverges by round 4 regardless of codec (measured).
_NEWTON_ALGOS = ("giant", "newton_gmres", "dane")


def dryrun_fl_round(algo: str, multi_pod: bool = False,
                    num_clients: int = 64, n: int | None = None,
                    comm_codec: str = "identity", rounds: int = 1,
                    round_chunk: int = 1, aa_impl: str = "auto",
                    local_impl: str = "auto",
                    cohort_size: int | None = None,
                    clip_rtol: float = 0.0,
                    drop_rate: float = 0.0, stale_rate: float = 0.0,
                    byz_clients: int = 0, byz_mode: str = "sign_flip",
                    dp_sigma: float = 0.0, fault_seed: int = 0,
                    checkpoint_dir: str = "", checkpoint_every: int = 10,
                    checkpoint_keep: int = 3, resume: str = "none") -> dict:
    """Compile + execute shard_mapped FL round(s) on the production mesh.

    Uses a synthetic logistic-regression problem (the paper's workload) with
    the K clients partitioned over the mesh's ("pod","data") axes; num_clients
    must divide over those axes (64 covers both 16 and 2x16 client shards).
    Newton-family algos get a workload sized for them (n=8192 so the local
    Hessians are full-rank, q=10 CG iterations); everything else keeps the
    historical n=2048, η=0.5, L=3.

    ``comm_codec`` threads a repro/comm channel through the sharded round —
    ``bf16`` (or ``bf16/bf16`` for a compressed downlink too) is the
    aggregation-numerics measurement the ROADMAP asks for, and ``int8`` /
    ``int8+noef`` on a Newton-family algo measures the schema'd stateful
    wire (diff-coded gradients): run several rounds and watch the recorded
    rel-error trace converge.

    ``round_chunk > 1`` executes the rounds through the device-resident
    engine (core/engine.py): one donated lax.scan jit per chunk, metrics
    stacked on device, one host sync per chunk — the sharded-runtime
    exercise of the round engine. ``aa_impl``/``local_impl`` thread
    AlgoHParams.aa_impl and .local_impl (the sharded runtime resolves both
    to "tree" — this dry-run exercises the automatic fallback).

    ``clip_rtol`` threads AAConfig.clip_rtol — the residual-clipped AA
    byzantine screen (repro/robust) — through the sharded round, so the
    defended step's compile/collective profile is measurable on the
    production mesh (0 = screen off, the bit-identical vanilla step).

    ``drop_rate``/``stale_rate``/``byz_clients``/``byz_mode``/``dp_sigma``
    build a FaultPlan (repro/robust) threaded through the sharded round —
    the fault-injected round's compile/collective profile on the production
    mesh. All zero (the default) compiles the byte-identical fault-free
    graph; ``fault_seed`` keys the injection stream.

    ``checkpoint_dir`` checkpoints the ServerState through the
    preemption-tolerant sharded format (repro/checkpoint) every
    ``checkpoint_every`` rounds; on the engine path (``round_chunk > 1``)
    saves dispatch from the chunk-boundary sync to a background thread.
    ``resume="auto"`` restores the newest COMPLETE checkpoint under the
    directory and continues toward the same total ``rounds`` (the manifest's
    config fingerprint — algo/mesh/channel/cohort/faults — must match, else
    the resume refuses).

    ``cohort_size`` samples a C-client cohort each round (AlgoHParams
    .cohort_size): the compiled round computes on [C, ...] tensors gathered
    from the K-sized client store — the scale demonstration is
    ``num_clients=4096, cohort_size=16``, where the round body never
    materializes a [K, d] float tensor (tests/test_cohort.py). C must divide
    over the mesh's client shards. At large K the default n grows to keep
    8 samples per client — fewer and the client-local SVRG full-batch
    gradient is too noisy for a 16-of-4096 cohort to converge (measured:
    2/client diverges).
    """
    from repro.comm import make_channel
    from repro.core import AlgoHParams, init_state, run_rounds, solve_reference
    from repro.core.anderson import AAConfig
    from repro.core.sharded import make_sharded_round_fn, num_client_shards
    from repro.data import make_binary_classification, partition
    from repro.models.logreg import make_logreg_problem
    from repro.utils import tree_math as tm

    t0 = time.time()
    # clamp up front so the recorded round_chunk (and main()'s artifact tag)
    # always names the chunk that actually executed
    if round_chunk > rounds:
        print(f"note: --round-chunk {round_chunk} clamped to --fl-rounds "
              f"{rounds}" + (" — the per-round loop runs, NOT the engine"
                             if rounds <= 1 else ""))
    round_chunk = max(1, min(round_chunk, rounds))
    mesh = make_production_mesh(multi_pod=multi_pod)
    aa = AAConfig(clip_rtol=clip_rtol)
    if algo in _NEWTON_ALGOS:
        n = 8192 if n is None else n
        hp = AlgoHParams(eta=1.0, local_epochs=10, aa=aa, aa_impl=aa_impl,
                         local_impl=local_impl, cohort_size=cohort_size)
    else:
        n = max(2048, 8 * num_clients) if n is None else n
        hp = AlgoHParams(eta=0.5, local_epochs=3, aa=aa, aa_impl=aa_impl,
                         local_impl=local_impl, cohort_size=cohort_size)
    X, y = make_binary_classification("synthetic_small", n=n, seed=0)
    clients = partition(X, y, num_clients=num_clients, scheme="iid")
    problem = make_logreg_problem(clients, gamma=1e-3)
    channel = make_channel(comm_codec)
    # algo-aware init: ServerState.comm gets exactly the buffers the
    # algorithm's uplink schema (UPLINK_SCHEMAS) declares for this channel
    from repro.robust import FaultPlan
    faults = FaultPlan(seed=fault_seed, drop_rate=drop_rate,
                       stale_rate=stale_rate, byz_clients=byz_clients,
                       byz_mode=byz_mode, dp_sigma=dp_sigma)
    faults = faults if faults.active else None
    state = init_state(problem, jax.random.PRNGKey(0), hp, channel, algo)
    if faults is not None and faults.stale_rate > 0.0:
        from repro.robust import init_fault_comm
        state = state._replace(comm=init_fault_comm(
            state.comm, state.params, num_clients))
    raw_round_fn = make_sharded_round_fn(algo, problem, hp, mesh,
                                         channel=channel, faults=faults)
    round_fn = jax.jit(raw_round_fn)
    compiled = round_fn.lower(state).compile()
    compile_s = time.time() - t0

    ckpt_mgr = None
    start_round = 0
    if checkpoint_dir or resume != "none":
        from repro.checkpoint import (
            CheckpointManager, CheckpointPolicy, load_checkpoint, load_latest,
        )
        from repro.core.server import checkpoint_config_fingerprint

        fingerprint = checkpoint_config_fingerprint(
            algo, "sharded", channel.name, num_clients, cohort_size, faults)
        fingerprint["mesh"] = "2x16x16" if multi_pod else "16x16"
        if resume != "none":
            if resume == "auto":
                if not checkpoint_dir:
                    raise ValueError('resume="auto" needs checkpoint_dir')
                found = load_latest(checkpoint_dir, state,
                                    expect_config=fingerprint)
            else:
                found = load_checkpoint(resume, state,
                                        expect_config=fingerprint)
            if found is not None:
                state, manifest = found
                start_round = int(manifest["round"])
                print(f"resumed from round {start_round} "
                      f"({manifest.get('inventory', {}).get('num_leaves')} "
                      "leaves)")
        if checkpoint_dir:
            ckpt_mgr = CheckpointManager(
                CheckpointPolicy(directory=checkpoint_dir,
                                 every=checkpoint_every,
                                 keep=checkpoint_keep),
                config=fingerprint, last_saved=start_round)
    rounds_left = max(0, rounds - start_round)

    # d=54 reference solve is cheap; rel-error traces make the dryrun a
    # convergence measurement, not just a compile check (ROADMAP: Newton-row
    # numerics under lossy codecs on the multi-pod mesh)
    wstar = solve_reference(problem, iters=50)
    wstar_norm = float(tm.tree_norm(wstar))

    if rounds_left == 0:
        raise ValueError(
            f"resume landed at round {start_round} of a {rounds}-round "
            "budget — nothing left to run (raise --fl-rounds)")

    engine_compile_s = None
    if round_chunk > 1:
        from repro.core.engine import make_chunk_runner

        # Warm the chunked executable with ONE real call on a throwaway
        # state so run_s measures execution only. (.lower().compile() does
        # NOT populate the jit dispatch cache on this jax — a subsequent
        # call would recompile inside the timed region.) The warmup time is
        # compile-dominated but includes one chunk's execution.
        chunk = round_chunk
        runner = make_chunk_runner(raw_round_fn, chunk, w_star=wstar)
        warm_state = init_state(problem, jax.random.PRNGKey(0), hp, channel,
                                algo)
        t0 = time.time()
        out = runner(warm_state, jnp.int32(chunk))
        jax.block_until_ready(out[1])
        engine_compile_s = round(time.time() - t0, 1)
        t0 = time.time()
        state, trace = run_rounds(raw_round_fn, state, rounds_left,
                                  chunk=chunk, w_star=wstar, runner=runner,
                                  start_round=start_round,
                                  checkpoint=ckpt_mgr)
        losses = [float(v) for v in trace.loss]
        rel_errors = [float(v) for v in trace.rel_error]
        gram_conds = [float(v) for v in trace.gram_cond_max]
        comm_bytes = float(trace.comm_bytes[-1])
        run_s = (time.time() - t0) / max(trace.num_rounds, 1)
    else:
        t0 = time.time()
        losses, rel_errors, gram_conds = [], [], []
        for t in range(start_round, rounds):
            state, metrics = round_fn(state)
            losses.append(float(metrics.loss))
            gram_conds.append(float(metrics.gram_cond_max))
            rel_errors.append(
                float(tm.tree_norm(tm.tree_sub(state.params, wstar)))
                / max(wstar_norm, 1e-30))
            if ckpt_mgr is not None:
                ckpt_mgr.maybe_save(state, t + 1)
        jax.block_until_ready(metrics.loss)
        if ckpt_mgr is not None:
            ckpt_mgr.finalize()
        comm_bytes = float(metrics.comm_bytes)
        run_s = (time.time() - t0) / rounds_left

    cost = _cost_dict(compiled)
    return {
        "fl_round": algo,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "client_shards": num_client_shards(mesh),
        "num_clients": num_clients,
        "cohort_size": cohort_size,
        "channel": channel.name,
        "round_chunk": round_chunk,
        "clip_rtol": clip_rtol,
        "faults": (None if faults is None else {
            "seed": faults.seed, "drop_rate": faults.drop_rate,
            "stale_rate": faults.stale_rate,
            "byz_clients": faults.byz_clients, "byz_mode": faults.byz_mode,
            "dp_sigma": faults.dp_sigma,
        }),
        "aa_impl": aa_impl,
        "local_impl": local_impl,
        "start_round": start_round,
        "checkpoint": (None if ckpt_mgr is None else ckpt_mgr.telemetry()),
        "compile_s": round(compile_s, 1),
        "engine_compile_s": engine_compile_s,
        "run_s": round(run_s, 2),
        "loss": losses[-1],
        "loss_curve": losses,
        "rel_error": rel_errors[-1],
        "rel_error_curve": rel_errors,
        "gram_cond_curve": gram_conds,
        "comm_bytes": comm_bytes,
        "flops": float(cost.get("flops", 0.0)),
        "collectives": collective_bytes(compiled.as_text()),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-aa", action="store_true")
    ap.add_argument("--fl-round", type=str, default="",
                    help="dry-run a shard_mapped FL round of this algorithm "
                         "('all' = the two headline FedOSAA variants)")
    ap.add_argument("--comm-codec", type=str, default="identity",
                    help="repro/comm channel for --fl-round (e.g. bf16, int8, "
                         "bf16/bf16 — the ROADMAP bf16 numerics measurement)")
    ap.add_argument("--fl-rounds", type=int, default=1,
                    help="rounds to execute in the --fl-round dry-run "
                         "(>1 records a loss trace for numerics comparisons)")
    ap.add_argument("--round-chunk", type=int, default=1,
                    help="with --fl-round: execute the rounds through the "
                         "device-resident engine (core/engine.py), this many "
                         "rounds per donated lax.scan jit; 1 = per-round loop")
    ap.add_argument("--fl-clients", type=int, default=64,
                    help="with --fl-round: number of clients K (must divide "
                         "over the mesh's client shards unless --cohort-size "
                         "is set)")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="with --fl-round: sample a C-client cohort each "
                         "round; the compiled round computes O(C·d) over the "
                         "O(K·d) client store (core/client_store.py). The "
                         "scale demo: --fl-clients 4096 --cohort-size 16. "
                         "0 = dense full-K rounds")
    ap.add_argument("--clip-rtol", type=float, default=0.0,
                    help="with --fl-round: AAConfig.clip_rtol, the residual-"
                         "clipped AA byzantine screen (repro/robust). "
                         "0 = screen off")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="with --fl-round: FaultPlan.drop_rate — per-round "
                         "per-client uplink drop probability")
    ap.add_argument("--stale-rate", type=float, default=0.0,
                    help="with --fl-round: FaultPlan.stale_rate — aged-anchor "
                         "upload probability")
    ap.add_argument("--byz-clients", type=int, default=0,
                    help="with --fl-round: FaultPlan.byz_clients — number of "
                         "persistently byzantine clients")
    ap.add_argument("--byz-mode", choices=("sign_flip", "noise", "history"),
                    default="sign_flip",
                    help="with --fl-round: FaultPlan.byz_mode")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="with --fl-round: FaultPlan.dp_sigma — post-codec "
                         "client-side Gaussian DP noise scale")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="with --fl-round: FaultPlan.seed — keys the "
                         "injection stream (equal seeds inject bit-identical "
                         "rounds across runs and runtimes)")
    ap.add_argument("--aa-impl", choices=("auto", "tree", "pallas"),
                    default="auto",
                    help="with --fl-round: AlgoHParams.aa_impl (the sharded "
                         "runtime resolves to 'tree' — exercises the "
                         "automatic fallback)")
    ap.add_argument("--local-impl", choices=("auto", "tree", "pallas"),
                    default="auto",
                    help="with --fl-round: AlgoHParams.local_impl (the "
                         "sharded runtime resolves to 'tree' — exercises "
                         "the fused-kernel fallback path)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="with --fl-round: checkpoint the ServerState under "
                         "this directory (preemption-tolerant sharded "
                         "format, repro/checkpoint; async at chunk "
                         "boundaries under --round-chunk)")
    ap.add_argument("--checkpoint-every", type=int, default=10,
                    help="with --fl-round: rounds between checkpoint saves")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="with --fl-round: retention — GC checkpoints "
                         "beyond the newest N (0 = keep all)")
    ap.add_argument("--resume", default="none",
                    help="with --fl-round: 'auto' restores the newest "
                         "COMPLETE checkpoint under --checkpoint-dir and "
                         "continues toward the same --fl-rounds total; "
                         "'none' starts fresh; otherwise a ckpt_* path. "
                         "Mismatched manifest config refuses to resume")
    args = ap.parse_args()

    if args.fl_round:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        algos = (["fedosaa_svrg", "fedosaa_scaffold"]
                 if args.fl_round == "all" else [args.fl_round])
        failures = []
        codec_tag = ("" if args.comm_codec == "identity"
                     else f"{args.comm_codec.replace('/', '-').replace(':', '')}__")
        engine_tag = ""  # distinct artifact names for engine/pallas/cohort runs
        # same clamp as dryrun_fl_round: the tag names the EXECUTED chunk
        eff_chunk = max(1, min(args.round_chunk, args.fl_rounds))
        if args.cohort_size:
            # the cohort tag subsumes the chunk tag (the JSON records
            # round_chunk either way)
            engine_tag += f"cohort{args.cohort_size}-of-{args.fl_clients}"
        elif eff_chunk > 1:
            engine_tag += f"chunk{eff_chunk}"
        if args.clip_rtol:
            engine_tag += ("+" if engine_tag else "") + f"clip{args.clip_rtol:g}"
        # fault knobs name the artifact so injected dry-runs never clobber
        # the fault-free profile of the same algo/codec/mesh combination
        if args.drop_rate:
            engine_tag += ("+" if engine_tag else "") + f"drop{args.drop_rate:g}"
        if args.stale_rate:
            engine_tag += ("+" if engine_tag else "") + f"stale{args.stale_rate:g}"
        if args.byz_clients:
            engine_tag += ("+" if engine_tag else "") + (
                f"byz{args.byz_clients}-{args.byz_mode.replace('_', '')}")
        if args.dp_sigma:
            engine_tag += ("+" if engine_tag else "") + f"dp{args.dp_sigma:g}"
        if args.aa_impl != "auto":
            engine_tag += ("+" if engine_tag else "") + args.aa_impl
        if args.local_impl != "auto":
            engine_tag += ("+" if engine_tag else "") + f"local-{args.local_impl}"
        engine_tag = f"{engine_tag}__" if engine_tag else ""
        for algo in algos:
            tag = (f"fl_round__{algo}__{codec_tag}{engine_tag}"
                   f"{'2x16x16' if args.multi_pod else '16x16'}")
            try:
                res = dryrun_fl_round(algo, args.multi_pod,
                                      num_clients=args.fl_clients,
                                      comm_codec=args.comm_codec,
                                      rounds=args.fl_rounds,
                                      round_chunk=args.round_chunk,
                                      aa_impl=args.aa_impl,
                                      local_impl=args.local_impl,
                                      cohort_size=args.cohort_size or None,
                                      clip_rtol=args.clip_rtol,
                                      drop_rate=args.drop_rate,
                                      stale_rate=args.stale_rate,
                                      byz_clients=args.byz_clients,
                                      byz_mode=args.byz_mode,
                                      dp_sigma=args.dp_sigma,
                                      fault_seed=args.fault_seed,
                                      checkpoint_dir=args.checkpoint_dir,
                                      checkpoint_every=args.checkpoint_every,
                                      checkpoint_keep=args.checkpoint_keep,
                                      resume=args.resume)
                with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
                print(f"OK   {tag}: compile={res['compile_s']}s "
                      f"run={res['run_s']}s loss={res['loss']:.4f} "
                      f"relerr={res['rel_error']:.2e} "
                      f"ar={res['collectives'].get('all-reduce_count', 0)}")
            except Exception as e:
                failures.append(tag)
                print(f"FAIL {tag}: {e}")
                traceback.print_exc()
        if failures:
            raise SystemExit(1)
        print("fl-round dry-runs passed")
        return

    os.makedirs(RESULTS_DIR, exist_ok=True)
    combos = []
    if args.all:
        combos = [(a, s) for a in ARCHS for s in INPUT_SHAPES]
    else:
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
        out_path = os.path.join(RESULTS_DIR, tag + ".json")
        try:
            res = dryrun_one(arch, shape, args.multi_pod, include_aa=not args.no_aa)
            with open(out_path, "w") as f:
                json.dump(res, f, indent=1)
            mem = (res.get("memory") or {}).get("peak_bytes", 0)
            print(f"OK   {tag}: compile={res['compile_s']}s "
                  f"flops={res['flops']:.3e} peak={mem/2**30:.2f}GiB "
                  f"coll={sum(v for k, v in res['collectives'].items() if not k.endswith('_count'))/2**30:.3f}GiB")
        except Exception as e:
            failures.append(tag)
            print(f"FAIL {tag}: {e}")
            traceback.print_exc()
    if failures:
        print(f"FAILURES ({len(failures)}): {failures}")
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
