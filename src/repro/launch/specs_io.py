"""ShapeDtypeStruct stand-ins for every dry-run input (no device allocation).

``input_specs(cfg, shape)`` returns the kwargs of the step function that the
dry-run lowers for that (arch × input-shape) pair:

  train_4k    -> train_step(params, batch{tokens[,embeds]}, correction)
  prefill_32k -> prefill_step(params, tokens[, embeds])
  decode_*    -> serve_step(params, caches, tokens[B,1], pos[B,1])

Decode caches: full-attention archs get a KV cache of seq_len; for
``long_500k`` the sliding-window variant is auto-enabled for attention archs
(window 8192 ring buffer) — SSM/hybrid archs are O(1)-state natively.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import InputShape

Pytree = Any

LONG_CONTEXT_WINDOW = 8192


def effective_cfg(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Arch variant actually lowered for this input shape: attention archs
    switch to the sliding-window variant for long_500k (sub-quadratic
    requirement); everything else is unchanged."""
    if shape.name == "long_500k" and cfg.num_heads and not cfg.sliding_window:
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def cache_len_for(cfg: ArchConfig, shape: InputShape) -> int:
    if cfg.sliding_window:
        return cfg.sliding_window
    return shape.seq_len


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_shape(model) -> Pytree:
    """Shape-only init via eval_shape (no allocation)."""
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def batch_specs_for(cfg: ArchConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.frontend_tokens:
            batch["embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.frontend_tokens:
            out["embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model), dt)
        return out
    # decode
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((B, 1), jnp.int32),
    }


def caches_shape(model, batch: int, cache_len: int) -> Pytree:
    return jax.eval_shape(lambda: model.init_caches(batch, cache_len))


def correction_shape(params: Pytree) -> Pytree:
    """FL gradient-correction term: same structure as params (SVRG term)."""
    return params
