from repro.data.partition import PARTITIONERS, heterogeneity_score, partition  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    DATASETS,
    make_binary_classification,
    make_lm_tokens,
    make_mnist_like,
)
