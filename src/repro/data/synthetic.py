"""Dataset generation & loading.

The evaluation container is offline, so the LIBSVM datasets the paper uses
(covtype: N=581,012 d=54; w8a: N=49,749 d=300) are replaced by synthetic
generators that match their statistical fingerprint (dimension, scale,
class balance, feature correlation). If the real files are present under
$REPRO_DATA_DIR (libsvm text format), they are loaded instead — the code path
is identical downstream.
"""
from __future__ import annotations

import os

import numpy as np

DATASETS = {
    # name: (default N for experiments, d, positive fraction, margin scale)
    "covtype": (58_100, 54, 0.49, 1.0),    # paper uses N=581,012; 10% default here
    "w8a": (49_749, 300, 0.03, 1.0),
    "synthetic_small": (4_000, 40, 0.5, 1.0),
}


def _load_libsvm(path: str, d: int):
    xs, ys = [], []
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            y = float(parts[0])
            ys.append(1.0 if y > 0 else -1.0)
            row = np.zeros(d, np.float32)
            for tok in parts[1:]:
                i, v = tok.split(":")
                row[int(i) - 1] = float(v)
            xs.append(row)
    return np.stack(xs), np.asarray(ys, np.float32)


def make_binary_classification(
    name: str = "covtype",
    n: int | None = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Return (X [N,d] float32, y [N] in {−1,+1})."""
    if name not in DATASETS:
        raise ValueError(f"unknown dataset {name!r}")
    n_default, d, pos_frac, scale = DATASETS[name]
    n = n or n_default

    data_dir = os.environ.get("REPRO_DATA_DIR", "")
    real = os.path.join(data_dir, name) if data_dir else ""
    if real and os.path.exists(real):
        X, y = _load_libsvm(real, d)
        return X[:n], y[:n]

    rng = np.random.default_rng(seed)
    # correlated features with decaying spectrum — mimics real tabular data
    # and yields an ill-conditioned Hessian like covtype's
    spectrum = (1.0 / np.sqrt(1.0 + np.arange(d))).astype(np.float32)
    basis = np.linalg.qr(rng.standard_normal((d, d)))[0].astype(np.float32)
    latent = rng.standard_normal((n, d)).astype(np.float32)
    X = (latent * spectrum) @ basis.T * scale
    # ground-truth separator + label noise, then rebalance to pos_frac
    w_true = rng.standard_normal(d).astype(np.float32)
    logits = X @ w_true / np.sqrt(d)
    thresh = np.quantile(logits, 1.0 - pos_frac)
    y = np.where(logits > thresh, 1.0, -1.0).astype(np.float32)
    # 2% label noise so the problem is not separable (keeps w* finite)
    flip = rng.random(n) < 0.02
    y[flip] = -y[flip]
    return X, y


def make_mnist_like(
    n: int = 10_000, d: int = 784, num_classes: int = 10, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic 10-class 'MNIST' for the App. D.5 NN experiment: Gaussian
    class prototypes in a low-dim manifold embedded in d dims + pixel noise."""
    rng = np.random.default_rng(seed)
    latent_dim = 32
    protos = rng.standard_normal((num_classes, latent_dim)).astype(np.float32) * 3.0
    embed = rng.standard_normal((latent_dim, d)).astype(np.float32) / np.sqrt(latent_dim)
    y = rng.integers(0, num_classes, n)
    z = protos[y] + rng.standard_normal((n, latent_dim)).astype(np.float32)
    X = z @ embed + 0.3 * rng.standard_normal((n, d)).astype(np.float32)
    # squash to [0,1] like pixel intensities
    X = 1.0 / (1.0 + np.exp(-X))
    return X.astype(np.float32), y.astype(np.int32)


def make_lm_tokens(
    n_docs: int, seq_len: int, vocab: int, seed: int = 0
) -> np.ndarray:
    """Synthetic token stream with Zipfian unigram + Markov bigram structure,
    for LM federated-training examples. Returns [n_docs, seq_len] int32."""
    rng = np.random.default_rng(seed)
    # zipf over a capped vocab for speed
    v_eff = min(vocab, 32_768)
    ranks = np.arange(1, v_eff + 1)
    p = 1.0 / ranks
    p /= p.sum()
    toks = rng.choice(v_eff, size=(n_docs, seq_len), p=p)
    # light Markov smoothing: with prob .3 repeat previous token's neighborhood
    repeat = rng.random((n_docs, seq_len)) < 0.3
    shifted = np.roll(toks, 1, axis=1)
    toks = np.where(repeat, (shifted + rng.integers(0, 17, toks.shape)) % v_eff, toks)
    return toks.astype(np.int32)
