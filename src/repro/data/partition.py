"""Client partitioners (paper §4 data distributions + Li et al. [33]).

* iid        — random equal split (extra data dropped, paper D.2)
* imbalance  — power-law sizes: largest client 50% of data, smallest 0.2%
* label_skew — near-equal sizes, each client dominated by one label
"""
from __future__ import annotations

import numpy as np

from repro.core.problem import StackedClients, stack_client_arrays

PARTITIONERS = ("iid", "imbalance", "label_skew")


def partition(
    X: np.ndarray,
    y: np.ndarray,
    num_clients: int,
    scheme: str = "iid",
    seed: int = 0,
) -> StackedClients:
    if scheme not in PARTITIONERS:
        raise ValueError(f"unknown scheme {scheme!r}; choose from {PARTITIONERS}")
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    perm = rng.permutation(n)
    X, y = X[perm], y[perm]

    if scheme == "iid":
        n_k = n // num_clients
        xs = [X[k * n_k:(k + 1) * n_k] for k in range(num_clients)]
        ys = [y[k * n_k:(k + 1) * n_k] for k in range(num_clients)]

    elif scheme == "imbalance":
        # geometric interpolation from 50% down to 0.2% (paper §4), normalized
        fracs = np.geomspace(0.5, 0.002, num_clients)
        fracs = fracs / fracs.sum()
        counts = np.maximum((fracs * n).astype(int), 2)
        edges = np.concatenate([[0], np.cumsum(counts)])
        edges = np.minimum(edges, n)
        xs = [X[edges[k]:edges[k + 1]] for k in range(num_clients)]
        ys = [y[edges[k]:edges[k + 1]] for k in range(num_clients)]

    else:  # label_skew: sort by label, deal contiguous label blocks to clients
        order = np.argsort(y, kind="stable")
        X, y = X[order], y[order]
        n_k = n // num_clients
        xs = [X[k * n_k:(k + 1) * n_k] for k in range(num_clients)]
        ys = [y[k * n_k:(k + 1) * n_k] for k in range(num_clients)]

    return stack_client_arrays(xs, ys)


def heterogeneity_score(clients: StackedClients) -> float:
    """Mean pairwise distance between client label means — a rough proxy for
    the degree of statistical heterogeneity (reported in EXPERIMENTS.md)."""
    means = []
    y = np.asarray(clients.y, dtype=np.float64)
    m = np.asarray(clients.mask, dtype=np.float64)
    for k in range(clients.num_clients):
        nk = max(m[k].sum(), 1.0)
        means.append((y[k] * m[k]).sum() / nk)
    means = np.asarray(means)
    return float(np.abs(means[:, None] - means[None, :]).mean())
