"""Client partitioners (paper §4 data distributions + Li et al. [33]).

* iid        — random equal split (extra data dropped, paper D.2)
* imbalance  — power-law sizes: largest client 50% of data, smallest 0.2%
* label_skew — near-equal sizes, each client dominated by one label
"""
from __future__ import annotations

import numpy as np

from repro.core.problem import StackedClients, stack_client_arrays

PARTITIONERS = ("iid", "imbalance", "label_skew")


def partition(
    X: np.ndarray,
    y: np.ndarray,
    num_clients: int,
    scheme: str = "iid",
    seed: int = 0,
) -> StackedClients:
    if scheme not in PARTITIONERS:
        raise ValueError(f"unknown scheme {scheme!r}; choose from {PARTITIONERS}")
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    perm = rng.permutation(n)
    X, y = X[perm], y[perm]

    if scheme == "iid":
        n_k = n // num_clients
        xs = [X[k * n_k:(k + 1) * n_k] for k in range(num_clients)]
        ys = [y[k * n_k:(k + 1) * n_k] for k in range(num_clients)]

    elif scheme == "imbalance":
        # geometric interpolation from 50% down to 0.2% (paper §4), normalized
        if n < 2 * num_clients:
            raise ValueError(
                f"imbalance partition needs >= 2 samples per client: "
                f"n={n} < 2*num_clients={2 * num_clients}")
        fracs = np.geomspace(0.5, 0.002, num_clients)
        fracs = fracs / fracs.sum()
        counts = np.maximum((fracs * n).astype(int), 2)
        # the 2-sample floor can push the total past n; trim the excess from
        # the largest clients (never below 2) so every client keeps >= 2
        # samples instead of trailing clients getting empty slices
        excess = int(counts.sum()) - n
        while excess > 0:
            k = int(np.argmax(counts))
            take = min(excess, int(counts[k]) - 2)
            counts[k] -= take
            excess -= take
        if excess < 0:
            # floor-rounding undershoot: give the remainder to the largest
            # client (keeps the power-law head) instead of silently dropping
            # the samples
            counts[int(np.argmax(counts))] -= excess
        edges = np.concatenate([[0], np.cumsum(counts)])
        xs = [X[edges[k]:edges[k + 1]] for k in range(num_clients)]
        ys = [y[edges[k]:edges[k + 1]] for k in range(num_clients)]

    else:  # label_skew: sort by label, deal contiguous label blocks to clients
        order = np.argsort(y, kind="stable")
        X, y = X[order], y[order]
        n_k = n // num_clients
        xs = [X[k * n_k:(k + 1) * n_k] for k in range(num_clients)]
        ys = [y[k * n_k:(k + 1) * n_k] for k in range(num_clients)]

    return stack_client_arrays(xs, ys)


def heterogeneity_score(clients: StackedClients) -> float:
    """Mean pairwise distance between client label means — a rough proxy for
    the degree of statistical heterogeneity (reported in EXPERIMENTS.md)."""
    means = []
    y = np.asarray(clients.y, dtype=np.float64)
    m = np.asarray(clients.mask, dtype=np.float64)
    for k in range(clients.num_clients):
        nk = max(m[k].sum(), 1.0)
        means.append((y[k] * m[k]).sum() / nk)
    means = np.asarray(means)
    return float(np.abs(means[:, None] - means[None, :]).mean())
