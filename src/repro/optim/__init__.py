from repro.optim.optimizers import Optimizer, OptState, adamw, clip_by_global_norm, sgd  # noqa: F401
from repro.optim.schedules import constant, cosine, wsd  # noqa: F401
