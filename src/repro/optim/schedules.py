"""LR schedules, including WSD (Warmup-Stable-Decay) from MiniCPM
[arXiv:2404.06395] — the schedule the minicpm-2b assigned arch trains with."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, min_ratio: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos
    return fn


def wsd(lr: float, total_steps: int, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, long flat plateau, sharp
    exponential-style decay over the final ``decay_frac`` of training."""
    warmup = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1 - decay_frac))

    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / warmup, 1.0)
        decay_prog = jnp.clip(
            (s - decay_start) / jnp.maximum(total_steps - decay_start, 1), 0, 1
        )
        decay = jnp.power(jnp.asarray(min_ratio, jnp.float32), decay_prog)
        return lr * warm * decay
    return fn
