"""Optimizers (no optax in this container — built from scratch, pytree-native).

The FL local update in the paper is plain (corrected) GD; AdamW + schedules
are provided for the centralized LM baselines and the MiniCPM (WSD) config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Pytree          # first moment (zeros for sgd)
    nu: Pytree          # second moment (zeros unless adam)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    update: Callable[[Pytree, OptState, Pytree], tuple[Pytree, OptState]]
    # update(grads, state, params) -> (new_params, new_state)


def sgd(lr: float | Callable[[jax.Array], jax.Array],
        momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), zeros, None)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            d = (jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
                 if nesterov else mu)
        else:
            mu, d = None, grads
        new_params = jax.tree.map(
            lambda w, gi: (w - lr_t * gi.astype(jnp.float32)).astype(w.dtype),
            params, d,
        )
        return new_params, OptState(step, mu, None)

    return Optimizer(init, update)


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32),
                        z, jax.tree.map(jnp.zeros_like, z))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(w, m, v):
            d = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                d = d + weight_decay * w.astype(jnp.float32)
            return (w.astype(jnp.float32) - lr_t * d).astype(w.dtype)

        return jax.tree.map(upd, params, mu, nu), OptState(step, mu, nu)

    return Optimizer(init, update)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    from repro.utils import tree_math as tm
    norm = tm.tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
