"""Pallas TPU kernels for the FedOSAA Anderson-acceleration step.

Hardware adaptation (DESIGN.md §3): the AA step is *memory-bound* — O(L)
arithmetic intensity over a parameter vector of up to 10¹⁰ elements. The
naive jnp implementation streams S and Y from HBM THREE times (Gram build,
projection, update). These kernels stream them exactly once per pass, tiled
through VMEM:

  pass 1 (``gram_kernel``):   accumulate YᵀY [m,m] and Yᵀg [m] tile-by-tile
  pass 2 (``update_kernel``): w⁺ = w − ηg − (S − ηY)Γ       tile-by-tile

The [m,m] solve between the passes is negligible (m = local epochs ≤ ~30) and
stays in plain jnp. Tiles are (m, T) with T=2048 lanes — m is padded to the
8-sublane granule by the caller (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 2048


def _gram_kernel(y_ref, g_ref, gram_ref, yg_ref):
    """Grid: (d // T,). Accumulates into the single output block.

    y_ref:   [m, T] VMEM tile of Y
    g_ref:   [1, T] VMEM tile of the gradient
    gram_ref:[m, m] output (same block every step -> accumulate)
    yg_ref:  [1, m] output
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        yg_ref[...] = jnp.zeros_like(yg_ref)

    y = y_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    gram_ref[...] += jax.lax.dot_general(
        y, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    yg_ref[...] += jax.lax.dot_general(
        g, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def gram_pallas(y: jax.Array, g: jax.Array, tile: int = DEFAULT_TILE,
                interpret: bool = False):
    """y: [m, d]; g: [d]. Returns (YᵀY [m,m], Yᵀg [m]). d % tile == 0."""
    m, d = y.shape
    assert d % tile == 0, (d, tile)
    grid = (d // tile,)
    gram, yg = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
        ],
        interpret=interpret,
    )(y, g.reshape(1, d))
    return gram, yg[0]


def _update_kernel(w_ref, g_ref, s_ref, y_ref, gamma_ref, eta_ref, beta_ref,
                   out_ref):
    """w⁺ tile = w − η·g − β·(Sᵀγ − η·Yᵀγ) over a [1, T] tile.

    gamma_ref: [1, m] SMEM-resident coefficients; eta/beta scalars [1,1].
    """
    w = w_ref[...].astype(jnp.float32)       # [1, T]
    g = g_ref[...].astype(jnp.float32)       # [1, T]
    s = s_ref[...].astype(jnp.float32)       # [m, T]
    y = y_ref[...].astype(jnp.float32)       # [m, T]
    gamma = gamma_ref[...].astype(jnp.float32)   # [1, m]
    eta = eta_ref[0, 0]
    beta = beta_ref[0, 0]
    s_g = jax.lax.dot_general(
        gamma, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # [1, T]
    y_g = jax.lax.dot_general(
        gamma, y, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out = w - eta * g - beta * (s_g - eta * y_g)
    out_ref[...] = out.astype(out_ref.dtype)


def update_pallas(w, g, s, y, gamma, eta, beta, tile: int = DEFAULT_TILE,
                  interpret: bool = False):
    """w,g: [d]; s,y: [m,d]; gamma: [m]. Returns w⁺ [d]."""
    m, d = s.shape
    assert d % tile == 0, (d, tile)
    grid = (d // tile,)
    out = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((m, tile), lambda i: (0, i)),
            pl.BlockSpec((m, tile), lambda i: (0, i)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        interpret=interpret,
    )(
        w.reshape(1, d), g.reshape(1, d), s, y,
        gamma.reshape(1, m).astype(jnp.float32),
        jnp.full((1, 1), eta, jnp.float32),
        jnp.full((1, 1), beta, jnp.float32),
    )
    return out[0]
