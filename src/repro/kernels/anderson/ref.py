"""Pure-jnp oracle for the Anderson kernels (the 3-pass naive version)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(y: jax.Array, g: jax.Array):
    """y: [m,d]; g: [d] -> (YᵀY [m,m], Yᵀg [m]) in f32."""
    y32 = y.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    return y32 @ y32.T, y32 @ g32


def update_ref(w, g, s, y, gamma, eta, beta):
    """w⁺ = w − ηg − β(Sᵀγ − ηYᵀγ); inputs as in update_pallas."""
    w32, g32 = w.astype(jnp.float32), g.astype(jnp.float32)
    s32, y32 = s.astype(jnp.float32), y.astype(jnp.float32)
    gm = gamma.astype(jnp.float32)
    out = w32 - eta * g32 - beta * (gm @ s32 - eta * (gm @ y32))
    return out.astype(w.dtype)


def solve_gamma_ref(gram, yg, tikhonov: float = 1e-10):
    m = gram.shape[0]
    lam = tikhonov * jnp.trace(gram) / m
    return jnp.linalg.solve(gram + lam * jnp.eye(m), yg)


def aa_step_ref(w, g, s, y, eta, beta=1.0, tikhonov=1e-10):
    """Full flat-vector AA step (Eq. 7), matching ops.aa_step_flat."""
    gram, yg = gram_ref(y, g)
    gamma = solve_gamma_ref(gram, yg, tikhonov)
    return update_ref(w, g, s, y, gamma, eta, beta)
