"""Jit'd public wrappers: flat-vector AA passes via the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile natively. The wrappers pad d up to the tile size and m up to the
8-sublane granule (histories longer than one granule — m > 8, e.g. L=10
local epochs or carried cross-round columns — pad to the next multiple of
8), then strip the padding: padded Y columns are zero so they contribute
nothing to the Gram matrix, and gamma entries for them are zeroed after the
solve.

Besides the one-shot ``aa_step_flat`` (kept as the flat-vector reference
entry point), this module exposes the two passes separately
(``flat_gram`` / ``flat_update``) plus dtype-preserving ravel helpers, so
the round cores can fuse the AA hot path over a *pytree*: group the leaves
by dtype, ravel each group into one flat buffer, accumulate ONE Gram system
across groups, solve once, and stream each group through the update kernel —
every S/Y element is read exactly once per pass instead of the three
HBM sweeps of the naive tree_math path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.anderson.anderson import DEFAULT_TILE, gram_pallas, update_pallas
from repro.kernels.anderson.ref import solve_gamma_ref

_ON_CPU = None


def _interpret_default() -> bool:
    global _ON_CPU
    if _ON_CPU is None:
        _ON_CPU = jax.devices()[0].platform != "tpu"
    return _ON_CPU


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_dims(m: int, d: int, tile: int) -> tuple[int, int, int]:
    """(tile, d_pad, m_pad): shrink the tile for small vectors, pad d to a
    tile multiple and m to the 8-sublane granule (handles m > 8)."""
    t = min(tile, 256) if d < tile else tile
    d_pad = ((d + t - 1) // t) * t
    m_pad = ((m + 7) // 8) * 8
    return t, d_pad, m_pad


# --------------------------------------------------------------------------
# the two single-pass kernels on unpadded flat buffers
# --------------------------------------------------------------------------

def flat_gram(y, g, *, tile: int = DEFAULT_TILE, interpret: bool | None = None):
    """One-pass Gram build on flat buffers: y [m,d], g [d] →
    (YᵀY [m,m] f32, Yᵀg [m] f32). Pads internally; any m ≥ 1."""
    if interpret is None:
        interpret = _interpret_default()
    m, d = y.shape
    t, d_pad, m_pad = _pad_dims(m, d, tile)
    yp = _pad_to(_pad_to(y, d_pad, 1), m_pad, 0)
    gp = _pad_to(g, d_pad, 0)
    gram, yg = gram_pallas(yp, gp, tile=t, interpret=interpret)
    return gram[:m, :m], yg[:m]


def flat_update(w, g, s, y, gamma, eta, beta, *, tile: int = DEFAULT_TILE,
                interpret: bool | None = None):
    """One-pass update on flat buffers: w⁺ = w − ηg − β(SᵀΓ − ηYᵀΓ).
    w,g: [d]; s,y: [m,d]; gamma: [m]. Pads internally; preserves w.dtype."""
    if interpret is None:
        interpret = _interpret_default()
    m, d = s.shape
    t, d_pad, m_pad = _pad_dims(m, d, tile)
    wp, gp = _pad_to(w, d_pad, 0), _pad_to(g, d_pad, 0)
    sp = _pad_to(_pad_to(s, d_pad, 1), m_pad, 0)
    yp = _pad_to(_pad_to(y, d_pad, 1), m_pad, 0)
    gp_ = _pad_to(gamma.astype(jnp.float32), m_pad, 0)
    out = update_pallas(wp, gp, sp, yp, gp_, eta, beta, tile=t,
                        interpret=interpret)
    return out[:d]


# --------------------------------------------------------------------------
# dtype-preserving ravel helpers (pytree ↔ per-dtype flat buffers)
# --------------------------------------------------------------------------

def dtype_leaf_groups(tree) -> list[tuple[jnp.dtype, list[int]]]:
    """Flattened-leaf indices grouped by dtype, in first-seen leaf order.

    A single-dtype model (the common case) yields exactly one group — one
    flat buffer per round through the kernels; mixed-dtype trees get one
    buffer per dtype, sharing a single Gram system across groups."""
    groups: dict = {}
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        groups.setdefault(jnp.dtype(leaf.dtype), []).append(i)
    return list(groups.items())


def ravel_group(leaves: list, idxs: list[int]):
    """Concatenate the selected plain leaves into one flat [d_g] buffer."""
    return jnp.concatenate([leaves[i].reshape(-1) for i in idxs])


def ravel_stack_group(leaves: list, idxs: list[int]):
    """Concatenate the selected stacked leaves ([m, ...]) into [m, d_g]."""
    m = leaves[idxs[0]].shape[0]
    return jnp.concatenate([leaves[i].reshape(m, -1) for i in idxs], axis=1)


def unravel_group_into(flat, leaves: list, idxs: list[int], out: list) -> None:
    """Scatter a flat [d_g] buffer back into ``out`` at the group's leaf
    slots, restoring each leaf's shape and dtype (dtype-preserving)."""
    off = 0
    for i in idxs:
        ref = leaves[i]
        out[i] = flat[off:off + ref.size].reshape(ref.shape).astype(ref.dtype)
        off += ref.size


@partial(jax.jit, static_argnames=("eta", "beta", "tikhonov", "tile", "interpret"))
def aa_step_flat(w, g, s, y, *, eta: float, beta: float = 1.0,
                 tikhonov: float = 1e-10, tile: int = DEFAULT_TILE,
                 interpret: bool | None = None):
    """One AA step on flat vectors. w,g: [d]; s,y: [m,d]. Returns w⁺ [d]."""
    if interpret is None:
        interpret = _interpret_default()
    # solve only over the true m columns (padded rows/cols are zero; the
    # padded gamma entries flat_update re-pads are zero too)
    gram, yg = flat_gram(y, g, tile=tile, interpret=interpret)
    gamma = solve_gamma_ref(gram, yg, tikhonov)
    return flat_update(w, g, s, y, gamma, eta, beta, tile=tile,
                       interpret=interpret)
