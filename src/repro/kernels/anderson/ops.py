"""Jit'd public wrapper: one-shot flat-vector AA step via the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile natively. The wrapper pads d up to the tile size and m up to the
8-sublane granule, then strips the padding — padded Y columns are zero so
they contribute nothing to the Gram matrix (gamma entries for them are zeroed
after the solve).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.anderson.anderson import DEFAULT_TILE, gram_pallas, update_pallas
from repro.kernels.anderson.ref import solve_gamma_ref

_ON_CPU = None


def _interpret_default() -> bool:
    global _ON_CPU
    if _ON_CPU is None:
        _ON_CPU = jax.devices()[0].platform != "tpu"
    return _ON_CPU


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("eta", "beta", "tikhonov", "tile", "interpret"))
def aa_step_flat(w, g, s, y, *, eta: float, beta: float = 1.0,
                 tikhonov: float = 1e-10, tile: int = DEFAULT_TILE,
                 interpret: bool | None = None):
    """One AA step on flat vectors. w,g: [d]; s,y: [m,d]. Returns w⁺ [d]."""
    if interpret is None:
        interpret = _interpret_default()
    m, d = s.shape
    t = min(tile, 256) if d < tile else tile
    d_pad = ((d + t - 1) // t) * t
    m_pad = ((m + 7) // 8) * 8
    wp, gp = _pad_to(w, d_pad, 0), _pad_to(g, d_pad, 0)
    sp = _pad_to(_pad_to(s, d_pad, 1), m_pad, 0)
    yp = _pad_to(_pad_to(y, d_pad, 1), m_pad, 0)

    gram, yg = gram_pallas(yp, gp, tile=t, interpret=interpret)
    # solve only over the true m columns (padded rows/cols are zero)
    gamma_true = solve_gamma_ref(gram[:m, :m], yg[:m], tikhonov)
    gamma = jnp.zeros((m_pad,), jnp.float32).at[:m].set(gamma_true)
    out = update_pallas(wp, gp, sp, yp, gamma, eta, beta, tile=t,
                        interpret=interpret)
    return out[:d]
