"""Pallas TPU kernels for the comm-subsystem int8 stochastic-rounding codec.

Wire format (repro/comm): a flat f32 upload vector is reshaped into chunks of
``chunk`` lanes; each chunk is quantized to int8 with its own f32 scale
(symmetric, scale = max|x| / 127) and stochastic rounding, so the roundtrip is
UNBIASED: E[dequant(quant(x))] = x, |error| < scale elementwise.

The random uniforms are an *input* (generated with jax.random by the caller,
one draw per element) rather than an in-kernel PRNG: the pure-jnp oracle
(ref.py) then computes bit-identical results from the same draws, which is
what the interpret-mode parity tests pin down.

Both kernels are single-pass and memory-bound: one [rows, chunk] tile streams
through VMEM per grid step, exactly like the anderson/ kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: lanes per quantization chunk (also the kernel tile width).
DEFAULT_CHUNK = 256
#: rows (chunks) per tile — the f32 sublane granule.
ROW_TILE = 8


def _quantize_kernel(x_ref, u_ref, q_ref, scale_ref):
    """One [R, C] tile: per-row abs-max scale + stochastic round to int8.

    x_ref, u_ref: [R, C] VMEM tiles (values, uniform draws in [0,1))
    q_ref:        [R, C] int8 output tile
    scale_ref:    [R, 1] f32 per-chunk scales
    """
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)          # [R, 1]
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    v = x / scale                                              # in [-127, 127]
    q = jnp.floor(v + u_ref[...].astype(jnp.float32))          # E[q] = v
    q = jnp.clip(q, -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def _dequantize_kernel(q_ref, scale_ref, out_ref):
    """out tile = int8 tile × its per-row scale."""
    out_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


def quantize_pallas(x: jax.Array, u: jax.Array, row_tile: int = ROW_TILE,
                    interpret: bool = False):
    """x, u: [nc, C] f32 (nc % row_tile == 0). Returns (q int8, scales [nc,1])."""
    nc, C = x.shape
    assert nc % row_tile == 0, (nc, row_tile)
    grid = (nc // row_tile,)
    q, scales = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, C), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_tile, C), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, C), jnp.int8),
            jax.ShapeDtypeStruct((nc, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, u)
    return q, scales


def dequantize_pallas(q: jax.Array, scales: jax.Array,
                      row_tile: int = ROW_TILE, interpret: bool = False):
    """q: [nc, C] int8; scales: [nc, 1] f32. Returns f32 [nc, C]."""
    nc, C = q.shape
    assert nc % row_tile == 0, (nc, row_tile)
    grid = (nc // row_tile,)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, C), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, C), jnp.float32),
        interpret=interpret,
    )(q, scales)
