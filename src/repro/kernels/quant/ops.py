"""Public flat-vector entry points for the int8-SR wire codec.

``int8_sr_encode`` / ``int8_dequantize`` are what repro/comm's Int8SRCodec
calls: they handle the flatten/pad-to-chunk bookkeeping and dispatch the 2-D
chunk math to the Pallas kernels on TPU or to the op-identical jnp oracle
(ref.py) elsewhere — interpret-mode Pallas inside a vmapped FL round core
would dominate CPU round time. Both are vmap-safe (the comm layer maps them
over the client axis) and jit-inlineable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant.quant import (
    DEFAULT_CHUNK,
    ROW_TILE,
    dequantize_pallas,
    quantize_pallas,
)
from repro.kernels.quant.ref import dequantize_ref, quantize_ref

_ON_TPU = None


def _use_pallas_default() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return _ON_TPU


def chunk_rows(n: int, chunk: int = DEFAULT_CHUNK) -> int:
    """Number of quantization chunks covering a length-n vector."""
    return max(1, -(-n // chunk))


def _to_chunks(x_flat: jax.Array, chunk: int) -> jax.Array:
    n = x_flat.shape[0]
    nc = chunk_rows(n, chunk)
    pad = nc * chunk - n
    if pad:
        x_flat = jnp.pad(x_flat, (0, pad))
    return x_flat.reshape(nc, chunk)


def quantize_2d(x: jax.Array, u: jax.Array, use_pallas: bool | None = None,
                interpret: bool | None = None):
    """[nc, C] chunked quantize, kernel- or oracle-backed (same arithmetic)."""
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if not use_pallas:
        return quantize_ref(x, u)
    if interpret is None:
        interpret = not _use_pallas_default()
    nc = x.shape[0]
    nc_pad = -(-nc // ROW_TILE) * ROW_TILE
    xp = jnp.pad(x, ((0, nc_pad - nc), (0, 0)))
    up = jnp.pad(u, ((0, nc_pad - nc), (0, 0)))
    q, scales = quantize_pallas(xp, up, interpret=interpret)
    return q[:nc], scales[:nc]


def dequantize_2d(q: jax.Array, scales: jax.Array,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None) -> jax.Array:
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if not use_pallas:
        return dequantize_ref(q, scales)
    if interpret is None:
        interpret = not _use_pallas_default()
    nc = q.shape[0]
    nc_pad = -(-nc // ROW_TILE) * ROW_TILE
    qp = jnp.pad(q, ((0, nc_pad - nc), (0, 0)))
    sp = jnp.pad(scales, ((0, nc_pad - nc), (0, 0)), constant_values=1.0)
    return dequantize_pallas(qp, sp, interpret=interpret)[:nc]


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def int8_sr_encode(x_flat: jax.Array, rng: jax.Array,
                   chunk: int = DEFAULT_CHUNK,
                   use_pallas: bool | None = None):
    """Flat f32 [n] -> (q [nc, chunk] int8, scales [nc, 1] f32)."""
    x2d = _to_chunks(x_flat.astype(jnp.float32), chunk)
    u2d = jax.random.uniform(rng, x2d.shape, jnp.float32)
    return quantize_2d(x2d, u2d, use_pallas)


@partial(jax.jit, static_argnames=("n", "use_pallas"))
def int8_dequantize(q: jax.Array, scales: jax.Array, n: int,
                    use_pallas: bool | None = None) -> jax.Array:
    """Inverse of int8_sr_encode: back to flat f32 [n]."""
    return dequantize_2d(q, scales, use_pallas).reshape(-1)[:n]


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def int8_sr_roundtrip(x_flat: jax.Array, rng: jax.Array,
                      chunk: int = DEFAULT_CHUNK,
                      use_pallas: bool | None = None) -> jax.Array:
    """encode + decode in one call — what the comm layer simulates on-wire."""
    q, scales = int8_sr_encode(x_flat, rng, chunk, use_pallas)
    return int8_dequantize(q, scales, x_flat.shape[0], use_pallas)
