"""Pure-jnp oracle for the int8-SR quant kernels (identical arithmetic).

Also the codec's compute path off-TPU: interpret-mode Pallas inside the
vmapped round cores would dominate CPU round time, and this is the same
math op-for-op (see tests/test_kernels.py::TestQuantKernel parity)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array, u: jax.Array):
    """x, u: [nc, C] f32 -> (q [nc, C] int8, scales [nc, 1] f32).

    Per-row symmetric scale max|x|/127; stochastic rounding floor(x/scale + u)
    with u ~ U[0,1), so E[q·scale] = x and |q·scale − x| < scale."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=1, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.floor(x32 / scale + u.astype(jnp.float32))
    q = jnp.clip(q, -127.0, 127.0)
    return q.astype(jnp.int8), scale


def dequantize_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    """q: [nc, C] int8; scales: [nc, 1] f32 -> f32 [nc, C]."""
    return q.astype(jnp.float32) * scales
