"""Op-identical jnp oracle for the fused local-trajectory kernel.

Mirrors ``local_update.py`` operation for operation — same ``link_coeff``
coefficients, same row-vector ``dot_general`` contractions, same cast
points, same emit expression — so a single-row-tile interpret-mode kernel
run is BIT-exact against this reference (pinned in tests/test_local_update).

It doubles as the CPU executor of ``local_impl="pallas"`` (see ops.py):
like the quant codec, interpret-mode Pallas inside a vmapped round core
would dominate CPU round time, while this oracle IS the fused algorithm —
the anchor coefficients of a resident full-batch design are computed once
and every local step costs one forward and one combined backward X sweep
instead of the autodiff path's four.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.local_update.local_update import link_coeff


def _row_dot(a, b):
    """[1, k] · [n, k]ᵀ → [1, n]  (the kernel's forward contraction)."""
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=a.dtype)


def _col_dot(c, x):
    """[1, n] · [n, d] → [1, d]  (the kernel's backward accumulation)."""
    return jax.lax.dot_general(
        c, x, (((1,), (0,)), ((), ())), preferred_element_type=c.dtype)


def trajectory_ref(x, y, mask, w0, u, invn, *, link: str, eta: float,
                   reg: float, anchor_scale: float, steps: int):
    """x: [S, n, d]; y, mask: [S, n]; w0, u: [1, d]; invn: [1, 1] (S ∈ {1,
    steps}).  Returns (w_traj, r_traj), each [steps, d] in w0.dtype —
    exactly ``local_update.trajectory_pallas`` on a single row tile.
    """
    S = x.shape[0]
    if S not in (1, steps):
        raise ValueError(f"S={S} must be 1 or steps={steps}")
    out_dtype = w0.dtype
    compute = jnp.float64 if out_dtype == jnp.float64 else jnp.float32
    eta = jnp.asarray(eta, compute)
    reg = jnp.asarray(reg, compute)
    xc = x.astype(compute)
    yc = y.astype(compute)[:, None, :]       # [S, 1, n]
    mc = mask.astype(compute)[:, None, :]    # [S, 1, n]
    w0c = w0.astype(compute)
    uc = u.astype(compute)
    inv = invn[0, 0].astype(compute)
    anchor = anchor_scale == 1.0

    def residual(w, xs, ys, ms, c_anc):
        c = link_coeff(link, _row_dot(w, xs), ys, ms)
        if anchor:
            c = c - c_anc
        return _col_dot(c, xs) * inv + reg * w + uc

    if S == 1:
        xs, ys, ms = xc[0], yc[0], mc[0]
        # resident design: the anchor coefficients are step-invariant —
        # computed once here, recomputed (bit-identically) per tile visit
        # by the kernel
        c_anc = link_coeff(link, _row_dot(w0c, xs), ys, ms) if anchor else None

        def step(w, _):
            r = residual(w, xs, ys, ms, c_anc)
            return w - eta * r, (w.astype(out_dtype)[0], r.astype(out_dtype)[0])

        _, (w_traj, r_traj) = jax.lax.scan(step, w0c, None, length=steps)
    else:

        def step(w, blk):
            xs, ys, ms = blk
            c_anc = (link_coeff(link, _row_dot(w0c, xs), ys, ms)
                     if anchor else None)
            r = residual(w, xs, ys, ms, c_anc)
            return w - eta * r, (w.astype(out_dtype)[0], r.astype(out_dtype)[0])

        _, (w_traj, r_traj) = jax.lax.scan(step, w0c, (xc, yc, mc))
    return w_traj, r_traj
