from repro.kernels.local_update.ops import (  # noqa: F401
    FUSED_IMPLS,
    fused_trajectory,
)
from repro.kernels.local_update.local_update import (  # noqa: F401
    LINKS,
    link_coeff,
    trajectory_pallas,
)
from repro.kernels.local_update.ref import trajectory_ref  # noqa: F401
