"""Public entry point for the fused local-trajectory kernel family.

``fused_trajectory`` is what the round cores (core/algorithms.py, under
``AlgoHParams.local_impl="pallas"``) call per client: it handles the
lane/sublane granule padding and row-tile sizing, then dispatches to

  * the Pallas kernel (local_update.py) on TPU — native compilation, X
    streamed once per local step (resident across steps when one row tile
    covers the design block);
  * the op-identical jnp oracle (ref.py) elsewhere — the SAME fused
    algorithm (one forward + one combined backward sweep per step, anchor
    coefficients hoisted for resident designs) without the interpret-mode
    emulation tax, exactly like the quant codec's CPU path.

Padded rows carry mask 0 and padded feature lanes are zero, so neither can
influence the trajectories (hypothesis-tested); n pads to the 128-lane
granule (the row axis is the LAST axis of the y/mask blocks) and d to the
128-lane granule.  Interpret-mode kernel runs are for parity tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.local_update.local_update import (
    DEFAULT_ROW_TILE,
    LINKS,
    trajectory_pallas,
)
from repro.kernels.local_update.ref import trajectory_ref

#: execution backends of the fused path ("auto" = kernel on TPU, ref off it)
FUSED_IMPLS = ("auto", "kernel", "ref")

#: module default, monkeypatchable by tests to force the interpret-mode
#: kernel through full rounds
DEFAULT_IMPL = "auto"

#: keep one X row tile comfortably inside VMEM (bytes, f32)
TILE_BUDGET = 2 * 1024 * 1024
#: designs up to this many bytes use ONE row tile — the Pallas pipeline
#: then elides the X re-fetch across local steps (fully resident loop)
RESIDENT_BUDGET = 4 * 1024 * 1024

_ON_TPU = None


def _use_kernel_default() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return _ON_TPU


def _granule(v: int, g: int = 128) -> int:
    return ((v + g - 1) // g) * g


def _pick_row_tile(S: int, n_pad: int, d_pad: int, itemsize: int) -> int:
    """Row-tile height: the whole block when it fits the resident budget
    (S==1 → X stays in VMEM across every local step), else the largest
    128-granule tile inside the per-tile budget."""
    if S == 1 and n_pad * d_pad * itemsize <= RESIDENT_BUDGET:
        return n_pad
    t = max(128, (TILE_BUDGET // max(d_pad * itemsize, 1)) // 128 * 128)
    while n_pad % t:
        t -= 128
    return max(t, 128)


def _pad_axis(a, n, axis):
    pad = n - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def fused_trajectory(x, y, mask, w0, u, *, link: str, reg: float, eta: float,
                     anchor_scale: float, steps: int,
                     impl: str | None = None, interpret: bool | None = None,
                     row_tile: int | None = None):
    """Run ``steps`` fused corrected-GD steps; see local_update.py for the
    math.  x: [S, n, d] with S ∈ {1, steps}; y, mask: [S, n]; w0, u: [d].
    Returns (w_traj, r_traj), each [steps, d] in w0.dtype.
    """
    if link not in LINKS:
        raise ValueError(f"unknown link {link!r}; choose from {LINKS}")
    impl = DEFAULT_IMPL if impl is None else impl
    if impl not in FUSED_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; choose from {FUSED_IMPLS}")
    if impl == "auto":
        impl = "kernel" if _use_kernel_default() else "ref"
    if interpret is None:
        interpret = not _use_kernel_default()
    S, n, d = x.shape
    x = x.astype(w0.dtype)
    # the loss's masked-mean denominator; every step's block has the same
    # valid count (full batch: the one design block; minibatch: B ones).
    # Divide in the COMPUTE dtype (the f32 reciprocal is 1e-8 off, which the
    # AA Gram solve amplifies macroscopically in f64 runs)
    inv_dtype = jnp.float64 if w0.dtype == jnp.float64 else jnp.float32
    invn = (1.0 / jnp.maximum(jnp.sum(mask[0]).astype(inv_dtype),
                              1.0)).reshape(1, 1)
    w0r, ur = w0.reshape(1, d), u.reshape(1, d)

    if impl == "ref":
        return trajectory_ref(x, y, mask, w0r, ur, invn, link=link, eta=eta,
                              reg=reg, anchor_scale=anchor_scale, steps=steps)

    d_pad, n_pad = _granule(d), _granule(n)
    if row_tile is None:
        row_tile = _pick_row_tile(S, n_pad, d_pad, x.dtype.itemsize)
    n_pad = _granule(n_pad, row_tile)
    xp = _pad_axis(_pad_axis(x, n_pad, 1), d_pad, 2).reshape(S * n_pad, d_pad)
    yp = _pad_axis(y, n_pad, 1)
    mp = _pad_axis(mask, n_pad, 1)
    w_traj, r_traj = trajectory_pallas(
        xp, yp, mp, _pad_axis(w0r, d_pad, 1), _pad_axis(ur, d_pad, 1), invn,
        link=link, eta=eta, reg=reg, anchor_scale=anchor_scale, steps=steps,
        row_tile=row_tile, interpret=interpret)
    return w_traj[:, :d], r_traj[:, :d]
