"""Pallas TPU kernels for the fused dual-gradient local trajectory.

Hardware adaptation of the FedOSAA hot loop: for linear-design models
(logistic/linear regression — the paper's workload), one local step of the
variance-reduced GD trajectory is

    r(w) = Xᵀ(c_live(Xw) − a·c_anchor(Xw_t)) / n + γ·w + u
    w   ←  w − η·r

where ``c_live``/``c_anchor`` are the per-sample link derivatives evaluated
at the live iterate and the round anchor, ``a`` selects the SVRG dual-
gradient form (a=1) or the constant-correction form (SCAFFOLD/FedAvg, a=0),
and ``u`` folds every minibatch-independent term (global gradient, control
variates, the anchor's ℓ2 term).  The autodiff path realizes this with TWO
loss autodiffs per step — four X sweeps (forward+backward × live+anchor)
from HBM.  This kernel computes both coefficient vectors from the SAME X
tile and accumulates the single combined backward product, so X streams
ONCE per local step — and when the whole design block fits in VMEM (one row
tile), the Pallas pipeline elides the re-fetch across grid steps entirely:
the L-step loop runs on-chip with X resident.

Layout (one client; the round cores vmap this over K):

    x:    [S·n, d]   design blocks, S stacked on the row axis — S == 1
                     (full batch: every step revisits block 0, which is
                     what keeps it resident) or S == steps (per-step
                     minibatch gathers).  Kept 2-D: the row tile is a plain
                     (row_tile, d) block, bit-identical to the oracle's
                     contractions (a squeezed 3-D block is not)
    y:    [S, n]     targets (±1 for the logistic link)
    mask: [S, n]     0/1 row validity (padded rows contribute exactly 0)
    w0:   [1, d]     start == anchor w^t
    u:    [1, d]     constant additive correction (see above)
    invn: [1, 1]     1 / n_eff (the loss's masked-mean denominator)

Grid is (steps, row_tiles) — row tiles iterate fastest; a VMEM scratch pair
(w_cur, acc) carries the iterate and the gradient accumulator across grid
steps, and the (w_traj, r_traj) history FedOSAA's AA step consumes is
emitted tile-block-locally at the last row tile of every step.

The [steps, d] trajectories, w_cur and the dual logit/coefficient buffers
live in VMEM; only X (once per step, at worst) and the emitted trajectory
rows touch HBM.  ``ref.py`` is the op-identical jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: links the kernel family knows how to differentiate
LINKS = ("logistic", "linear")

#: default row-tile height (lane-granule multiple; see ops.py for sizing)
DEFAULT_ROW_TILE = 512


def link_coeff(link: str, z: jax.Array, y: jax.Array, mask: jax.Array):
    """Per-sample gradient coefficient c(z) with d loss_j/dw = c_j · x_j.

    logistic: loss_j = softplus(−y_j z_j)   → c_j = −y_j σ(−y_j z_j)
    linear:   loss_j = ½ (z_j − y_j)²       → c_j = z_j − y_j

    Shared (re-exported) by ref.py so kernel and oracle stay op-identical.
    """
    if link == "logistic":
        return (-y) * jax.nn.sigmoid(-(z * y)) * mask
    if link == "linear":
        return (z - y) * mask
    raise ValueError(f"unknown link {link!r}; choose from {LINKS}")


def _make_traj_kernel(link: str, eta: float, reg: float, anchor: bool,
                      compute_dtype):
    """Kernel body with the static knobs closed over (baked constants)."""

    def kernel(x_ref, y_ref, m_ref, w0_ref, u_ref, invn_ref,
               wt_ref, rt_ref, wcur, acc):
        i = pl.program_id(1)
        n_tiles = pl.num_programs(1)
        first = jnp.logical_and(pl.program_id(0) == 0, i == 0)

        @pl.when(first)
        def _init():
            wcur[...] = w0_ref[...].astype(compute_dtype)

        @pl.when(i == 0)
        def _zero():
            acc[...] = jnp.zeros_like(acc)

        x = x_ref[...].astype(compute_dtype)        # [Tn, d]
        yv = y_ref[...].astype(compute_dtype)       # [1, Tn]
        mv = m_ref[...].astype(compute_dtype)       # [1, Tn]
        w = wcur[...]                               # [1, d]

        # forward: live logits from the tile already in VMEM ...
        z = jax.lax.dot_general(
            w, x, (((1,), (1,)), ((), ())),
            preferred_element_type=compute_dtype)   # [1, Tn]
        c = link_coeff(link, z, yv, mv)
        if anchor:
            # ... and the anchor logits from the SAME tile — the second
            # gradient of the dual-gradient residual costs no extra X fetch
            z0 = jax.lax.dot_general(
                w0_ref[...].astype(compute_dtype), x,
                (((1,), (1,)), ((), ())),
                preferred_element_type=compute_dtype)
            c = c - link_coeff(link, z0, yv, mv)
        # one combined backward accumulation: both residual contributions
        # ride a single Xᵀ(·) sweep of the tile
        acc[...] += jax.lax.dot_general(
            c, x, (((1,), (0,)), ((), ())),
            preferred_element_type=compute_dtype)   # [1, d]

        @pl.when(i == n_tiles - 1)
        def _emit():
            w_now = wcur[...]
            r = (acc[...] * invn_ref[0, 0].astype(compute_dtype)
                 + reg * w_now + u_ref[...].astype(compute_dtype))
            wt_ref[...] = w_now.astype(wt_ref.dtype)
            rt_ref[...] = r.astype(rt_ref.dtype)
            wcur[...] = w_now - eta * r

    return kernel


def trajectory_pallas(x, y, mask, w0, u, invn, *, link: str, eta: float,
                      reg: float, anchor_scale: float, steps: int,
                      row_tile: int = DEFAULT_ROW_TILE,
                      interpret: bool = False):
    """x: [S·n, d] (S stacked on rows); y, mask: [S, n]; w0, u: [1, d];
    invn: [1, 1].

    S must be 1 (resident full-batch design) or ``steps`` (per-step
    minibatch blocks); n % row_tile == 0.  Returns (w_traj, r_traj), each
    [steps, d] in w0.dtype.
    """
    S, n = y.shape
    d = x.shape[1]
    if x.shape[0] != S * n:
        raise ValueError(f"x rows {x.shape[0]} != S*n = {S}*{n}")
    if S not in (1, steps):
        raise ValueError(f"S={S} must be 1 or steps={steps}")
    if n % row_tile:
        raise ValueError(f"n={n} not a multiple of row_tile={row_tile}")
    if anchor_scale not in (0.0, 1.0):
        raise ValueError(f"anchor_scale must be 0.0 or 1.0, got {anchor_scale}")
    compute_dtype = jnp.float64 if w0.dtype == jnp.float64 else jnp.float32
    n_tiles = n // row_tile
    sidx = (lambda l: l) if S > 1 else (lambda l: 0)
    kernel = _make_traj_kernel(link, float(eta), float(reg),
                               anchor_scale == 1.0, compute_dtype)
    w_traj, r_traj = pl.pallas_call(
        kernel,
        grid=(steps, n_tiles),
        in_specs=[
            pl.BlockSpec((row_tile, d),
                         lambda l, i: (sidx(l) * n_tiles + i, 0)),
            pl.BlockSpec((1, row_tile), lambda l, i: (sidx(l), i)),
            pl.BlockSpec((1, row_tile), lambda l, i: (sidx(l), i)),
            pl.BlockSpec((1, d), lambda l, i: (0, 0)),
            pl.BlockSpec((1, d), lambda l, i: (0, 0)),
            pl.BlockSpec((1, 1), lambda l, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda l, i: (l, 0)),
            pl.BlockSpec((1, d), lambda l, i: (l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((steps, d), w0.dtype),
            jax.ShapeDtypeStruct((steps, d), w0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), compute_dtype),   # w_cur
            pltpu.VMEM((1, d), compute_dtype),   # gradient accumulator
        ],
        interpret=interpret,
    )(x, y, mask, w0, u, invn)
    return w_traj, r_traj
