"""Public wrapper: model-layout flash attention with GQA + padding handling.

Takes [B, S, H, hd] tensors (the model's layout), maps GQA kv heads to q
heads, pads S to the block granule (padded keys are masked out via the causal
structure: pad queries produce garbage rows that are sliced away, pad keys
sit at positions > every real query and are causally invisible).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_pallas,
)

_ON_CPU = None


def _interpret_default() -> bool:
    global _ON_CPU
    if _ON_CPU is None:
        _ON_CPU = jax.devices()[0].platform != "tpu"
    return _ON_CPU


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    """q: [B, S, H, hd]; k, v: [B, S, KV, hd] with H % KV == 0 (GQA).
    Returns [B, S, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    S_pad = ((S + max(bq, bk) - 1) // max(bq, bk)) * max(bq, bk)
    if S_pad != S:
        pad = ((0, 0), (0, S_pad - S), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S_pad, hd)

    out = flash_attention_pallas(
        to_bh(q), to_bh(k), to_bh(v), causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    out = out.reshape(B, H, S_pad, hd).transpose(0, 2, 1, 3)
    return out[:, :S]
