"""Pallas TPU flash attention (causal + sliding-window).

Online-softmax tiling: grid (B·H, Sq/bq, Sk/bk); the k-block axis is the
fastest (sequential on TPU), with running max / normalizer / accumulator kept
in VMEM scratch across k-steps. Non-contributing blocks (beyond the causal
frontier or before the sliding window) are skipped via ``pl.when``.

Block shapes default to (128, head_dim): MXU-aligned (128 lanes) and small
enough that q, k, v, scores and the accumulator fit VMEM comfortably
(≈ 128·128·4·5 ≈ 0.3 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, window: int, causal: bool):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk

    # block-level skip: entirely above the causal diagonal, or entirely
    # behind the sliding window
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [bq, d]
        k = k_ref[0].astype(jnp.float32)               # [bk, d]
        v = v_ref[0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (1.0 / (q.shape[-1] ** 0.5))               # [bq, bk]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                # rescale of old state
        p = jnp.exp(s - m_new)                         # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: [BH, S, d] (batch×heads flattened; GQA mapping upstream).
    S must be divisible by the block sizes (ops.py pads)."""
    BH, S, d = q.shape
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, window=window, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
