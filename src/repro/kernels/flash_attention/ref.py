"""Pure-jnp oracle for flash attention (materializes the full score matrix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: [BH, S, d]. Returns [BH, S, d]."""
    S = q.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (q.shape[-1] ** 0.5)
    i = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (i[None, :] <= i[:, None])
    if window > 0:
        mask = mask & (i[None, :] > i[:, None] - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
