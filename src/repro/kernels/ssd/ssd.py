"""Pallas TPU kernel for the Mamba2 SSD intra-chunk compute.

The SSD chunked algorithm (arXiv:2405.21060 §6) splits the recurrence into an
intra-chunk quadratic part (this kernel) and an inter-chunk associative scan
(stays in jnp — it is O(S/Q) tiny). The quadratic part is the FLOPs hot spot:
per (batch, chunk, head) it builds the [Q, Q] decay-masked attention-like
matrix and two small matmuls.

Grid: (B·nc, nh) — one (chunk, head) tile per step. VMEM at Q=256, hd=64,
st=128: decay+cb [Q,Q] f32 ≈ 0.5 MB, well within budget; all matmul operands
are 128-lane aligned for the MXU when Q and st are multiples of 128 (the
model's chunk=256, st∈{64,128} satisfy this; ops.py pads st=64 to 128 lanes
implicitly via the layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, st_ref, *,
                      q_len: int):
    """Blocks (leading grid dims dropped):
    x_ref:  [Q, hd]   inputs for this (chunk, head)
    dt_ref: [Q, 1]    softplus'd dt
    da_ref: [Q, 1]    within-chunk cumsum of dt·A  (negative, decreasing)
    b_ref:  [Q, st]   B_t  (shared across heads; duplicated per grid step)
    c_ref:  [Q, st]   C_t
    y_ref:  [Q, hd]   intra-chunk output
    st_ref: [hd, st]  chunk final state contribution
    """
    x = x_ref[0, 0].astype(jnp.float32)      # [Q, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)     # [Q, 1]
    da = da_ref[0, 0].astype(jnp.float32)     # [Q, 1]
    B = b_ref[0].astype(jnp.float32)          # [Q, st]
    C = c_ref[0].astype(jnp.float32)

    # decay L[i,j] = exp(da_i − da_j) for i ≥ j; mask BEFORE exp (grad safety)
    seg = da - da.reshape(1, q_len)                         # [Q, Q]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    )
    decay = jnp.exp(jnp.where(causal, seg, NEG_INF))

    cb = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                       # [Q, Q]
    att = cb * decay
    xdt = x * dt                                            # [Q, hd]
    y_ref[0, 0] = jax.lax.dot_general(
        att, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    # chunk state: Σ_j exp(da_last − da_j) · dt_j · B_j ⊗ x_j  -> [hd, st]
    decay_last = jnp.exp(da[q_len - 1:q_len, :] - da.reshape(1, q_len))  # [1, Q]
    w = (dt.reshape(1, q_len) * decay_last)                 # [1, Q]
    xw = x * w.reshape(q_len, 1)                            # [Q, hd]
    st_ref[0, 0] = jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(st_ref.dtype)


def ssd_chunk_pallas(x, dt, da_cumsum, B, C, interpret: bool = False):
    """x: [G, nh, Q, hd]; dt/da_cumsum: [G, nh, Q]; B, C: [G, Q, st]
    (G = batch·n_chunks). Returns (y [G, nh, Q, hd], state [G, nh, hd, st])."""
    G, nh, Q, hd = x.shape
    st = B.shape[-1]
    kernel = functools.partial(_ssd_chunk_kernel, q_len=Q)
    y, state = pl.pallas_call(
        kernel,
        grid=(G, nh),
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, Q, st), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, Q, st), lambda g, h: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, hd, st), lambda g, h: (g, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, nh, Q, hd), jnp.float32),
            jax.ShapeDtypeStruct((G, nh, hd, st), jnp.float32),
        ],
        interpret=interpret,
    )(
        x,
        dt[..., None],
        da_cumsum[..., None],
        B, C,
    )
    return y, state
