"""Pure-jnp oracle for the SSD intra-chunk kernel (mirrors the default branch
of models/layers._ssd_chunked_scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(xc, dtc, dA_cumsum, Bc, Cc):
    """xc: [B,nc,Q,nh,hd]; dtc/dA_cumsum: [B,nc,Q,nh]; Bc/Cc: [B,nc,Q,st].
    Returns (y_diag [B,nc,Q,nh,hd], chunk_state [B,nc,nh,hd,st])."""
    Q = xc.shape[2]
    seg = dA_cumsum[:, :, :, None, :] - dA_cumsum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(causal, seg, -1e30))
    cb = jnp.einsum("bcqs,bcks->bcqk", Cc, Bc)
    att = cb[..., None] * decay
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bcqkh,bckhd->bcqhd", att, xdt)
    decay_last = jnp.exp(dA_cumsum[:, :, -1:, :] - dA_cumsum)
    chunk_state = jnp.einsum("bcqs,bcqh,bcqhd->bchds", Bc, dtc * decay_last, xc)
    return y_diag, chunk_state
