"""Public wrapper: adapts the Pallas SSD chunk kernel to the model's
``ssd_fn`` interface (models/layers._ssd_chunked_scan)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import ssd_chunk_pallas

_ON_CPU = None


def _interpret_default() -> bool:
    global _ON_CPU
    if _ON_CPU is None:
        _ON_CPU = jax.devices()[0].platform != "tpu"
    return _ON_CPU


@partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xc, dtc, dA_cumsum, Bc, Cc, interpret: bool | None = None):
    """Model-layout entry point — drop-in ``ssd_fn`` for build_model.

    xc: [B,nc,Q,nh,hd]; dtc/dA_cumsum: [B,nc,Q,nh]; Bc/Cc: [B,nc,Q,st].
    """
    if interpret is None:
        interpret = _interpret_default()
    B, nc, Q, nh, hd = xc.shape
    st = Bc.shape[-1]
    G = B * nc
    x = xc.transpose(0, 1, 3, 2, 4).reshape(G, nh, Q, hd)
    dt = dtc.transpose(0, 1, 3, 2).reshape(G, nh, Q)
    da = dA_cumsum.transpose(0, 1, 3, 2).reshape(G, nh, Q)
    Bg = Bc.reshape(G, Q, st)
    Cg = Cc.reshape(G, Q, st)
    y, state = ssd_chunk_pallas(x, dt, da, Bg, Cg, interpret=interpret)
    y_diag = y.reshape(B, nc, nh, Q, hd).transpose(0, 1, 3, 2, 4)
    chunk_state = state.reshape(B, nc, nh, hd, st)
    return y_diag, chunk_state
