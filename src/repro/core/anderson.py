"""Anderson acceleration core (paper §2.2, Eq. 2–7).

Everything is pytree-native: history stacks S, Y are pytrees whose leaves carry
a leading history axis [m, ...]; the only dense objects are the [m, m] Gram
matrix and length-m coefficient vectors, so the same code path serves a
300-parameter logistic regression and a tensor-parallel 76B transformer
(where each Gram contraction compiles to per-shard matmuls + a psum).

Two mathematically equivalent formulations are provided:

* ``aa_mixing_step``   — the classical constrained-LS mixing form (Eq. 2–3),
* ``multisecant_update`` — the quasi-Newton form actually used by FedOSAA
  (Eq. 4–5 / Algorithm 1 Eq. 7):

      w⁺ = w − H⁻¹ g,   H⁻¹ = ηI + (S − ηY)(YᵀY)⁻¹Yᵀ .

Stability options from paper Appendix A are first-class:
Tikhonov regularization of the Gram system, spectral filtering of nearly
linearly-dependent Y columns (Pollock & Rebholz 2023, adapted to fixed-shape
jit via truncated-eigenvalue pseudo-inverse), and damping of the quasi-Newton
correction (Wei et al. 2021).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_math as tm

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AAConfig:
    """Knobs for one Anderson-acceleration step.

    Attributes:
      tikhonov: relative Tikhonov regularization λ; the Gram system solved is
        (YᵀY + λ·tr(YᵀY)/m·I). 0 disables. Paper default experiments use 0
        (f64 on CPU); we default to 1e-10 which is invisible at f32 scale but
        guards rank-deficient trajectories.
      filter_rtol: drop (zero out) eigen-directions of the Gram matrix whose
        eigenvalue is below filter_rtol × λ_max — the jit-friendly analogue of
        column filtering [34]. 0 disables.
      damping: scale on the quasi-Newton correction term (S−ηY)Γ. 1.0 = paper.
      min_history: below this many valid columns the AA step falls back to the
        plain damped-gradient step (returned unchanged).
      clip_rtol: byzantine-column screen — drop history columns whose residual
        norm ‖y_i‖ exceeds median(‖y‖)/clip_rtol before the Gram solve (a
        column is kept iff clip_rtol·‖y_i‖ ≤ median). The median is the robust
        scale: with ≤ half the columns poisoned it sits at the clean scale, so
        one stale/byzantine column (which can otherwise steer the extrapolation
        arbitrarily through (YᵀY)Γ = Yᵀg) is screened out and the step degrades
        toward the plain damped-gradient step instead of diverging. Values in
        (0, 1] keep at least half the columns (0.1 ≈ "drop columns 10× the
        median"). 0 disables — and is an exact no-op: the default path's
        compiled graph is unchanged. The same screen doubles as an AGE
        screen under the deadline gate (repro.robust.async_agg): a
        stale-folded client's residual columns drift off the cohort median
        and get clipped the same way — the measured alternative to
        ``AsyncConfig.guard_history``, which instead bit-freezes the
        folded rows' history writes (benchmarks/ext_async.py records both;
        at the committed scale they converge in the same round count).
    """

    tikhonov: float = 1e-10
    filter_rtol: float = 0.0
    damping: float = 1.0
    min_history: int = 1
    residual_ema: float = 0.0   # EMA over residuals before building Y
                                # (Pasini et al. [28]; App. A option 3) —
                                # smooths stochastic-gradient noise that
                                # otherwise stalls AA at the noise floor
    clip_rtol: float = 0.0


class AAStats(NamedTuple):
    """Diagnostics of one AA step (all scalars)."""

    theta: jax.Array          # optimization gain ‖(I−Proj_Y)g‖/‖g‖  (Eq. 9)
    gamma_norm: jax.Array     # ‖Γ‖ of the LS solution
    gram_cond: jax.Array      # rough condition estimate of the Gram matrix
    used_columns: jax.Array   # how many eigen-directions survived filtering
    clipped_columns: jax.Array  # history columns dropped by the clip_rtol
                                # residual screen (0 when the screen is off)


def _solve_gram(gram: jax.Array, rhs: jax.Array, cfg: AAConfig,
                col_mask: jax.Array | None = None):
    """Solve (YᵀY) Γ = Yᵀg robustly; returns (Γ, stats pieces).

    Uses a symmetric eigendecomposition so filtering and conditioning fall out
    for free. m is tiny (≤ local epochs L), so this is negligible work.

    col_mask (bool [m], optional) zeroes the masked columns out of the system
    entirely — their Gram rows/cols, their rhs entries, AND their Tikhonov
    diagonal — so a screened column contributes exactly nothing to Γ and does
    not count toward used_columns (its eigenvalue is exactly 0 and falls to
    the near-zero guard).

    Degenerate systems are well-defined, never NaN: if filtering plus the
    near-zero guard drop every direction (all-filtered, or a rank-0 Gram from
    identical history columns) then Γ is exactly 0 — the caller's update
    degrades bit-exactly to the plain damped-gradient step — and cond reports
    1.0 (a zero system is not ill-conditioned, it is empty).
    """
    m = gram.shape[0]
    tik_diag = jnp.eye(m, dtype=gram.dtype)
    if col_mask is not None:
        # select, don't multiply: a byzantine column can carry inf/nan Gram
        # entries and 0·inf = nan would leak the poison back into the masked
        # system; jnp.where zeroes the row/column unconditionally
        cm2 = jnp.logical_and(col_mask[:, None], col_mask[None, :])
        gram = jnp.where(cm2, gram, 0.0)
        rhs = jnp.where(col_mask, rhs, 0.0)
        tik_diag = jnp.where(col_mask[:, None], tik_diag, 0.0)
    trace = jnp.trace(gram)
    lam = cfg.tikhonov * trace / m
    evals, evecs = jnp.linalg.eigh(gram + lam * tik_diag)
    evals = jnp.maximum(evals, 0.0)
    emax = jnp.max(evals)
    keep = evals > cfg.filter_rtol * emax
    # guard: never invert a (near-)zero eigenvalue even when filtering is off
    safe = evals > 1e-30 * jnp.maximum(emax, 1e-30)
    keep = jnp.logical_and(keep, safe)
    inv = jnp.where(keep, 1.0 / jnp.where(keep, evals, 1.0), 0.0)
    gamma = evecs @ (inv * (evecs.T @ rhs))
    used = jnp.sum(keep)
    emin_kept = jnp.min(jnp.where(keep, evals, emax))
    cond = jnp.where(used > 0, emax / jnp.maximum(emin_kept, 1e-30), 1.0)
    return gamma, cond, used


def _residual_clip_mask(gram: jax.Array, cfg: AAConfig) -> jax.Array:
    """Bool [m] keep-mask for the clip_rtol byzantine-column screen.

    The per-column residual norms ‖y_i‖ are read off the Gram diagonal (so the
    screen is identical for the tree and pallas paths, which both have the
    accumulated Gram in hand), and compared against the jit-friendly robust
    scale median(‖y‖): keep iff clip_rtol·‖y_i‖ ≤ median. Non-finite columns
    (an overflowed byzantine column drives ‖y‖² past f32 max) are always
    dropped and excluded from the median so they cannot poison the scale
    estimate itself.
    """
    norms = jnp.sqrt(jnp.maximum(jnp.diagonal(gram), 0.0))
    finite = jnp.isfinite(norms)
    med = jnp.nanmedian(jnp.where(finite, norms, jnp.nan))
    return jnp.logical_and(finite, cfg.clip_rtol * norms <= med)


def _screened_solve(gram: jax.Array, rhs: jax.Array, cfg: AAConfig):
    """clip_rtol screen (python-gated: off → graph unchanged) + Gram solve.

    Returns (Γ, cond, used_columns, clipped_columns, keep_cols). keep_cols is
    None when the screen is off; when it is a mask, callers MUST also zero the
    screened columns out of their own downstream contractions (YΓ, Yᵀg·Γ):
    Γ's masked entries are exactly 0, but the contraction kernels run in f32
    where an overflowed byzantine column is ±inf and 0·inf = nan — the poison
    must never reach a matmul at all.
    """
    if cfg.clip_rtol > 0.0:
        keep_cols = _residual_clip_mask(gram, cfg)
        clipped = (gram.shape[0] - jnp.sum(keep_cols)).astype(jnp.int32)
        gamma, cond, used = _solve_gram(gram, rhs, cfg, keep_cols)
        return gamma, cond, used, clipped, keep_cols
    clipped = jnp.zeros((), jnp.int32)
    gamma, cond, used = _solve_gram(gram, rhs, cfg)
    return gamma, cond, used, clipped, None


def _mask_stack_columns(stack: Pytree, keep: jax.Array) -> Pytree:
    """Zero the non-kept history columns of a stacked pytree ([m, ...] leaves)."""
    return jax.tree.map(
        lambda l: jnp.where(
            keep.reshape((-1,) + (1,) * (l.ndim - 1)), l,
            jnp.zeros((), l.dtype)),
        stack)


#: legal values of the AA-step implementation knob (AlgoHParams.aa_impl)
AA_IMPLS = ("auto", "tree", "pallas")


def resolve_aa_impl(impl: str, runtime: str = "vmap") -> str:
    """Resolve the ``aa_impl`` knob to a concrete implementation.

    "auto" picks the fused Pallas kernels where they compile natively (TPU)
    and the pytree path elsewhere. The sharded runtime ALWAYS resolves to
    "tree" — its leaves may be sharded across the mesh, where leaf-wise
    contraction (see tree_math sharding notes) is the correct hot path —
    so an explicit "pallas" falls back without error, as documented.
    """
    if impl not in AA_IMPLS:
        raise ValueError(f"unknown aa_impl {impl!r}; choose from {AA_IMPLS}")
    if runtime == "sharded":
        return "tree"
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "tree"
    return impl


def multisecant_update(
    w: Pytree,
    g: Pytree,
    s_stack: Pytree,
    y_stack: Pytree,
    eta: float,
    cfg: AAConfig = AAConfig(),
    impl: str = "tree",
) -> tuple[Pytree, AAStats]:
    """FedOSAA's one-step AA update (Algorithm 1, lines 15–18).

    Args:
      w: anchor point w^t (pytree).
      g: the gradient the update is taken against — ∇f(w^t) for FedOSAA-SVRG,
         the server control variate c for FedOSAA-SCAFFOLD.
      s_stack / y_stack: histories with leading axis m:
         s_ℓ = w_{ℓ+1} − w_ℓ,  y_ℓ = r_{ℓ+1} − r_ℓ  (r = corrected gradients).
      eta: local learning rate η.
      impl: "tree" (leaf-wise tree_math contractions — streams S/Y three
         times, but keeps sharded leaves sharded), "pallas" (ravel into
         per-dtype flat buffers and run the single-pass fused Gram/update
         kernels from kernels/anderson — the vmap-runtime hot path), or
         "auto" (pallas on TPU, tree elsewhere).

    Returns (w⁺, stats) with
      w⁺ = w − η g − damping · (S − ηY) Γ + ... ,  Γ = (YᵀY)⁻¹ Yᵀ g.
    """
    with jax.named_scope("fl.aa_step"):
        if resolve_aa_impl(impl) == "pallas":
            return _multisecant_update_pallas(w, g, s_stack, y_stack, eta, cfg)
        gram = tm.tree_gram(y_stack, y_stack)          # [m, m] YᵀY
        yg = tm.tree_vdot_stacked(y_stack, g)          # [m]    Yᵀg
        gamma, cond, used, clipped, keep = _screened_solve(gram, yg, cfg)
        if keep is not None:
            y_stack = _mask_stack_columns(y_stack, keep)
            s_stack = _mask_stack_columns(s_stack, keep)
            yg = jnp.where(keep, yg, 0.0)

        # optimization gain θ² = 1 − (Yᵀg·Γ)/‖g‖²   (Eq. 9, via Pythagoras)
        g_norm2 = tm.tree_dot(g, g)
        proj2 = jnp.dot(yg, gamma)
        theta = jnp.sqrt(
            jnp.clip(1.0 - proj2 / jnp.maximum(g_norm2, 1e-30), 0.0, 1.0))

        s_gamma = tm.tree_combine_stacked(s_stack, gamma)   # S Γ
        y_gamma = tm.tree_combine_stacked(y_stack, gamma)   # Y Γ

        beta = cfg.damping
        new_w = jax.tree.map(
            lambda wi, gi, sg, yg_: wi - eta * gi - beta * (sg - eta * yg_),
            w, g, s_gamma, y_gamma,
        )
        stats = AAStats(theta=theta, gamma_norm=jnp.linalg.norm(gamma),
                        gram_cond=cond, used_columns=used,
                        clipped_columns=clipped)
        return new_w, stats


def _multisecant_update_pallas(
    w: Pytree, g: Pytree, s_stack: Pytree, y_stack: Pytree,
    eta: float, cfg: AAConfig,
) -> tuple[Pytree, AAStats]:
    """Fused AA step: same math and stats as the tree path, via the
    single-pass Pallas kernels on per-dtype flat buffers.

    The leaves are grouped by dtype and each group raveled once into a
    [m, d_g] buffer; the Gram system accumulates ACROSS groups (YᵀY is a sum
    over all components, so per-group Grams add exactly), the [m,m] solve —
    including Tikhonov/filtering, shared with the tree path via _solve_gram —
    happens once, and each group streams through the update kernel. S and Y
    are read once per pass instead of the tree path's three HBM sweeps.
    """
    from repro.kernels.anderson import ops

    w_leaves, treedef = jax.tree.flatten(w)
    g_leaves = jax.tree.leaves(g)
    s_leaves = jax.tree.leaves(s_stack)
    y_leaves = jax.tree.leaves(y_stack)
    m = y_leaves[0].shape[0]
    groups = ops.dtype_leaf_groups(w)

    gram = jnp.zeros((m, m), jnp.float32)
    yg = jnp.zeros((m,), jnp.float32)
    g_norm2 = jnp.zeros((), jnp.float32)
    flats = []
    for _, idxs in groups:
        wf = ops.ravel_group(w_leaves, idxs)
        gf = ops.ravel_group(g_leaves, idxs)
        sf = ops.ravel_stack_group(s_leaves, idxs)
        yf = ops.ravel_stack_group(y_leaves, idxs)
        gm, ygv = ops.flat_gram(yf, gf)
        gram += gm
        yg += ygv
        gf32 = gf.astype(jnp.float32)
        g_norm2 += jnp.dot(gf32, gf32)
        flats.append((idxs, wf, gf, sf, yf))

    gamma, cond, used, clipped, keep = _screened_solve(gram, yg, cfg)
    if keep is not None:
        yg = jnp.where(keep, yg, 0.0)
    proj2 = jnp.dot(yg, gamma)
    theta = jnp.sqrt(jnp.clip(1.0 - proj2 / jnp.maximum(g_norm2, 1e-30), 0.0, 1.0))

    out_leaves = list(w_leaves)
    for idxs, wf, gf, sf, yf in flats:
        if keep is not None:
            # see _screened_solve: a screened column must not reach the f32
            # update matmul (0·inf = nan)
            sf = jnp.where(keep[:, None], sf, jnp.zeros((), sf.dtype))
            yf = jnp.where(keep[:, None], yf, jnp.zeros((), yf.dtype))
        of = ops.flat_update(wf, gf, sf, yf, gamma, eta, cfg.damping)
        ops.unravel_group_into(of, w_leaves, idxs, out_leaves)
    new_w = jax.tree.unflatten(treedef, out_leaves)
    stats = AAStats(theta=theta, gamma_norm=jnp.linalg.norm(gamma),
                    gram_cond=cond, used_columns=used,
                    clipped_columns=clipped)
    return new_w, stats


def aa_mixing_step(
    w_hist: Pytree,
    r_hist: Pytree,
    cfg: AAConfig = AAConfig(),
) -> tuple[Pytree, jax.Array]:
    """Classical AA mixing (Eq. 2–3) on stacked histories (newest first).

    w_hist, r_hist: pytrees with leading axis m+1 of iterates w^{t-i} and
    residuals r(w^{t-i}).  Solves the sum-to-one constrained LS for α, returns
      w⁺ = Σ αᵢ (w^{t-i} + r^{t-i})            and α.

    Provided for the property test asserting equivalence with
    ``multisecant_update`` (they are algebraically the same update), and as a
    reference implementation for readers of the paper.
    """
    # Reduce the constrained problem to an unconstrained one in differences:
    # α = e₀ − ... standard trick: with F = [r₀, …, r_m], minimize ‖F α‖ s.t.
    # Σα=1. Substitute α = e₀ + Dξ where D maps ξ∈R^m to differences.
    def diffs(stack):
        return jax.tree.map(lambda s: s[1:] - s[:-1], stack)   # [m, ...]

    dR = diffs(r_hist)   # rows: r^{t-i-1}−r^{t-i} ... sign convention immaterial
    r0 = tm.tree_unstack_index(r_hist, 0)
    gram = tm.tree_gram(dR, dR)
    rhs = tm.tree_vdot_stacked(dR, r0)
    xi, _, _ = _solve_gram(gram, rhs, cfg)
    # α₀ = 1 − Σ contributions handled implicitly:
    w0 = tm.tree_unstack_index(w_hist, 0)
    dW = diffs(w_hist)
    w_corr = tm.tree_combine_stacked(dW, xi)
    r_corr = tm.tree_combine_stacked(dR, xi)
    new_w = jax.tree.map(
        lambda wi, ri, wc, rc: wi + ri - (wc + rc), w0, r0, w_corr, r_corr
    )
    # recover alpha for diagnostics: α = e0 - scatter(xi diffs)
    m = xi.shape[0]
    alpha = jnp.zeros(m + 1).at[0].set(1.0)
    alpha = alpha.at[:-1].add(-xi).at[1:].add(xi)
    return new_w, alpha


def trajectory_to_sy(
    w_traj: Pytree, r_traj: Pytree, residual_ema: float = 0.0
) -> tuple[Pytree, Pytree]:
    """Build S, Y stacks from a local trajectory.

    w_traj: [L+1, ...] iterates w_{k,0..L};  r_traj: [L+1, ...] corrected
    gradients r_{k,0..L}.  Returns S, Y with leading axis L.

    residual_ema > 0 smooths the residual sequence with an exponential
    moving average before differencing (beyond-paper stabilizer for
    stochastic gradients; paper App. A / [28]).
    """
    if residual_ema > 0.0:
        rho = residual_ema

        def smooth(t):
            def step(prev, cur):
                new = rho * prev + (1 - rho) * cur
                return new, new
            _, smoothed = jax.lax.scan(step, t[0], t[1:])
            return jnp.concatenate([t[:1], smoothed], axis=0)

        r_traj = jax.tree.map(smooth, r_traj)
    s = jax.tree.map(lambda t: t[1:] - t[:-1], w_traj)
    y = jax.tree.map(lambda t: t[1:] - t[:-1], r_traj)
    return s, y


def lbfgs_two_loop(
    g: Pytree, s_stack: Pytree, y_stack: Pytree, eta: float
) -> Pytree:
    """Classic L-BFGS two-loop recursion over the SAME S/Y data FedOSAA uses.

    This is the paper's 'one-step L-BFGS' baseline (Appendix D.1): collect
    local points as in FedOSAA, then apply H_lbfgs⁻¹ to g. History axis is m,
    oldest first (index 0 = s_0 from the first local step).
    """
    m = jax.tree.leaves(s_stack)[0].shape[0]

    def si(i):
        return tm.tree_unstack_index(s_stack, i)

    def yi(i):
        return tm.tree_unstack_index(y_stack, i)

    q = g
    alphas = []
    rhos = []
    # first loop: newest -> oldest
    for i in range(m - 1, -1, -1):
        sy = tm.tree_dot(si(i), yi(i))
        rho = 1.0 / jnp.where(jnp.abs(sy) < 1e-30, jnp.inf, sy)
        a = rho * tm.tree_dot(si(i), q)
        q = tm.tree_axpy(-a, yi(i), q)
        alphas.append(a)
        rhos.append(rho)
    alphas.reverse()
    rhos.reverse()
    # initial Hessian scaling γ = s·y/y·y of the newest pair; fall back to η
    sy_last = tm.tree_dot(si(m - 1), yi(m - 1))
    yy_last = tm.tree_dot(yi(m - 1), yi(m - 1))
    gamma0 = jnp.where(yy_last > 1e-30, sy_last / jnp.maximum(yy_last, 1e-30), eta)
    r = tm.tree_scale(gamma0, q)
    # second loop: oldest -> newest
    for i in range(m):
        b = rhos[i] * tm.tree_dot(yi(i), r)
        r = tm.tree_axpy(alphas[i] - b, si(i), r)
    return r
