"""Federated training driver: the server-side orchestration loop.

``run_federated`` is the single entry point used by the examples and every
benchmark. It compiles one round of the chosen algorithm and iterates it,
collecting the metric history the paper plots (relative error vs. aggregation
round, communication, wall time).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import AlgoHParams, init_state, make_round_fn
from repro.core.problem import FLProblem
from repro.utils import tree_math as tm

Pytree = Any


@dataclasses.dataclass
class History:
    algo: str
    rounds: np.ndarray            # [T]
    loss: np.ndarray              # f(w^t)
    grad_norm: np.ndarray
    rel_error: np.ndarray         # ‖w^t − w*‖/‖w*‖  (nan if w* not given)
    theta_mean: np.ndarray        # AA gain per round (nan for non-AA algos)
    comm_bytes: np.ndarray        # cumulative bytes on the wire (codec-exact)
    wall_time: np.ndarray         # cumulative seconds (per-round, measured)
    final_params: Pytree = None
    channel: str = "identity"     # repro/comm channel name
    gram_cond_max: np.ndarray = None  # worst AA Gram conditioning per round
                                  # (nan for non-AA algos) — the divergence
                                  # predictor, kept in the history so plots
                                  # and logs can correlate it with rel_error
    arrivals: np.ndarray = None   # deadline-gated landings per round (nan
                                  # everywhere when async_cfg is off)
    staleness_mean: np.ndarray = None  # mean landed buffer age (nan if n/a)
    staleness_max: np.ndarray = None   # oldest landed buffer age (nan if n/a)

    @property
    def comm_floats(self) -> np.ndarray:
        """fp32-equivalent floats (bytes/4) — the paper's Table 1 unit, kept
        so historical comparisons (table1_comm.json) stay directly readable.
        Equal to the old float counters on the identity channel."""
        return self.comm_bytes / 4.0

    def summary(self) -> str:
        last = -1
        gcond = (f"gcond={self.gram_cond_max[last]:.2e} "
                 if self.gram_cond_max is not None
                 and len(self.gram_cond_max) else "")
        return (
            f"{self.algo:18s} rounds={len(self.rounds):4d} "
            f"loss={self.loss[last]:.6e} |g|={self.grad_norm[last]:.3e} "
            f"relerr={self.rel_error[last]:.3e} {gcond}"
            f"comm={self.comm_bytes[last]:.3e}B[{self.channel}] "
            f"wall={self.wall_time[last]:.2f}s"
        )


def checkpoint_config_fingerprint(algo: str, runtime: str, channel_name: str,
                                  num_clients: int, cohort_size: int,
                                  faults=None, async_cfg=None) -> dict:
    """The run-identity dict embedded in every checkpoint manifest and
    demanded back at resume: a checkpoint written under one algorithm /
    runtime / channel / cohort / fault schedule / async gate must not be
    silently continued under another (the carried AA history, EF residuals
    and buffers would be statistically meaningless). JSON-normalized so the
    comparison survives the manifest's serialization round-trip."""
    fp = {
        "algo": algo,
        "runtime": runtime,
        "channel": channel_name,
        "num_clients": int(num_clients),
        "cohort_size": int(cohort_size) if cohort_size is not None else None,
        "faults": dataclasses.asdict(faults) if faults is not None else None,
        "async": dataclasses.asdict(async_cfg)
        if async_cfg is not None else None,
    }
    return json.loads(json.dumps(fp))


def run_federated(
    problem: FLProblem,
    algo: str,
    hp: AlgoHParams,
    num_rounds: int,
    rng: jax.Array | int = 0,
    w_star: Pytree | None = None,
    w0: Pytree | None = None,
    stop_rel_error: float | None = None,
    stop_grad_norm: float | None = None,
    runtime: str = "vmap",
    mesh=None,
    channel=None,
    chunk: int | None = None,
    sinks=(),
    trace_capture=None,
    tap=None,
    faults=None,
    async_cfg=None,
    checkpoint=None,
    resume=None,
    checkpoint_fs=None,
) -> History:
    """Iterate ``num_rounds`` of ``algo`` and collect the metric history.

    runtime — "vmap" (default): the K clients are vmapped on one device;
              "sharded": the client fan-out runs under shard_map over the
              ("pod","data") axes of ``mesh`` (core/sharded.py). ``mesh``
              defaults to launch/mesh.py::make_host_mesh() so the sharded
              runtime is exercisable on a 1-device CPU.
    channel — repro/comm wire-compression channel (a CommChannel or a spec
              string like "int8", "topk:0.05", "bf16/bf16"); None = lossless
              fp32. Both runtimes honor it, and ``History.comm_bytes`` counts
              exactly what the chosen codecs put on the wire.
    chunk   — None (default): the per-round loop — one jit dispatch and one
              host metric sync per round. chunk >= 1: the device-resident
              round engine (core/engine.py) compiles ``chunk`` rounds into
              one lax.scan jit with DONATED state, stacks metrics on device,
              and evaluates the stop criteria in-graph, syncing the host
              once per chunk. The History rows are identical either way
              (tests/test_engine.py, rtol 1e-6); only the wall_time
              attribution differs — the engine divides each chunk's measured
              time equally over its rounds.

    Telemetry (repro/obs — all optional and off by default; sinks and
    trace_capture are bit-neutral — attaching them leaves the computed
    rounds bit-identical, pinned in tests/test_obs.py. The tap is the one
    exception: it compiles a callback into the chunk and matches the tapless
    run at rtol 1e-6, see make_chunk_runner):
    sinks         — MetricsSinks (obs/sinks) opened with a run header
                    (algo/runtime/channel/cohort/per-UplinkSpec byte
                    breakdown), fed one versioned row per executed round —
                    at chunk boundaries on the engine path, per round on the
                    loop path — and closed with a footer. A sink exposing a
                    truthy ``stop_requested`` (obs/alarms.AlarmMonitor) stops
                    the run at the next boundary.
    trace_capture — obs/profiling.TraceCapture: on-demand jax.profiler trace
                    windows around chunk (or round) execution.
    tap           — live in-chunk jax.debug.callback (obs/sinks.LiveTap);
                    engine path only.
    faults        — repro/robust.FaultPlan: inject the plan's dropout/stale/
                    byzantine/DP perturbations inside the compiled round on
                    either runtime (None or an inactive plan compiles the
                    exact fault-free graph). Stale-update plans attach the
                    per-client lagged-anchor rows to the comm state here, so
                    they ride the cohort gather/scatter and checkpoints like
                    any other per-client buffer.
    async_cfg     — repro.robust.async_agg.AsyncConfig: replace the barriered
                    round close with the deadline gate — only clients whose
                    realized latency (``faults.latency_*``) beats the
                    deadline land each round; late updates park in per-client
                    buffer rows (attached to the comm state here, riding
                    gather/scatter and checkpoints) and fold in later with
                    staleness-discounted weight. None or ``deadline == 0``
                    compiles the byte-identical synchronous graph on either
                    runtime. ``History.arrivals``/``staleness_*`` surface the
                    gate's per-round activity.
    checkpoint    — checkpoint/policy.CheckpointPolicy: preemption-tolerant
                    saves of the full ServerState (params + control variates
                    + AA history + codec EF/ref buffers + fault anchors +
                    async buffers). On the engine path saves dispatch from
                    the chunk-boundary host sync to a background thread
                    (policy.mode="async"); the per-round loop saves inline.
                    Every checkpoint's manifest embeds this run's config
                    fingerprint (algo/runtime/channel/cohort/faults/async),
                    and the save telemetry rides the v4 footer.
    resume        — None: fresh start. "auto": restore the newest COMPLETE
                    checkpoint under ``checkpoint.directory`` (torn/corrupt
                    saves are skipped; nothing restorable → fresh start). A
                    path: restore exactly that checkpoint directory (raises
                    if torn). Either way the restored manifest's config
                    fingerprint must match this run's — a resumed run
                    REFUSES to continue under different hyperparameters/
                    faults (CheckpointConfigMismatch) instead of silently
                    blending histories. Round numbering continues from the
                    checkpoint round: ``num_rounds`` stays the TOTAL budget,
                    so a run preempted at round r executes rounds
                    r..num_rounds-1 and History/rows stay contiguous.
    checkpoint_fs — filesystem override for the save/restore path (the
                    crash-injection harness passes a
                    repro.robust.fs_faults.FaultyFs here); None = the real
                    filesystem.
    """
    from repro.comm import make_channel
    from repro.comm.schema import uplink_byte_breakdown
    from repro.core.algorithms import UPLINK_SCHEMAS, resolve_cohort_size

    if runtime not in ("vmap", "sharded"):
        raise ValueError(f"unknown runtime {runtime!r}; choose 'vmap' or 'sharded'")
    if isinstance(rng, int):
        rng = jax.random.PRNGKey(rng)
    channel = make_channel(channel)
    state = init_state(problem, rng, hp, channel, algo)
    if w0 is not None:
        # the engine path DONATES the state; copy so the caller's w0 buffers
        # are never consumed (the loop path aliases them harmlessly)
        state = state._replace(
            params=jax.tree.map(jnp.array, w0) if chunk is not None else w0)
    if faults is not None and faults.active and faults.stale_rate > 0.0:
        # every client's lagged anchor starts at the actual starting point
        from repro.robust.faults import init_fault_comm

        state = state._replace(comm=init_fault_comm(
            state.comm, state.params, problem.clients.num_clients))
    if async_cfg is not None and async_cfg.active:
        # every client starts with an empty buffer (age 0)
        from repro.robust.async_agg import init_async_comm

        state = state._replace(comm=init_async_comm(
            state.comm, state.params, problem.clients.num_clients))
    if runtime == "sharded":
        from repro.core.sharded import make_sharded_round_fn

        if mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh()
        round_fn = make_sharded_round_fn(algo, problem, hp, mesh,
                                         channel=channel, faults=faults,
                                         async_cfg=async_cfg)
    else:
        round_fn = make_round_fn(algo, problem, hp, channel, faults=faults,
                                 async_cfg=async_cfg)

    sinks = list(sinks)
    run_info = {
        "algo": algo,
        "runtime": runtime,
        "channel": channel.name,
        "backend": jax.default_backend(),
        "num_clients": problem.clients.num_clients,
        "cohort_size": resolve_cohort_size(hp, problem.clients.num_clients),
        "uplink_bytes": uplink_byte_breakdown(
            channel, UPLINK_SCHEMAS[algo], state.params),
    }

    ckpt_mgr = None
    start_round = 0
    if checkpoint is not None or resume not in (None, "none"):
        from repro.checkpoint import (
            LOCAL_FS, CheckpointManager, load_checkpoint, load_latest,
        )

        ckpt_fs = checkpoint_fs if checkpoint_fs is not None else LOCAL_FS
        fingerprint = checkpoint_config_fingerprint(
            algo, runtime, channel.name, problem.clients.num_clients,
            run_info["cohort_size"], faults, async_cfg)
        if resume not in (None, "none"):
            # the freshly-initialized state (incl. fault-anchor/async-buffer
            # comm attachments) is the shape/dtype/sharding template
            if resume == "auto":
                if checkpoint is None:
                    raise ValueError(
                        'resume="auto" needs a checkpoint policy (it names '
                        "the directory to scan)")
                found = load_latest(checkpoint.directory, state, fs=ckpt_fs,
                                    expect_config=fingerprint)
            else:
                found = (load_checkpoint(resume, state, fs=ckpt_fs,
                                         expect_config=fingerprint))
            if found is not None:
                state, manifest = found
                start_round = int(manifest["round"])
        if checkpoint is not None:
            ckpt_mgr = CheckpointManager(
                checkpoint, config=fingerprint, fs=ckpt_fs,
                last_saved=start_round)

    if chunk is not None:
        if chunk < 1:
            # the CLIs map their 0-means-loop knob to None before calling;
            # a direct chunk=0 should not silently pick either path
            raise ValueError(
                f"chunk must be >= 1 (or None for the per-round loop), "
                f"got {chunk}")
        from repro.core import engine

        state, trace = engine.run_rounds(
            round_fn, state, max(0, num_rounds - start_round), chunk=chunk,
            w_star=w_star,
            stop_rel_error=stop_rel_error, stop_grad_norm=stop_grad_norm,
            sinks=sinks, run_info=run_info, trace_capture=trace_capture,
            tap=tap, start_round=start_round, checkpoint=ckpt_mgr,
        )
        return History(
            algo=algo,
            rounds=np.arange(start_round, start_round + trace.num_rounds,
                             dtype=np.float64),
            loss=trace.loss,
            grad_norm=trace.grad_norm,
            rel_error=trace.rel_error,
            theta_mean=trace.theta_mean,
            comm_bytes=np.cumsum(trace.comm_bytes),
            wall_time=trace.wall_time,
            final_params=jax.device_get(state.params),
            channel=channel.name,
            gram_cond_max=trace.gram_cond_max,
            arrivals=trace.arrivals,
            staleness_mean=trace.staleness_mean,
            staleness_max=trace.staleness_max,
        )

    round_fn = jax.jit(round_fn)
    w_star_norm = None
    rel_fn = None
    if w_star is not None:
        w_star_norm = float(tm.tree_norm(w_star))
        # jit once, reuse every round: un-jitted tree_norm(tree_sub(...))
        # eagerly dispatched O(n_leaves) kernels per round
        rel_fn = jax.jit(lambda p: tm.tree_norm(tm.tree_sub(p, w_star)))

    from repro.obs.sinks import (
        ROW_FIELDS, SCHEMA_VERSION, build_footer, build_round_row,
    )

    for s in sinks:
        s.open({
            "v": SCHEMA_VERSION, "kind": "header", "fields": list(ROW_FIELDS),
            "num_rounds": num_rounds, "chunk": None,
            "start_round": start_round, **run_info,
        })
    rows = []
    comm_total = 0.0
    t_total = 0.0
    stopped = False
    try:
        for t in range(start_round, num_rounds):
            if trace_capture is not None:
                trace_capture.on_chunk_start(t, 1)
            t0 = time.perf_counter()
            state, m = round_fn(state)
            m = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), m)
            dt = time.perf_counter() - t0
            t_total += dt
            mdict = {f: float(getattr(m, f)) for f in m._fields}
            comm_total += mdict["comm_bytes"]
            if rel_fn is not None:
                rel = float(rel_fn(state.params)) / max(w_star_norm, 1e-30)
            else:
                rel = float("nan")
            rows.append((t, mdict["loss"], mdict["grad_norm"], rel,
                         mdict["theta_mean"], mdict["gram_cond_max"],
                         comm_total, t_total, mdict["arrivals"],
                         mdict["staleness_mean"], mdict["staleness_max"]))
            for s in sinks:
                s.emit([build_round_row(t, mdict, rel, comm_total, dt,
                                        t_total)])
            if trace_capture is not None:
                trace_capture.on_chunk_end(t + 1)
            if ckpt_mgr is not None:
                # loop path: no donation hazard, but the same snapshot-copy
                # save path as the engine (inline here, async per policy)
                ckpt_mgr.maybe_save(state, t + 1, dt)
            if not np.isfinite(m.loss):
                stopped = True
                break
            if stop_rel_error is not None and rel < stop_rel_error:
                stopped = True
                break
            if stop_grad_norm is not None and m.grad_norm < stop_grad_norm:
                stopped = True
                break
            if any(getattr(s, "stop_requested", False) for s in sinks):
                stopped = True
                break
    finally:
        if trace_capture is not None:
            trace_capture.close()
        if ckpt_mgr is not None:
            ckpt_mgr.finalize()
        alarms = [e for s in sinks for e in getattr(s, "events", [])]
        if ckpt_mgr is not None:
            alarms.extend(ckpt_mgr.events)
        footer = build_footer(
            len(rows), stopped, alarms,
            checkpoint=ckpt_mgr.telemetry() if ckpt_mgr is not None
            else None)
        for s in sinks:
            s.close(footer)

    arr = np.asarray(rows, dtype=np.float64)
    if arr.size == 0:
        # resumed at (or past) the round budget: nothing left to run
        arr = arr.reshape(0, 11)
    return History(
        algo=algo,
        rounds=arr[:, 0],
        loss=arr[:, 1],
        grad_norm=arr[:, 2],
        rel_error=arr[:, 3],
        theta_mean=arr[:, 4],
        comm_bytes=arr[:, 6],
        wall_time=arr[:, 7],
        final_params=jax.device_get(state.params),
        channel=channel.name,
        gram_cond_max=arr[:, 5],
        arrivals=arr[:, 8],
        staleness_mean=arr[:, 9],
        staleness_max=arr[:, 10],
    )


def solve_reference(
    problem: FLProblem, iters: int = 2000, tol: float = 1e-12
) -> Pytree:
    """Compute w* to high precision with centralized Newton-CG (for the
    relative-error metric). Works for any smooth strongly-convex problem."""
    from repro.core.algorithms import _cg_solve
    from repro.core.problem import ClientBatch

    params = problem.init(jax.random.PRNGKey(0))

    @jax.jit
    def newton_step(w):
        g = problem.global_grad(w)

        def matvec(v):
            # global HVP = weighted sum of client HVPs
            hv = jax.vmap(lambda x, y, m: problem.hvp(w, ClientBatch(x, y, m), v))(
                problem.clients.x, problem.clients.y, problem.clients.mask
            )
            return jax.tree.map(
                lambda h: jnp.tensordot(problem.clients.weight, h, axes=1), hv
            )

        p = _cg_solve(matvec, g, 100)
        return tm.tree_sub(w, p), tm.tree_norm(g)

    for _ in range(iters):
        params, gnorm = newton_step(params)
        if float(gnorm) < tol:
            break
    return params
