"""Federated problem abstraction.

A federated problem = a differentiable loss + K clients' data. To make K=100
clients cheap under jit we keep client datasets *stacked*: every array leaf
has leading axis K (padded to the largest client, with a per-sample mask), so
per-client gradients are one ``vmap`` instead of a python loop — and the
stacked layout is exactly what core/sharded.py::make_sharded_round_fn
partitions over the ("pod","data") mesh axes in the distributed runtime
(the leading K axis must divide over those axes' sizes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class ClientBatch(NamedTuple):
    """One (possibly padded) batch of client data.

    x: [n, ...] features; y: [n, ...] targets; mask: [n] 0/1 sample validity.
    """

    x: jax.Array
    y: jax.Array
    mask: jax.Array


class LinearDesign(NamedTuple):
    """A batch's loss declared in canonical linear-design form.

    The model asserts that its per-sample loss is ``link_loss(x_jᵀw, y_j)``
    plus ``reg/2·‖w‖²``, mask-mean-reduced — which is what makes the fused
    local-trajectory kernels (kernels/local_update) applicable: both the
    live and the anchor gradient of a variance-reduced local step are then
    ``Xᵀ c(Xw) / n + reg·w`` for a cheap per-sample coefficient c, so one
    X sweep serves all four autodiff passes of the naive step.

    x: [n, d] design rows (row-aligned with the batch: row j of ``x`` must
       correspond to batch row j, so minibatch index gathers agree with the
       autodiff path); y: [n] targets (±1 for "logistic"); link: one of
       kernels.local_update.LINKS; reg: the ℓ2 coefficient.
    """

    x: jax.Array
    y: jax.Array
    link: str
    reg: float


@dataclasses.dataclass(frozen=True)
class StackedClients:
    """All K clients, padded & stacked on axis 0.

    x: [K, n_max, ...], y: [K, n_max, ...], mask: [K, n_max],
    weight: [K] = N_k / N  (aggregation weights, sums to 1).
    """

    x: jax.Array
    y: jax.Array
    mask: jax.Array
    weight: jax.Array

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]

    def client(self, k: int) -> ClientBatch:
        return ClientBatch(self.x[k], self.y[k], self.mask[k])


@dataclasses.dataclass(frozen=True)
class FLProblem:
    """loss(params, batch) must return the *mean* loss over valid samples of
    the batch (mask-weighted), including any regularizer — i.e. it IS f_k when
    evaluated on client k's full data.
    """

    loss: Callable[[Pytree, ClientBatch], jax.Array]
    init: Callable[[jax.Array], Pytree]
    clients: StackedClients
    #: optional protocol: declare a batch's loss in canonical linear-design
    #: form (see LinearDesign). Models that implement it (logreg, linreg)
    #: become eligible for the fused local-trajectory kernel path
    #: (AlgoHParams.local_impl="pallas"); models that cannot (MLP, decoder)
    #: leave it None and keep the autodiff path.
    linear_design: "Callable[[ClientBatch], LinearDesign] | None" = None

    # ---- single-client oracles -------------------------------------------
    def grad(self, params: Pytree, batch: ClientBatch) -> Pytree:
        return jax.grad(self.loss)(params, batch)

    def value_and_grad(self, params: Pytree, batch: ClientBatch):
        return jax.value_and_grad(self.loss)(params, batch)

    def hvp(self, params: Pytree, batch: ClientBatch, v: Pytree) -> Pytree:
        """Hessian-vector product via forward-over-reverse — the only Hessian
        access mode any algorithm in this repo uses (matches GIANT's model)."""
        g = lambda p: jax.grad(self.loss)(p, batch)
        return jax.jvp(g, (params,), (v,))[1]

    # ---- all-clients (vmapped) oracles -----------------------------------
    def client_grads(self, params: Pytree) -> Pytree:
        """[K, ...] stacked full-batch gradients ∇f_k(params) for all k."""
        return jax.vmap(lambda x, y, m: self.grad(params, ClientBatch(x, y, m)))(
            self.clients.x, self.clients.y, self.clients.mask
        )

    def global_grad(self, params: Pytree) -> Pytree:
        """∇f(params) = Σ_k (N_k/N) ∇f_k(params)."""
        grads = self.client_grads(params)
        w = self.clients.weight
        return jax.tree.map(
            lambda g: jnp.tensordot(w, g, axes=1), grads
        )

    def global_loss(self, params: Pytree) -> jax.Array:
        losses = jax.vmap(
            lambda x, y, m: self.loss(params, ClientBatch(x, y, m))
        )(self.clients.x, self.clients.y, self.clients.mask)
        return jnp.dot(self.clients.weight, losses)


def sample_minibatch_indices(
    mask: jax.Array, rng: jax.Array, batch_size: int
) -> jax.Array:
    """The row indices ``sample_minibatch`` gathers — exposed so the fused
    local-trajectory path (kernels/local_update) can draw the bit-identical
    minibatches from the design matrix."""
    n = mask.shape[0]
    p = mask / jnp.maximum(jnp.sum(mask), 1.0)
    return jax.random.choice(rng, n, shape=(batch_size,), p=p)


def sample_minibatch(
    batch: ClientBatch, rng: jax.Array, batch_size: int
) -> ClientBatch:
    """Uniformly sample ``batch_size`` valid rows (with replacement — standard
    for SVRG-style estimators and shape-static under jit)."""
    idx = sample_minibatch_indices(batch.mask, rng, batch_size)
    return ClientBatch(batch.x[idx], batch.y[idx], jnp.ones(batch_size, batch.mask.dtype))


def stack_client_arrays(
    xs: list, ys: list
) -> StackedClients:
    """Pad a ragged python list of per-client (x, y) arrays into StackedClients."""
    import numpy as np

    K = len(xs)
    n_max = max(x.shape[0] for x in xs)
    x0, y0 = np.asarray(xs[0]), np.asarray(ys[0])
    X = np.zeros((K, n_max) + x0.shape[1:], dtype=x0.dtype)
    Y = np.zeros((K, n_max) + y0.shape[1:], dtype=y0.dtype)
    M = np.zeros((K, n_max), dtype=np.float32)
    for k, (x, y) in enumerate(zip(xs, ys)):
        n = x.shape[0]
        X[k, :n] = x
        Y[k, :n] = y
        M[k, :n] = 1.0
    # Aggregation weights in float64, normalized BEFORE the f32 cast: per-
    # element f32 rounding of n_k/N leaves Σ W off 1 by O(K·eps), a bias the
    # delta-form aggregation then applies to the model every round and that
    # scales with K. Normalizing in f64 keeps the f32 sum within 1 ulp of 1
    # for ragged K=100 splits (regression-tested).
    counts = np.array([x.shape[0] for x in xs], dtype=np.float64)
    W = (counts / counts.sum()).astype(np.float32)
    return StackedClients(jnp.asarray(X), jnp.asarray(Y), jnp.asarray(M), jnp.asarray(W))
