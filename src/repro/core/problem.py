"""Federated problem abstraction.

A federated problem = a differentiable loss + K clients' data. To make K=100
clients cheap under jit we keep client datasets *stacked*: every array leaf
has leading axis K (padded to the largest client, with a per-sample mask), so
per-client gradients are one ``vmap`` instead of a python loop — and the
stacked layout is exactly what core/sharded.py::make_sharded_round_fn
partitions over the ("pod","data") mesh axes in the distributed runtime
(the leading K axis must divide over those axes' sizes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class ClientBatch(NamedTuple):
    """One (possibly padded) batch of client data.

    x: [n, ...] features; y: [n, ...] targets; mask: [n] 0/1 sample validity.
    """

    x: jax.Array
    y: jax.Array
    mask: jax.Array


@dataclasses.dataclass(frozen=True)
class StackedClients:
    """All K clients, padded & stacked on axis 0.

    x: [K, n_max, ...], y: [K, n_max, ...], mask: [K, n_max],
    weight: [K] = N_k / N  (aggregation weights, sums to 1).
    """

    x: jax.Array
    y: jax.Array
    mask: jax.Array
    weight: jax.Array

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]

    def client(self, k: int) -> ClientBatch:
        return ClientBatch(self.x[k], self.y[k], self.mask[k])


@dataclasses.dataclass(frozen=True)
class FLProblem:
    """loss(params, batch) must return the *mean* loss over valid samples of
    the batch (mask-weighted), including any regularizer — i.e. it IS f_k when
    evaluated on client k's full data.
    """

    loss: Callable[[Pytree, ClientBatch], jax.Array]
    init: Callable[[jax.Array], Pytree]
    clients: StackedClients

    # ---- single-client oracles -------------------------------------------
    def grad(self, params: Pytree, batch: ClientBatch) -> Pytree:
        return jax.grad(self.loss)(params, batch)

    def value_and_grad(self, params: Pytree, batch: ClientBatch):
        return jax.value_and_grad(self.loss)(params, batch)

    def hvp(self, params: Pytree, batch: ClientBatch, v: Pytree) -> Pytree:
        """Hessian-vector product via forward-over-reverse — the only Hessian
        access mode any algorithm in this repo uses (matches GIANT's model)."""
        g = lambda p: jax.grad(self.loss)(p, batch)
        return jax.jvp(g, (params,), (v,))[1]

    # ---- all-clients (vmapped) oracles -----------------------------------
    def client_grads(self, params: Pytree) -> Pytree:
        """[K, ...] stacked full-batch gradients ∇f_k(params) for all k."""
        return jax.vmap(lambda x, y, m: self.grad(params, ClientBatch(x, y, m)))(
            self.clients.x, self.clients.y, self.clients.mask
        )

    def global_grad(self, params: Pytree) -> Pytree:
        """∇f(params) = Σ_k (N_k/N) ∇f_k(params)."""
        grads = self.client_grads(params)
        w = self.clients.weight
        return jax.tree.map(
            lambda g: jnp.tensordot(w, g, axes=1), grads
        )

    def global_loss(self, params: Pytree) -> jax.Array:
        losses = jax.vmap(
            lambda x, y, m: self.loss(params, ClientBatch(x, y, m))
        )(self.clients.x, self.clients.y, self.clients.mask)
        return jnp.dot(self.clients.weight, losses)


def sample_minibatch(
    batch: ClientBatch, rng: jax.Array, batch_size: int
) -> ClientBatch:
    """Uniformly sample ``batch_size`` valid rows (with replacement — standard
    for SVRG-style estimators and shape-static under jit)."""
    n = batch.mask.shape[0]
    p = batch.mask / jnp.maximum(jnp.sum(batch.mask), 1.0)
    idx = jax.random.choice(rng, n, shape=(batch_size,), p=p)
    return ClientBatch(batch.x[idx], batch.y[idx], jnp.ones(batch_size, batch.mask.dtype))


def stack_client_arrays(
    xs: list, ys: list
) -> StackedClients:
    """Pad a ragged python list of per-client (x, y) arrays into StackedClients."""
    import numpy as np

    K = len(xs)
    n_max = max(x.shape[0] for x in xs)
    total = sum(x.shape[0] for x in xs)
    x0, y0 = np.asarray(xs[0]), np.asarray(ys[0])
    X = np.zeros((K, n_max) + x0.shape[1:], dtype=x0.dtype)
    Y = np.zeros((K, n_max) + y0.shape[1:], dtype=y0.dtype)
    M = np.zeros((K, n_max), dtype=np.float32)
    W = np.zeros((K,), dtype=np.float32)
    for k, (x, y) in enumerate(zip(xs, ys)):
        n = x.shape[0]
        X[k, :n] = x
        Y[k, :n] = y
        M[k, :n] = 1.0
        W[k] = n / total
    return StackedClients(jnp.asarray(X), jnp.asarray(Y), jnp.asarray(M), jnp.asarray(W))
