"""Device-resident round engine: multi-round scan chunking with donated state.

The seed driver (core/server.py) dispatched ONE jit per aggregation round and
host-synced every metric — per-round Python/dispatch overhead plus a blocking
device→host transfer per round, with the K×d-heavy ServerState (params +
control variates + per-client EF residuals + diff-coding refs) re-uploaded
conceptually every call. This engine compiles ``chunk`` rounds into one XLA
computation:

  * ``jax.lax.scan`` over the rounds, so B rounds are one dispatch;
  * the ServerState argument is DONATED (``donate_argnums``), so XLA reuses
    the K×d client-state buffers in place instead of doubling peak memory —
    this holds for the sharded runtime too, whose round_fn carries the
    stacked per-client buffers through shard_map;
  * per-round ``RoundMetrics`` (plus the rel-error against a device-resident
    ``w_star``) stack ON DEVICE; the host syncs once per chunk;
  * stopping criteria — rel-error target, grad-norm target, non-finite
    loss — are evaluated IN-GRAPH: once one fires, the carried state passes
    through the remaining rounds of the chunk untouched (a leaf-wise
    select), so the final state is identical to the per-round loop that
    breaks immediately.

Stop criteria therefore resolve at CHUNK granularity from the host's point
of view (the driver learns about the stop one chunk-sync later) but at ROUND
granularity numerically: no extra round is ever applied to the carried
state, and the emitted per-round rows are exactly the rows the Python loop
would have produced (guarded by tests/test_engine.py in both runtimes).

Why a select and not ``lax.cond``: the scan body applies the round
UNCONDITIONALLY and selects between old and new state afterwards. Measured
on this container, that keeps the chunked round BIT-EXACT with the
standalone per-round jit — wrapping the round in a runtime-predicated cond
changes XLA's fusion choices by an ulp, which the ill-conditioned AA Gram
solve then amplifies arbitrarily (the same chaos documented for
vmap-vs-sharded agreement in core/sharded.py). The price is that scan slots
past an early stop (or past ``n_live`` in a short final chunk) burn a
round's FLOPs on a discarded result — bounded by chunk−1 rounds per run,
zero when no stop criterion fires and chunk divides num_rounds.

``run_rounds`` works with any ``round(state) -> (state, RoundMetrics)`` —
the vmap runtime's ``make_round_fn`` and the sharded runtime's
``make_sharded_round_fn`` alike. Pass the UN-jitted round function; the
engine owns the jit (and its donation).

Cohort rounds compose with all of the above: a round_fn built with
``cohort_size`` (or participation < 1) gathers its C sampled rows from the
K-sized client store inside the scan body and scatters the updated rows
back (core/client_store.py), so donation still reuses the O(K·d) store in
place while each scan slot computes O(C·d). The live/stop select passes
untouched store fields through by OBJECT IDENTITY (see tree_math.tree_where)
— no [K, ...] select op enters the compiled chunk, which is what the
no-dense-compute jaxpr assertion in tests/test_cohort.py pins.

NOTE donation semantics: with ``donate=True`` (default) the caller's input
``state`` buffers are consumed by the first chunk — re-init (same PRNGKey
gives an identical state) if the initial state is needed afterwards.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import tree_math as tm

Pytree = Any


@dataclasses.dataclass
class RoundTrace:
    """Per-round history of an engine run (host-side numpy, one row per
    EXECUTED round — padded/skipped scan slots are dropped)."""

    loss: np.ndarray           # [T]
    grad_norm: np.ndarray      # [T]
    theta_mean: np.ndarray     # [T]
    gram_cond_max: np.ndarray  # [T]
    comm_bytes: np.ndarray     # [T] per-round (NOT cumulative) wire bytes
    rel_error: np.ndarray      # [T] ‖w−w*‖/‖w*‖ (nan when w_star not given)
    wall_time: np.ndarray      # [T] cumulative seconds; each chunk's measured
                               # wall time is attributed equally to its rounds
    stopped: bool              # a stop criterion fired (vs round budget spent)

    @property
    def num_rounds(self) -> int:
        return len(self.loss)


def make_chunk_runner(
    round_fn: Callable,
    chunk: int,
    *,
    w_star: Pytree | None = None,
    stop_rel_error: float | None = None,
    stop_grad_norm: float | None = None,
    donate: bool = True,
):
    """Compile ``chunk`` rounds of ``round_fn`` into one donated jit.

    Returns ``runner(state, n_live) -> (state, done, metrics, rel, live)``:
      state   — after min(n_live, first-stop) rounds; the INPUT state buffers
                are donated (consumed) when ``donate``;
      done    — scalar bool: a stop criterion fired inside the chunk;
      metrics — RoundMetrics stacked [chunk];
      rel     — [chunk] f32 rel-error after each round (nan w/o w_star);
      live    — [chunk] bool: the round's result entered the carried state.
                Non-live slots (past ``n_live`` or past a stop) computed a
                round on the frozen state and DISCARDED it — their metric
                rows are garbage and must be dropped.

    ``n_live`` is a device scalar, so a short final chunk reuses the SAME
    executable (no recompile); slots with i >= n_live behave exactly like
    post-stop slots.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    w_star_norm = (
        jnp.maximum(tm.tree_norm(w_star), 1e-30) if w_star is not None else None
    )

    def chunk_fn(state, n_live):
        def step(carry, i):
            s, done = carry
            # unconditional round + select (NOT lax.cond) — see module
            # docstring: this keeps the chunk bit-exact with the loop
            new_s, m = round_fn(s)
            if w_star is not None:
                rel = tm.tree_norm(tm.tree_sub(new_s.params, w_star)) / w_star_norm
            else:
                rel = jnp.full((), jnp.nan, jnp.float32)
            live = jnp.logical_and(~done, i < n_live)
            new_s = tm.tree_where(live, new_s, s)
            # mirror the loop's break order: the row is emitted, THEN the
            # stop fires — so the stopping round's row is kept
            stop = ~jnp.isfinite(m.loss)
            if stop_rel_error is not None:
                stop = jnp.logical_or(stop, rel < stop_rel_error)
            if stop_grad_norm is not None:
                stop = jnp.logical_or(stop, m.grad_norm < stop_grad_norm)
            done = jnp.logical_or(done, jnp.logical_and(live, stop))
            return (new_s, done), (m, rel, live)

        (state, done), (ms, rels, lives) = jax.lax.scan(
            step, (state, jnp.zeros((), bool)), jnp.arange(chunk)
        )
        return state, done, ms, rels, lives

    return jax.jit(chunk_fn, donate_argnums=(0,) if donate else ())


def run_rounds(
    round_fn: Callable,
    state,
    num_rounds: int,
    *,
    chunk: int = 8,
    w_star: Pytree | None = None,
    stop_rel_error: float | None = None,
    stop_grad_norm: float | None = None,
    donate: bool = True,
    runner: Callable | None = None,
):
    """Run up to ``num_rounds`` rounds in chunks of ``chunk``; one host sync
    per chunk. Returns ``(final_state, RoundTrace)`` — the state stays
    device-resident, the trace is host numpy with one row per executed round
    (identical to the per-round Python loop's rows).

    ``runner`` — optionally a prebuilt ``make_chunk_runner(...)`` whose
    compiled executable should be reused (e.g. pre-compiled via
    ``runner.lower(state, np.int32(n)).compile()`` so the trace excludes
    compile time). It MUST have been built from the same ``round_fn`` with
    the same chunk/stop configuration; when omitted, one is built here.
    """
    chunk = max(1, min(chunk, num_rounds))
    if runner is None:
        runner = make_chunk_runner(
            round_fn, chunk, w_star=w_star, stop_rel_error=stop_rel_error,
            stop_grad_norm=stop_grad_norm, donate=donate,
        )
    cols: list[list] = [[] for _ in range(7)]
    t_total = 0.0
    executed = 0
    stopped = False
    while executed < num_rounds and not stopped:
        n_live = min(chunk, num_rounds - executed)
        t0 = time.perf_counter()
        state, done, ms, rels, lives = runner(state, np.int32(n_live))
        # the ONE host sync of this chunk (device_get blocks on the results)
        done, ms, rels, lives = jax.device_get((done, ms, rels, lives))
        elapsed = time.perf_counter() - t0
        idx = np.flatnonzero(lives)
        per_round = elapsed / max(len(idx), 1)
        for i in idx:
            t_total += per_round
            cols[0].append(float(np.asarray(ms.loss)[i]))
            cols[1].append(float(np.asarray(ms.grad_norm)[i]))
            cols[2].append(float(np.asarray(ms.theta_mean)[i]))
            cols[3].append(float(np.asarray(ms.gram_cond_max)[i]))
            cols[4].append(float(np.asarray(ms.comm_bytes)[i]))
            cols[5].append(float(rels[i]))
            cols[6].append(t_total)
        executed += len(idx)
        stopped = bool(done)
    trace = RoundTrace(
        loss=np.asarray(cols[0]),
        grad_norm=np.asarray(cols[1]),
        theta_mean=np.asarray(cols[2]),
        gram_cond_max=np.asarray(cols[3]),
        comm_bytes=np.asarray(cols[4]),
        rel_error=np.asarray(cols[5]),
        wall_time=np.asarray(cols[6]),
        stopped=stopped,
    )
    return state, trace
