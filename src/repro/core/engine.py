"""Device-resident round engine: multi-round scan chunking with donated state.

The seed driver (core/server.py) dispatched ONE jit per aggregation round and
host-synced every metric — per-round Python/dispatch overhead plus a blocking
device→host transfer per round, with the K×d-heavy ServerState (params +
control variates + per-client EF residuals + diff-coding refs) re-uploaded
conceptually every call. This engine compiles ``chunk`` rounds into one XLA
computation:

  * ``jax.lax.scan`` over the rounds, so B rounds are one dispatch;
  * the ServerState argument is DONATED (``donate_argnums``), so XLA reuses
    the K×d client-state buffers in place instead of doubling peak memory —
    this holds for the sharded runtime too, whose round_fn carries the
    stacked per-client buffers through shard_map;
  * per-round ``RoundMetrics`` (plus the rel-error against a device-resident
    ``w_star``) stack ON DEVICE; the host syncs once per chunk;
  * stopping criteria — rel-error target, grad-norm target, non-finite
    loss — are evaluated IN-GRAPH: once one fires, the carried state passes
    through the remaining rounds of the chunk untouched (a leaf-wise
    select), so the final state is identical to the per-round loop that
    breaks immediately.

Stop criteria therefore resolve at CHUNK granularity from the host's point
of view (the driver learns about the stop one chunk-sync later) but at ROUND
granularity numerically: no extra round is ever applied to the carried
state, and the emitted per-round rows are exactly the rows the Python loop
would have produced (guarded by tests/test_engine.py in both runtimes).

Why a select and not ``lax.cond``: the scan body applies the round
UNCONDITIONALLY and selects between old and new state afterwards. Measured
on this container, that keeps the chunked round BIT-EXACT with the
standalone per-round jit — wrapping the round in a runtime-predicated cond
changes XLA's fusion choices by an ulp, which the ill-conditioned AA Gram
solve then amplifies arbitrarily (the same chaos documented for
vmap-vs-sharded agreement in core/sharded.py). The price is that scan slots
past an early stop (or past ``n_live`` in a short final chunk) burn a
round's FLOPs on a discarded result — bounded by chunk−1 rounds per run,
zero when no stop criterion fires and chunk divides num_rounds.

``run_rounds`` works with any ``round(state) -> (state, RoundMetrics)`` —
the vmap runtime's ``make_round_fn`` and the sharded runtime's
``make_sharded_round_fn`` alike. Pass the UN-jitted round function; the
engine owns the jit (and its donation).

Cohort rounds compose with all of the above: a round_fn built with
``cohort_size`` (or participation < 1) gathers its C sampled rows from the
K-sized client store inside the scan body and scatters the updated rows
back (core/client_store.py), so donation still reuses the O(K·d) store in
place while each scan slot computes O(C·d). The live/stop select passes
untouched store fields through by OBJECT IDENTITY (see tree_math.tree_where)
— no [K, ...] select op enters the compiled chunk, which is what the
no-dense-compute jaxpr assertion in tests/test_cohort.py pins.

NOTE donation semantics: with ``donate=True`` (default) the caller's input
``state`` buffers are consumed by the first chunk — re-init (same PRNGKey
gives an identical state) if the initial state is needed afterwards.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import tree_math as tm

Pytree = Any


#: RoundMetrics fields mirrored into RoundTrace columns, in order — the
#: engine reads them off the stacked metrics generically, so a new device-side
#: metric becomes a trace column (and a telemetry row field) by being added to
#: RoundMetrics and here.
METRIC_FIELDS = (
    "loss", "grad_norm", "theta_mean", "gram_cond_max", "gram_cond_mean",
    "aa_used_min", "aa_clipped_max", "cohort_ess", "comm_bytes",
    "arrivals", "staleness_mean", "staleness_max",
)


@dataclasses.dataclass
class RoundTrace:
    """Per-round history of an engine run (host-side numpy, one row per
    EXECUTED round — padded/skipped scan slots are dropped)."""

    loss: np.ndarray           # [T]
    grad_norm: np.ndarray      # [T]
    theta_mean: np.ndarray     # [T]
    gram_cond_max: np.ndarray  # [T]
    gram_cond_mean: np.ndarray # [T]
    aa_used_min: np.ndarray    # [T]
    aa_clipped_max: np.ndarray # [T] clip_rtol screen activity (nan if n/a)
    cohort_ess: np.ndarray     # [T]
    comm_bytes: np.ndarray     # [T] per-round (NOT cumulative) wire bytes
    arrivals: np.ndarray       # [T] deadline-gated landings (nan: async off)
    staleness_mean: np.ndarray # [T] mean landed buffer age (nan if n/a)
    staleness_max: np.ndarray  # [T] oldest landed buffer age (nan if n/a)
    rel_error: np.ndarray      # [T] ‖w−w*‖/‖w*‖ (nan when w_star not given)
    round_wall: np.ndarray     # [T] seconds attributed to this round (each
                               # chunk's measured wall time divided equally
                               # over its executed rounds)
    wall_time: np.ndarray      # [T] cumulative seconds
    stopped: bool              # a stop criterion fired (vs round budget spent)

    @property
    def num_rounds(self) -> int:
        return len(self.loss)


def make_chunk_runner(
    round_fn: Callable,
    chunk: int,
    *,
    w_star: Pytree | None = None,
    stop_rel_error: float | None = None,
    stop_grad_norm: float | None = None,
    donate: bool = True,
    tap: Callable | None = None,
):
    """Compile ``chunk`` rounds of ``round_fn`` into one donated jit.

    Returns ``runner(state, n_live) -> (state, done, metrics, rel, live)``:
      state   — after min(n_live, first-stop) rounds; the INPUT state buffers
                are donated (consumed) when ``donate``;
      done    — scalar bool: a stop criterion fired inside the chunk;
      metrics — RoundMetrics stacked [chunk];
      rel     — [chunk] f32 rel-error after each round (nan w/o w_star);
      live    — [chunk] bool: the round's result entered the carried state.
                Non-live slots (past ``n_live`` or past a stop) computed a
                round on the frozen state and DISCARDED it — their metric
                rows are garbage and must be dropped.

    ``n_live`` is a device scalar, so a short final chunk reuses the SAME
    executable (no recompile); slots with i >= n_live behave exactly like
    post-stop slots.

    ``tap`` — optional live tap (obs/sinks.LiveTap or any host callable
    ``(slot, metrics, rel, live)``) invoked via ``jax.debug.callback`` as
    each scan slot executes, for sub-chunk visibility into a long chunk.
    OFF by default: the callback re-enters the host mid-chunk, which is
    exactly what the one-sync-per-chunk contract otherwise rules out. It
    receives the compiled math's own values; note the inserted callback can
    shift XLA's fusion choices by an ulp (the same sensitivity documented
    above for lax.cond), so a tapped chunk matches the tapless one at the
    documented rtol 1e-6, not bit-exactly (pinned in tests/test_obs.py) —
    leave the tap off for runs that must be bit-reproducible.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    w_star_norm = (
        jnp.maximum(tm.tree_norm(w_star), 1e-30) if w_star is not None else None
    )

    def chunk_fn(state, n_live):
        def step(carry, i):
            s, done = carry
            # unconditional round + select (NOT lax.cond) — see module
            # docstring: this keeps the chunk bit-exact with the loop
            new_s, m = round_fn(s)
            if w_star is not None:
                rel = tm.tree_norm(tm.tree_sub(new_s.params, w_star)) / w_star_norm
            else:
                rel = jnp.full((), jnp.nan, jnp.float32)
            live = jnp.logical_and(~done, i < n_live)
            new_s = tm.tree_where(live, new_s, s)
            if tap is not None:
                jax.debug.callback(tap, i, m, rel, live, ordered=False)
            # mirror the loop's break order: the row is emitted, THEN the
            # stop fires — so the stopping round's row is kept
            stop = ~jnp.isfinite(m.loss)
            if stop_rel_error is not None:
                stop = jnp.logical_or(stop, rel < stop_rel_error)
            if stop_grad_norm is not None:
                stop = jnp.logical_or(stop, m.grad_norm < stop_grad_norm)
            done = jnp.logical_or(done, jnp.logical_and(live, stop))
            return (new_s, done), (m, rel, live)

        (state, done), (ms, rels, lives) = jax.lax.scan(
            step, (state, jnp.zeros((), bool)), jnp.arange(chunk)
        )
        return state, done, ms, rels, lives

    return jax.jit(chunk_fn, donate_argnums=(0,) if donate else ())


def run_rounds(
    round_fn: Callable,
    state,
    num_rounds: int,
    *,
    chunk: int = 8,
    w_star: Pytree | None = None,
    stop_rel_error: float | None = None,
    stop_grad_norm: float | None = None,
    donate: bool = True,
    runner: Callable | None = None,
    tap: Callable | None = None,
    sinks=(),
    run_info: "dict | None" = None,
    trace_capture=None,
    start_round: int = 0,
    checkpoint=None,
):
    """Run up to ``num_rounds`` rounds in chunks of ``chunk``; one host sync
    per chunk. Returns ``(final_state, RoundTrace)`` — the state stays
    device-resident, the trace is host numpy with one row per executed round
    (identical to the per-round Python loop's rows).

    ``runner`` — optionally a prebuilt ``make_chunk_runner(...)`` whose
    compiled executable should be reused (e.g. pre-compiled via
    ``runner.lower(state, np.int32(n)).compile()`` so the trace excludes
    compile time). It MUST have been built from the same ``round_fn`` with
    the same chunk/stop configuration (incl. ``tap``); when omitted, one is
    built here.

    Telemetry (repro/obs — every hook is optional and None/() by default):
      tap           — live in-chunk callback, compiled into the runner (see
                      make_chunk_runner); ignored when ``runner`` is given.
      sinks         — MetricsSinks. Opened with a header row (run_info merged
                      in), fed one row per executed round from THIS chunk
                      sync — attaching sinks adds no device→host transfer and
                      leaves the chunk math untouched (pinned in
                      tests/test_obs.py) — and closed with a footer. A sink
                      whose ``stop_requested`` turns truthy (health alarms)
                      stops the run at the next chunk boundary.
      run_info      — extra header fields (algo/runtime/channel/uplink byte
                      breakdown — see core/server.py).
      trace_capture — obs/profiling.TraceCapture; notified at chunk
                      boundaries to open/close jax.profiler windows.
      start_round   — global index of the first round (resumed runs), offsets
                      the "round" field of emitted rows.
      checkpoint    — checkpoint/policy.CheckpointManager; its ``maybe_save``
                      is called at every chunk boundary (from THIS one host
                      sync — the save path copies the state's addressable
                      shards host-side and never calls jax.device_get, so the
                      one-sync-per-chunk contract holds with checkpointing
                      on), and it is finalized (in-flight save joined) when
                      the run ends. Its telemetry and alarm events ride the
                      footer.
    """
    from repro.obs.sinks import ROW_FIELDS, SCHEMA_VERSION, build_footer, \
        build_round_row

    chunk = max(1, min(chunk, num_rounds))
    if runner is None:
        runner = make_chunk_runner(
            round_fn, chunk, w_star=w_star, stop_rel_error=stop_rel_error,
            stop_grad_norm=stop_grad_norm, donate=donate, tap=tap,
        )
    sinks = list(sinks)
    for s in sinks:
        s.open({
            "v": SCHEMA_VERSION, "kind": "header", "fields": list(ROW_FIELDS),
            "num_rounds": num_rounds, "chunk": chunk,
            "start_round": start_round, **(run_info or {}),
        })
    cols: dict[str, list] = {f: [] for f in METRIC_FIELDS}
    rel_col: list[float] = []
    rw_col: list[float] = []
    wall_col: list[float] = []
    t_total = 0.0
    comm_total = 0.0
    executed = 0
    stopped = False
    try:
        while executed < num_rounds and not stopped:
            n_live = min(chunk, num_rounds - executed)
            if trace_capture is not None:
                trace_capture.on_chunk_start(start_round + executed, n_live)
            t0 = time.perf_counter()
            state, done, ms, rels, lives = runner(state, np.int32(n_live))
            # the ONE host sync of this chunk (device_get blocks on results)
            done, ms, rels, lives = jax.device_get((done, ms, rels, lives))
            elapsed = time.perf_counter() - t0
            idx = np.flatnonzero(lives)
            per_round = elapsed / max(len(idx), 1)
            stacked = {f: np.asarray(getattr(ms, f)) for f in METRIC_FIELDS}
            rows = []
            for i in idx:
                t_total += per_round
                mrow = {f: float(stacked[f][i]) for f in METRIC_FIELDS}
                comm_total += mrow["comm_bytes"]
                for f in METRIC_FIELDS:
                    cols[f].append(mrow[f])
                rel_col.append(float(rels[i]))
                rw_col.append(per_round)
                wall_col.append(t_total)
                if sinks:
                    rows.append(build_round_row(
                        start_round + executed + len(rows), mrow,
                        float(rels[i]), comm_total, per_round, t_total))
            executed += len(idx)
            stopped = bool(done)
            for s in sinks:
                s.emit(rows)
            if any(getattr(s, "stop_requested", False) for s in sinks):
                stopped = True
            if trace_capture is not None:
                trace_capture.on_chunk_end(start_round + executed)
            if checkpoint is not None:
                # state buffers are about to be donated to the NEXT chunk:
                # maybe_save snapshots host copies before dispatching the
                # (async) write
                checkpoint.maybe_save(state, start_round + executed, elapsed)
    finally:
        if trace_capture is not None:
            trace_capture.close()
        if checkpoint is not None:
            checkpoint.finalize()
        alarms = [e for s in sinks for e in getattr(s, "events", [])]
        if checkpoint is not None:
            alarms.extend(checkpoint.events)
        footer = build_footer(
            executed, stopped, alarms,
            checkpoint=checkpoint.telemetry() if checkpoint is not None
            else None)
        for s in sinks:
            s.close(footer)
    trace = RoundTrace(
        **{f: np.asarray(cols[f]) for f in METRIC_FIELDS},
        rel_error=np.asarray(rel_col),
        round_wall=np.asarray(rw_col),
        wall_time=np.asarray(wall_col),
        stopped=stopped,
    )
    return state, trace
