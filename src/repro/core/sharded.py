"""Distributed FL round runtime: the client fan-out under shard_map.

``make_sharded_round_fn`` is the drop-in distributed twin of
``core/algorithms.py::make_round_fn``: the same per-client bodies
(``_client_svrg``, ``_client_scaffold``, ``_client_avg``, ``_client_lbfgs``,
``_client_giant``, ``_client_newton_gmres``, ``_client_dane``) and the same
round cores, but with the K stacked clients partitioned over the ("pod",
"data") mesh axes of a launch/mesh.py mesh instead of vmapped on one device.

How it maps:

  * every [K, ...] client array (data, rngs, control variates, carried AA
    history) enters the shard_map body sharded on its leading axis — each
    shard vmaps over its K / n_shards local clients;
  * every server quantity (params, server control variate, participation
    weights already normalized on the host) enters replicated;
  * all cross-client reductions — ``_aggregate`` deltas, the global gradient,
    control-variate means, metric reductions — finish with a psum/pmax over
    the client mesh axes (see ``ShardReduce``), inside the mapped body;
  * per-client outputs (new c_k, carried history) leave sharded, aggregates
    leave replicated.

One jit of the returned round_fn therefore compiles the full round as a
single XLA computation: no per-client Python loop, no host round-trips.
On a 1-device ``make_host_mesh()`` every psum is an identity and the sharded
round agrees with the vmap round to float precision from any given state
(allclose rtol 1e-6 — tests/test_sharded_runtime.py; the shard_map boundary
changes XLA fusion, so agreement is not bit-for-bit, and the ill-conditioned
AA gram solve can amplify that last-ulp difference across many rounds).

The unused "model" mesh axis (tensor parallelism for the LM workloads) is
simply not mentioned in any spec: the round is replicated over it.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import CommChannel, make_channel
from repro.core.algorithms import (
    ALGORITHMS,
    AlgoHParams,
    CrossClientReduce,
    ServerState,
    _avg_round_core,
    _client_giant,
    _client_newton_gmres,
    _dane_round_core,
    _commit_plan,
    _lbfgs_round_core,
    _newton_round_core,
    _plan_round,
    _scaffold_round_core,
    _svrg_round_core,
    comm_bytes_per_round,
    finalize_metrics,
    resolve_cohort_size,
    resolve_local_impl,
)
from repro.core.anderson import resolve_aa_impl
from repro.core.problem import FLProblem
from repro.utils.compat import shard_map

#: mesh axes the client axis is partitioned over, slowest (inter-pod) first.
CLIENT_MESH_AXES = ("pod", "data")


class ShardReduce(CrossClientReduce):
    """Cross-client reductions for the shard_map runtime.

    Each method reduces over the *local* client slice exactly like the vmap
    runtime, then finishes with a psum/pmax over the client mesh axes — so on
    a 1-shard mesh the arithmetic is identical to CrossClientReduce.
    """

    def __init__(self, axes: tuple[str, ...],
                 channel: CommChannel | None = None):
        super().__init__(channel)
        self.axes = axes

    def wsum(self, weights, stacked, anchor=None):
        with jax.named_scope("fl.psum"):
            if anchor is None:
                return jax.tree.map(
                    lambda s: jax.lax.psum(
                        jnp.tensordot(weights, s, axes=1), self.axes),
                    stacked,
                )
            return jax.tree.map(
                lambda a, s: a + jax.lax.psum(
                    jnp.tensordot(weights, s - a[None], axes=1), self.axes
                ),
                anchor, stacked,
            )

    def nanmean(self, x):
        finite = ~jnp.isnan(x)
        total = jax.lax.psum(jnp.sum(jnp.where(finite, x, 0.0)), self.axes)
        count = jax.lax.psum(jnp.sum(finite.astype(x.dtype)), self.axes)
        return jnp.where(count > 0, total / jnp.maximum(count, 1), jnp.nan)

    def nanmax(self, x):
        m = jax.lax.pmax(jnp.max(jnp.where(jnp.isnan(x), -jnp.inf, x)), self.axes)
        return jnp.where(jnp.isneginf(m), jnp.nan, m)

    def nanmin(self, x):
        m = jax.lax.pmin(jnp.min(jnp.where(jnp.isnan(x), jnp.inf, x)), self.axes)
        return jnp.where(jnp.isposinf(m), jnp.nan, m)

    def ess(self, weights):
        w2 = jax.lax.psum(jnp.sum(weights * weights), self.axes)
        return 1.0 / jnp.maximum(w2, 1e-30)


def client_mesh_axes(mesh) -> tuple[str, ...]:
    """The subset of ("pod","data") present in ``mesh``, slowest first."""
    return tuple(a for a in CLIENT_MESH_AXES if a in mesh.axis_names)


def _split_client_rngs(cl_rng, K: int, mesh):
    """K per-client keys, forced REPLICATED before they enter shard_map.

    Without the constraint GSPMD partitions the threefry split across the
    mesh (its consumer is sharded) and stitches the key halves back with
    512-participant collective-permutes — ~40 B of traffic that deadlocks
    the emulated-CPU collective rendezvous and would be pure latency on real
    pods. Replicating the split is a few µs of redundant compute per device;
    the shard_map entry then slices each shard's keys locally, collective-
    free. Only stochastic codecs (int8) keep the keys live, which is why the
    permutes never showed up in the bf16/identity dryruns.
    """
    from jax.sharding import NamedSharding

    rngs = jax.random.split(cl_rng, K)
    return jax.lax.with_sharding_constraint(rngs, NamedSharding(mesh, P()))


def num_client_shards(mesh, axes: tuple[str, ...] | None = None) -> int:
    axes = client_mesh_axes(mesh) if axes is None else axes
    return math.prod(mesh.shape[a] for a in axes)


# -- shard addressability (the checkpoint subsystem's view of an array) -----

def leaf_addressable_shards(leaf) -> "list[tuple[tuple[tuple[int, int], ...], object]]":
    """The shards of ``leaf`` THIS process can read, as
    ``[(box, host_copy), ...]`` — ``box`` is one ``(start, stop)`` pair per
    dimension and ``host_copy`` a fresh numpy COPY of that shard's data.

    This is the primitive the per-shard checkpoint save is built on: each
    host saves exactly the boxes it holds, so no cross-host ``device_get``
    (and no full-array gather through one process) ever happens on the save
    path. Replicated leaves yield one shard per local device with identical
    boxes — callers dedupe by box. The copy is deliberate: the engine DONATES
    state buffers to the next chunk's jit, so a zero-copy view taken at the
    chunk boundary would silently alias memory XLA is about to reuse.
    """
    import numpy as np

    shards = getattr(leaf, "addressable_shards", None)
    if shards is None:  # plain numpy / scalar leaf: one process-local box
        arr = np.array(leaf, copy=True)
        return [(tuple((0, n) for n in arr.shape), arr)]
    out = []
    for sh in shards:
        data = np.array(sh.data, copy=True)
        box = tuple(
            (0 if idx.start is None else int(idx.start),
             dim if idx.stop is None else int(idx.stop))
            for idx, dim in zip(sh.index, leaf.shape))
        if not box:  # 0-d leaf
            box = ()
        out.append((box, data))
    return out


def dedupe_shard_boxes(shards):
    """Drop replicated copies: keep the first shard seen per distinct box
    (replication puts bit-identical data at every copy, so which copy wins
    is immaterial)."""
    seen, out = set(), []
    for box, data in shards:
        if box in seen:
            continue
        seen.add(box)
        out.append((box, data))
    return out


def make_sharded_round_fn(algo: str, problem: FLProblem, hp: AlgoHParams,
                          mesh, client_axes: tuple[str, ...] | None = None,
                          channel: "CommChannel | str | None" = None,
                          faults: "FaultPlan | None" = None,
                          async_cfg: "AsyncConfig | None" = None):
    """Return a jittable round(state) -> (state, RoundMetrics) whose client
    fan-out is shard_mapped over ``mesh``'s ("pod","data") axes.

    Requires num_clients to divide evenly over the client shards (pad the
    client stack with stack_client_arrays if it does not).

    ``channel`` (repro/comm) compresses the wire exactly as in the vmap
    runtime: each shard encode/decodes its local clients' uploads, so the
    dequantized representation is what the client-axis psum reduces; the
    error-feedback residuals stay sharded with their clients.

    ``faults`` (repro/robust) injects the plan's perturbations exactly as
    the vmap runtime does: the per-round realization is drawn at jit level
    (keyed by global client id, so both runtimes inject identical rounds)
    and enters the shard_map body as extra [C] client-sharded arrays; every
    fault op inside the body is per-client row-local, so no new collectives
    appear. The weight adjustment, dropped-row freeze and stale-anchor
    refresh run at jit level outside the shard_map, shared with the vmap
    builder's logic verbatim.

    ``async_cfg`` (repro.robust.async_agg) deadline-gates the round close the
    same way: the gate's partition and discounted weights are computed at jit
    level from the realized latencies (identical to the vmap builder), the
    body's only change is capturing the anchored model uplink's post-codec
    rows as one extra client-sharded output, and the buffer fold/transition
    runs at jit level. None (or ``deadline == 0``) compiles the byte-identical
    barriered graph.
    """
    if algo not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algo!r}; choose from {ALGORITHMS}")
    # the sharded runtime always takes the leaf-wise tree AA path (leaves may
    # be sharded across the mesh, where the flat-buffer Pallas ravel would
    # force an all-gather) AND the autodiff local-trajectory path:
    # aa_impl/local_impl "pallas"/"auto" fall back without error
    hp = dataclasses.replace(
        hp, aa_impl=resolve_aa_impl(hp.aa_impl, "sharded"),
        local_impl=resolve_local_impl(hp.local_impl, "sharded"))
    axes = client_mesh_axes(mesh) if client_axes is None else tuple(client_axes)
    if not axes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} contain none of {CLIENT_MESH_AXES}; "
            "build the mesh with launch/mesh.py"
        )
    n_shards = num_client_shards(mesh, axes)
    C = problem.clients
    K = C.num_clients
    csize = resolve_cohort_size(hp, K)
    if csize is None and K % n_shards != 0:
        raise ValueError(
            f"num_clients={K} does not divide over {n_shards} client shards "
            f"(mesh axes {axes}); pad the client stack to a multiple"
        )
    if csize is not None and csize % n_shards != 0:
        raise ValueError(
            f"cohort_size={csize} does not divide over {n_shards} client "
            f"shards (mesh axes {axes}); pick a cohort that is a multiple"
        )
    channel = make_channel(channel)
    R = ShardReduce(axes, channel)
    comm_bytes = comm_bytes_per_round(algo, problem.init(jax.random.PRNGKey(0)),
                                      channel, hp.line_search)

    csh = P(axes)   # leading (client) dim split over the client mesh axes
    rep = P()       # replicated

    def prologue(state: ServerState):
        """Shared round prologue: rng splits + the cohort (or dense) plan.

        The gather stays at jit level, OUTSIDE shard_map: GSPMD reshards the
        gathered [C, ...] rows onto the client shards, so the mapped bodies
        and their in_specs are identical for both paths — only the leading
        axis extent changes. The scatter in _commit_plan likewise runs at jit
        level, writing the cohort rows back into the K-sized store."""
        rng, part_rng, cl_rng = jax.random.split(state.rng, 3)
        rngs_K = _split_client_rngs(cl_rng, K, mesh)
        return rng, _plan_round(problem, csize, state, part_rng, rngs_K)

    def smap(body, in_specs, out_specs):
        # check_vma off: the bodies close over `problem`/`hp` and batch psums
        # under vmap (line search), which older jax replication checks reject.
        return shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)

    # ---------------- fault injection (repro/robust) ----------------
    # python-gated exactly like the vmap builder: an absent/inactive plan
    # compiles the identical fault-free graph (no extra smap args)
    faults = faults if (faults is not None and faults.active) else None
    if faults is not None:
        from repro.robust.faults import (FAULT_ANCHOR_KEY, FaultRealization,
                                         FaultyReduce, advance_anchor,
                                         drop_weights, freeze_dropped,
                                         realize)

    def fault_ctx(plan, t):
        """jit-level (OUTSIDE shard_map) realization + weight adjustment.
        Returns (dweight, pweight, realization, extra-smap-args): the [C]
        fault arrays ride into the body client-sharded like every other
        per-client row."""
        if faults is None:
            return plan.dweight, plan.pweight, None, ()
        fr = realize(faults, t, K, plan.idx)
        dw, pw = plan.dweight, plan.pweight
        if faults.drop_rate > 0.0:
            pw = drop_weights(fr.drop, pw)
            if algo in ("scaffold", "fedosaa_scaffold"):
                # single exchange: the control variates ride the lost uplink
                dw = drop_weights(fr.drop, dw)
        return dw, pw, fr, tuple(fr)

    def fault_reduce(e, fxa):
        """Inside the body: rebuild the shard-local realization and wrap the
        reduce. Returns (reduce, realization-or-None)."""
        if not fxa:
            return R, None
        frl = FaultRealization(*fxa)
        anchors = e[FAULT_ANCHOR_KEY] if faults.stale_rate > 0.0 else None
        return FaultyReduce(R, faults, frl, anchors), frl

    def fault_epilogue(plan, fr, w_t, upd):
        """jit-level post-core landing: stale-anchor refresh, then the
        dropped-row bit-freeze (a dropped client's refreshed anchor must
        freeze back too) — same order as the vmap builder."""
        if faults is None:
            return upd
        if faults.stale_rate > 0.0 and upd.get("comm") is not None:
            upd = {**upd, "comm": advance_anchor(upd["comm"], fr.stale, w_t)}
        if faults.drop_rate > 0.0:
            upd = freeze_dropped(fr.drop, plan.cohort, upd)
        return upd

    fsp = () if faults is None else (csh,) * len(FaultRealization._fields)

    # ---------------- deadline gate (repro/robust/async_agg) ----------------
    # python-gated exactly like the fault plan: an absent/inactive config
    # compiles the byte-identical barriered round (no extra smap outputs)
    async_cfg = async_cfg if (async_cfg is not None and async_cfg.active) \
        else None
    if async_cfg is not None:
        if algo in ("giant", "newton_gmres"):
            raise ValueError(
                f"AsyncConfig requires a delta-form model aggregation; "
                f"{algo!r} aggregates Newton directions and cannot buffer "
                "client deltas")
        from repro.robust.async_agg import (ASYNC_AGE_KEY, ASYNC_BUF_KEY,
                                            CaptureReduce, advance_buffer,
                                            async_round_stats, fold_buffered,
                                            guard_history_rows, plan_async)
        from repro.robust.faults import _bc

    asp = () if async_cfg is None else (csh,)

    def async_ctx(plan, fr, dw, pw):
        """jit-level (OUTSIDE shard_map) deadline-gate partition + discounted
        weights — the same plan_async call the vmap builder makes, so both
        runtimes gate identical rounds."""
        if async_cfg is None:
            return dw, pw, None
        latency = fr.latency if fr is not None else jnp.zeros_like(pw)
        drop = fr.drop if (faults is not None and faults.drop_rate > 0.0) \
            else None
        ar = plan_async(async_cfg, latency,
                        plan.cohort.comm[ASYNC_AGE_KEY], pw, drop=drop)
        if algo in ("scaffold", "fedosaa_scaffold"):
            # control variates ride the model uplink: only fresh arrivals
            # contribute to the c aggregation (the buffer holds model deltas
            # only — a fold's c_up is lost on the floor)
            dwz = jnp.where(ar.fresh, dw, jnp.zeros_like(dw))
            dw = dwz / jnp.maximum(jnp.sum(dwz), 1e-30)
        return dw, ar.fresh_weights, ar

    def async_reduce(Rb):
        """Inside the body: wrap the (possibly faulty) reduce so the anchored
        model uplink's post-codec rows can leave as an extra sharded output."""
        return CaptureReduce(Rb) if async_cfg is not None else Rb

    def async_out(Rb):
        return (Rb.captured,) if async_cfg is not None else ()

    def async_epilogue(plan, ar, captured, w_t, new_params, upd):
        """jit-level buffer fold + transition, run AFTER fault_epilogue —
        identical logic to the vmap builder (see make_round_fn)."""
        if async_cfg is None:
            return new_params, upd, None
        comm_in = plan.cohort.comm
        new_params = fold_buffered(new_params, ar.fold_weights,
                                   comm_in[ASYNC_BUF_KEY])
        delta = jax.tree.map(lambda cap, w: cap - w, captured, w_t)
        new_buf, new_age = advance_buffer(ar, delta, comm_in[ASYNC_BUF_KEY],
                                          comm_in[ASYNC_AGE_KEY])
        comm = dict(upd["comm"] if upd.get("comm") is not None else comm_in)
        comm[ASYNC_BUF_KEY] = new_buf
        comm[ASYNC_AGE_KEY] = new_age
        upd = {**upd, "comm": comm}
        if upd.get("c_k") is not None:
            # a non-fresh client's control-variate update never arrived
            old_ck = plan.cohort.c_k
            upd["c_k"] = jax.tree.map(
                lambda o, n: jnp.where(_bc(~ar.fresh, n), o, n),
                old_ck, upd["c_k"])
        if async_cfg.guard_history:
            upd = guard_history_rows(ar.fold | ar.retain, plan.cohort, upd)
        return new_params, upd, async_round_stats(ar)

    # NOTE: optional per-client state (carried AA history, error-feedback
    # residuals) passes through shard_map as None when absent — None is an
    # empty pytree, so the csh spec sharding it has no leaves to act on and
    # one body covers every combination.

    # ---------------- SVRG family ----------------
    if algo in ("fedsvrg", "fedosaa_svrg"):
        use_aa = algo == "fedosaa_svrg"

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            dw, pw, fr, fx = fault_ctx(plan, state.t)
            dw, pw, ar = async_ctx(plan, fr, dw, pw)
            carry = hp.carry_history > 0 and state.hist_s is not None

            def body(w_t, x, y, mask, dw_, pw_, r, hs, hy, e, *fxa):
                Rb, frl = fault_reduce(e, fxa)
                Rb = async_reduce(Rb)
                kw = {}
                if frl is not None and faults.poisons_history and use_aa:
                    kw = dict(poison=(frl.byz, frl.keys),
                              poison_scale=faults.byz_scale)
                out = _svrg_round_core(
                    problem, hp, use_aa, Rb, w_t, x, y, mask, dw_, pw_, r,
                    hs, hy, e, **kw)
                return out + async_out(Rb)

            outs = smap(
                body,
                in_specs=(rep, csh, csh, csh, csh, csh, csh, csh, csh, csh)
                + fsp,
                out_specs=(rep, rep, csh, csh, csh) + asp,
            )(state.params, plan.x, plan.y, plan.mask, dw, pw, plan.rngs,
              plan.cohort.hist_s if carry else None,
              plan.cohort.hist_y if carry else None,
              plan.cohort.comm, *fx)
            captured = None
            if async_cfg is not None:
                *outs, captured = outs
            new_params, parts, new_hs, new_hy, new_comm = outs
            upd = dict(comm=new_comm)
            if carry:
                upd.update(hist_s=new_hs, hist_y=new_hy)
            upd = fault_epilogue(plan, fr, state.params, upd)
            new_params, upd, astats = async_epilogue(
                plan, ar, captured, state.params, new_params, upd)
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1, rng=rng,
                                  **upd), finalize_metrics(parts, comm_bytes,
                                                           astats)

        return round_fn

    # ---------------- SCAFFOLD family ----------------
    if algo in ("scaffold", "fedosaa_scaffold"):
        use_aa = algo == "fedosaa_scaffold"

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            dw, pw, fr, fx = fault_ctx(plan, state.t)
            dw, pw, ar = async_ctx(plan, fr, dw, pw)

            def body(w_t, c, x, y, mask, c_k, dw_, pw_, r, e, *fxa):
                Rb, _ = fault_reduce(e, fxa)
                Rb = async_reduce(Rb)
                out = _scaffold_round_core(
                    problem, hp, use_aa, Rb, w_t, c, x, y, mask, c_k, dw_,
                    pw_, r, e)
                return out + async_out(Rb)

            outs = smap(
                body,
                in_specs=(rep, rep, csh, csh, csh, csh, csh, csh, csh, csh)
                + fsp,
                out_specs=(rep, rep, csh, rep, csh) + asp,
            )(state.params, state.c, plan.x, plan.y, plan.mask,
              plan.cohort.c_k, dw, pw, plan.rngs, plan.cohort.comm, *fx)
            captured = None
            if async_cfg is not None:
                *outs, captured = outs
            new_params, new_c, new_c_k, parts, new_comm = outs
            upd = fault_epilogue(plan, fr, state.params,
                                 dict(c_k=new_c_k, comm=new_comm))
            new_params, upd, astats = async_epilogue(
                plan, ar, captured, state.params, new_params, upd)
            if ar is not None:
                # c's aggregation is not delta-form: a zero-fresh round would
                # zero the server control variate, so keep the old c instead
                any_fresh = jnp.any(ar.fresh)
                new_c = jax.tree.map(
                    lambda n, o: jnp.where(any_fresh, n, o), new_c, state.c)
            upd = _commit_plan(plan, **upd)
            return (
                state._replace(params=new_params, c=new_c, t=state.t + 1,
                               rng=rng, **upd),
                finalize_metrics(parts, comm_bytes, astats),
            )

        return round_fn

    # ---------------- AVG family (incl. negative control) ----------------
    if algo in ("fedavg", "fedosaa_avg"):
        use_aa = algo == "fedosaa_avg"

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            dw, pw, fr, fx = fault_ctx(plan, state.t)
            dw, pw, ar = async_ctx(plan, fr, dw, pw)

            def body(w_t, x, y, mask, dw_, pw_, r, e, *fxa):
                Rb, _ = fault_reduce(e, fxa)
                Rb = async_reduce(Rb)
                out = _avg_round_core(
                    problem, hp, use_aa, Rb, w_t, x, y, mask, dw_, pw_, r, e)
                return out + async_out(Rb)

            outs = smap(
                body,
                in_specs=(rep, csh, csh, csh, csh, csh, csh, csh) + fsp,
                out_specs=(rep, rep, csh) + asp,
            )(state.params, plan.x, plan.y, plan.mask, dw, pw, plan.rngs,
              plan.cohort.comm, *fx)
            captured = None
            if async_cfg is not None:
                *outs, captured = outs
            new_params, parts, new_comm = outs
            upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
            new_params, upd, astats = async_epilogue(
                plan, ar, captured, state.params, new_params, upd)
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1,
                                  rng=rng, **upd), finalize_metrics(
                                      parts, comm_bytes, astats)

        return round_fn

    # ---------------- one-step L-BFGS ----------------
    if algo == "lbfgs":

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            dw, pw, fr, fx = fault_ctx(plan, state.t)
            dw, pw, ar = async_ctx(plan, fr, dw, pw)

            def body(w_t, x, y, mask, dw_, pw_, r, e, *fxa):
                Rb, _ = fault_reduce(e, fxa)
                Rb = async_reduce(Rb)
                out = _lbfgs_round_core(
                    problem, hp, Rb, w_t, x, y, mask, dw_, pw_, r, e)
                return out + async_out(Rb)

            outs = smap(
                body,
                in_specs=(rep, csh, csh, csh, csh, csh, csh, csh) + fsp,
                out_specs=(rep, rep, csh) + asp,
            )(state.params, plan.x, plan.y, plan.mask, dw, pw, plan.rngs,
              plan.cohort.comm, *fx)
            captured = None
            if async_cfg is not None:
                *outs, captured = outs
            new_params, parts, new_comm = outs
            upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
            new_params, upd, astats = async_epilogue(
                plan, ar, captured, state.params, new_params, upd)
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1,
                                  rng=rng, **upd), finalize_metrics(
                                      parts, comm_bytes, astats)

        return round_fn

    # ---------------- Newton-type ----------------
    if algo in ("giant", "newton_gmres"):
        client_fn = _client_giant if algo == "giant" else _client_newton_gmres

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            dw, pw, fr, fx = fault_ctx(plan, state.t)

            def body(w_t, x, y, mask, dw_, pw_, r, e, *fxa):
                Rb, _ = fault_reduce(e, fxa)
                return _newton_round_core(
                    problem, hp, client_fn, Rb, w_t, x, y, mask, dw_, pw_,
                    r, e)

            new_params, parts, new_comm = smap(
                body,
                in_specs=(rep, csh, csh, csh, csh, csh, csh, csh) + fsp,
                out_specs=(rep, rep, csh),
            )(state.params, plan.x, plan.y, plan.mask, dw, pw, plan.rngs,
              plan.cohort.comm, *fx)
            upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1,
                                  rng=rng, **upd), finalize_metrics(parts, comm_bytes)

        return round_fn

    # ---------------- DANE ----------------
    assert algo == "dane"

    def round_fn(state: ServerState):
        rng, plan = prologue(state)
        dw, pw, fr, fx = fault_ctx(plan, state.t)
        dw, pw, ar = async_ctx(plan, fr, dw, pw)

        def body(w_t, x, y, mask, dw_, pw_, r, e, *fxa):
            Rb, _ = fault_reduce(e, fxa)
            Rb = async_reduce(Rb)
            out = _dane_round_core(problem, hp, Rb, w_t, x, y, mask, dw_,
                                   pw_, r, e)
            return out + async_out(Rb)

        outs = smap(
            body,
            in_specs=(rep, csh, csh, csh, csh, csh, csh, csh) + fsp,
            out_specs=(rep, rep, csh) + asp,
        )(state.params, plan.x, plan.y, plan.mask, dw, pw,
          plan.rngs, plan.cohort.comm, *fx)
        captured = None
        if async_cfg is not None:
            *outs, captured = outs
        new_params, parts, new_comm = outs
        upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
        new_params, upd, astats = async_epilogue(
            plan, ar, captured, state.params, new_params, upd)
        upd = _commit_plan(plan, **upd)
        return state._replace(params=new_params, t=state.t + 1,
                              rng=rng, **upd), finalize_metrics(
                                  parts, comm_bytes, astats)

    return round_fn
