"""Cohort-resident client state: the K-sized store behind a sampled round.

FL is "a large number of clients": the per-client state a server carries —
control variates (``ServerState.c_k``), carried AA history columns
(``hist_s``/``hist_y``), per-tag comm buffers (error-feedback residuals,
diff-coding references) — scales O(K·d), but a round only ever *computes* on
the sampled cohort of C ≪ K clients. ``ClientStateStore`` is the seam
between the two regimes:

  * the store OWNS the [K, ...] buffers (allocated once by
    ``init_state``/``init_comm_state``, donated through the round engine);
  * ``gather(idx)`` slices the cohort's [C, ...] rows — the ONLY view the
    round cores (core/algorithms.py) and the shard_mapped runtime
    (core/sharded.py) ever see;
  * ``scatter(idx, rows)`` writes the updated cohort rows back in place
    (``.at[idx].set`` — XLA aliases the donated buffer, so the store is
    updated without a second K-sized allocation). Rows outside the cohort
    are BIT-FROZEN: a client that did not participate cannot advance its
    error-feedback residual or diff-coding reference, exactly as a real
    deployment's offline client keeps its local state
    (tests/test_cohort.py pins this bitwise).

Fields mirror the per-client slots of ``ServerState``; a field that is None
(algorithm carries no such state) stays None through gather/scatter, and a
field that is None in the ``scatter`` update is left untouched — no scatter
op is even emitted, so e.g. a FedOSAA-SVRG round without carried history
never materializes a [K, d] operation (the jaxpr assertion in
tests/test_cohort.py).

The ``comm`` slot additionally carries the robustness layer's RESERVED
dunder keys — ``__fault_anchor__`` (repro.robust.faults: per-client lagged
anchors for stale-update injection), ``__async_buf__`` and ``__async_age__``
(repro.robust.async_agg: the deadline gate's carried straggler deltas and
their integer ages). They are ordinary [K, ...] comm entries on purpose:
riding the comm slot is what makes them survive cohort gather/scatter and
checkpoints with zero extra plumbing.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

Pytree = Any


def gather_rows(tree: Pytree, idx: jax.Array) -> Pytree:
    """Leaf-wise ``leaf[idx]``: the [C, ...] cohort rows of a [K, ...] pytree."""
    return jax.tree.map(lambda b: b[idx], tree)


def scatter_rows(full: Pytree, idx: jax.Array, rows: Pytree) -> Pytree:
    """Leaf-wise ``full.at[idx].set(rows)``; rows outside ``idx`` untouched.

    ``unique_indices=True`` — cohorts are sampled WITHOUT replacement
    (core/algorithms._sample_cohort), which lets XLA lower a plain
    (aliasable) scatter instead of a serialized combiner.
    """
    return jax.tree.map(
        lambda f, r: f.at[idx].set(r, unique_indices=True), full, rows
    )


class ClientStateStore(NamedTuple):
    """The per-client [K, ...] slots of a ServerState as one gather/scatter
    unit. Construct with :meth:`from_state`; fields absent from the
    algorithm's state are None and pass through untouched."""

    c_k: Pytree = None      # [K, ...] client control variates
    hist_s: Pytree = None   # [K, H, ...] carried AA columns
    hist_y: Pytree = None
    comm: Pytree = None     # {tag: {"ef"/"ref": [K, ...]}} wire state

    @classmethod
    def from_state(cls, state) -> "ClientStateStore":
        return cls(c_k=state.c_k, hist_s=state.hist_s, hist_y=state.hist_y,
                   comm=state.comm)

    @property
    def num_clients(self) -> int:
        leaves = jax.tree.leaves(self)
        if not leaves:
            raise ValueError("empty ClientStateStore has no client axis")
        return leaves[0].shape[0]

    def gather(self, idx: jax.Array) -> "ClientStateStore":
        """The cohort's [C, ...] rows (None fields stay None)."""
        return ClientStateStore(
            *(None if f is None else gather_rows(f, idx) for f in self)
        )

    def scatter(self, idx: jax.Array, rows: "ClientStateStore") -> "ClientStateStore":
        """Write updated [C, ...] rows back at ``idx``.

        A field that is None in ``rows`` is returned untouched — the SAME
        array object, so no scatter op enters the graph for state the round
        never advanced. Rows outside ``idx`` keep their bits.
        """
        return ClientStateStore(*(
            full if (full is None or upd is None)
            else scatter_rows(full, idx, upd)
            for full, upd in zip(self, rows)
        ))
