from repro.core.anderson import (  # noqa: F401
    AA_IMPLS,
    AAConfig,
    AAStats,
    aa_mixing_step,
    lbfgs_two_loop,
    multisecant_update,
    resolve_aa_impl,
    trajectory_to_sy,
)
from repro.core.engine import (  # noqa: F401
    METRIC_FIELDS,
    RoundTrace,
    make_chunk_runner,
    run_rounds,
)
from repro.core.algorithms import (  # noqa: F401
    ALGORITHMS,
    COMM_TABLE,
    LOCAL_IMPLS,
    TRAJECTORY_ALGOS,
    UPLINK_SCHEMAS,
    AlgoHParams,
    CommCost,
    RoundMetrics,
    ServerState,
    comm_bytes_per_round,
    comm_floats_per_round,
    fused_local_eligible,
    init_comm_state,
    init_state,
    make_round_fn,
    resolve_cohort_size,
    resolve_local_impl,
)
from repro.core.client_store import (  # noqa: F401
    ClientStateStore,
    gather_rows,
    scatter_rows,
)
from repro.comm.schema import UplinkSpec  # noqa: F401
from repro.comm import CommChannel, make_channel  # noqa: F401
from repro.core.sharded import make_sharded_round_fn  # noqa: F401
from repro.core.problem import (  # noqa: F401
    ClientBatch,
    FLProblem,
    LinearDesign,
    StackedClients,
    sample_minibatch,
    sample_minibatch_indices,
    stack_client_arrays,
)
from repro.core.server import History, run_federated, solve_reference  # noqa: F401
