"""Federated optimization algorithms (paper §2, §4, Appendix D.1).

Implemented, all under one jittable round API:

  fedavg            — McMahan et al. baseline (no correction)
  fedsvrg           — SVRG-corrected local steps (= FedLin)
  scaffold          — control-variate corrected local steps (paper's variant:
                      c = ∇f(w^{t-1}), c_k = ∇f_k(w^{t-1}))
  fedosaa_svrg      — THE PAPER: FedSVRG local steps + one AA step (Alg. 1)
  fedosaa_scaffold  — SCAFFOLD local steps + one AA step (Alg. 2)
  fedosaa_avg       — negative control (Appendix D.4): AA on uncorrected steps
  lbfgs             — one-step L-BFGS on the same S/Y data (App. D.1)
  giant             — local Newton-CG on the global gradient (Wang et al.)
  newton_gmres      — GIANT with GMRES in place of CG (= Newton-MINRES)
  dane              — exact local minimization of the DANE surrogate

Every round function has signature  round(state) -> (state, RoundMetrics)
and is a pure jax function: K clients are vmapped (stacked data), so a full
round is ONE XLA computation. The distributed runtime (core/sharded.py) runs
the SAME per-client bodies and round cores, but partitions the client axis
over the ("pod","data") mesh axes with shard_map and reduces via psum.

Layering (shared between the two runtimes):

  _client_*            per-client update bodies (one client's arrays in)
  _*_round_core        one round's cross-client math, written against a
                       CrossClientReduce so the SAME code runs under vmap
                       (plain reductions) and shard_map (psum reductions)
  make_round_fn        vmap runtime: prologue (rng/participation) + core
  make_sharded_round_fn(core/sharded.py): same prologue, core under shard_map
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import CommChannel, IDENTITY_CHANNEL, IdentityCodec, make_channel
from repro.comm.schema import (
    CTRL_UPLINK,
    DELTA_UPLINK,
    DIR_UPLINK,
    GRAD_UPLINK,
    UplinkSpec,
    init_schema_state,
    uplink_byte_breakdown,
    validate_schema,
)
from repro.core.anderson import (
    AAConfig,
    AAStats,
    lbfgs_two_loop,
    multisecant_update,
    resolve_aa_impl,
    trajectory_to_sy,
)
from repro.core.client_store import ClientStateStore
from repro.core.problem import (
    ClientBatch,
    FLProblem,
    sample_minibatch,
    sample_minibatch_indices,
)
from repro.utils import tree_math as tm

Pytree = Any

ALGORITHMS = (
    "fedavg", "fedsvrg", "scaffold",
    "fedosaa_svrg", "fedosaa_scaffold", "fedosaa_avg",
    "lbfgs", "giant", "newton_gmres", "dane",
)

class CommCost(NamedTuple):
    """Per-round communication accounting (paper Table 1).

    round_trips — synchronous server↔client exchanges per aggregation round.
      Methods needing the global gradient ∇f(w^t) before local work (SVRG
      family, L-BFGS, GIANT, Newton-GMRES, DANE) pay 2: one to collect local
      gradients, one to broadcast (w^t, ∇f) and collect results. FedAvg and
      SCAFFOLD piggyback everything on a single exchange.
    float_units — client-uplink floats per round, in units of d (the Table 1
      'cost' column): 1 for a model delta alone, 2 when a gradient or a
      control variate travels alongside it.
    """

    round_trips: int
    float_units: float

COMM_TABLE = {
    "fedavg":           CommCost(1, 1.0),
    "fedsvrg":          CommCost(2, 2.0),
    "scaffold":         CommCost(1, 2.0),
    "fedosaa_svrg":     CommCost(2, 2.0),
    "fedosaa_scaffold": CommCost(1, 2.0),
    "fedosaa_avg":      CommCost(1, 1.0),
    "lbfgs":            CommCost(2, 2.0),
    "giant":            CommCost(2, 2.0),
    "newton_gmres":     CommCost(2, 2.0),
    "dane":             CommCost(2, 2.0),
}


# --------------------------------------------------------------------------
# declarative uplink schemas (comm/schema.py)
#
# One UplinkSpec record per wire crossing of a round, in round order. The
# schema is what makes every algorithm's wire STATEFUL under a lossy channel:
# init_comm_state allocates exactly the buffers each record needs, and
# CrossClientReduce.uplink resolves error-feedback residuals and diff-coding
# references from ServerState.comm by the record's tag — uniformly, for the
# SVRG/SCAFFOLD families and the Newton family alike. A new algorithm gets a
# stateful wire by declaring its schema here; it cannot silently opt out.
# --------------------------------------------------------------------------

_SVRG_UPLINKS = validate_schema((GRAD_UPLINK, DELTA_UPLINK))
_SCAFFOLD_UPLINKS = validate_schema((DELTA_UPLINK, CTRL_UPLINK))
_AVG_UPLINKS = validate_schema((DELTA_UPLINK,))
_NEWTON_UPLINKS = validate_schema((GRAD_UPLINK, DIR_UPLINK))

UPLINK_SCHEMAS: "dict[str, tuple[UplinkSpec, ...]]" = {
    "fedavg":           _AVG_UPLINKS,
    "fedosaa_avg":      _AVG_UPLINKS,
    "fedsvrg":          _SVRG_UPLINKS,
    "fedosaa_svrg":     _SVRG_UPLINKS,
    "scaffold":         _SCAFFOLD_UPLINKS,
    "fedosaa_scaffold": _SCAFFOLD_UPLINKS,
    "lbfgs":            _SVRG_UPLINKS,
    "giant":            _NEWTON_UPLINKS,
    "newton_gmres":     _NEWTON_UPLINKS,
    "dane":             _SVRG_UPLINKS,
}

#: union of every tag — the allocation for algorithm-agnostic callers
#: (init_state(algo=None)); unused tags ride through rounds untouched
DEFAULT_SCHEMA = validate_schema(
    (GRAD_UPLINK, DELTA_UPLINK, CTRL_UPLINK, DIR_UPLINK))


def comm_floats_per_round(algo: str, d: int, line_search: bool = False) -> float:
    """Floats on the wire for one round of ``algo`` on a d-parameter model.

    The GIANT-style backtracking line search needs the *aggregated* direction
    p broadcast back to clients before the step size is chosen — one extra
    d-float downlink on top of the Table 1 units.
    """
    cost = COMM_TABLE[algo]
    extra = float(d) if (line_search and algo in ("giant", "newton_gmres")) else 0.0
    return cost.float_units * d + extra


def comm_bytes_per_round(algo: str, params: Pytree,
                         channel: "CommChannel | str | None" = None,
                         line_search: bool = False) -> float:
    """Bytes on the wire for one round of ``algo`` through ``channel``.

    Accounted from the algorithm's declarative uplink schema: each UplinkSpec
    is charged its codec-exact bytes at its kind's rate (int8 pays 1
    byte/value plus one f32 scale per chunk, topk pays 8 bytes per kept
    entry, aux uploads of a delta-only codec pay fp32 — repro/comm), plus the
    GIANT line-search extra broadcast at the downlink codec's rate.
    Per-client scalar uplinks (losses, AA stats) are ignored, as the paper's
    Table 1 ignores them; the schema lengths equal Table 1's float_units
    (asserted in tests), so the identity channel reproduces the historical
    counters exactly: bytes == 4 × comm_floats_per_round.
    """
    channel = make_channel(channel)
    total = sum(
        uplink_byte_breakdown(channel, UPLINK_SCHEMAS[algo], params).values())
    if line_search and algo in ("giant", "newton_gmres"):
        total += channel.downlink_bytes(params)
    return float(total)


@dataclasses.dataclass(frozen=True)
class AlgoHParams:
    """Tuning knobs shared by all algorithms (paper §4 / Appendix D.1)."""

    eta: float = 1.0            # local learning rate η
    local_epochs: int = 10      # L (== q CG/GMRES iterations for Newton-type)
    batch_size: int | None = None   # None => full-batch local gradients
    aa: AAConfig = AAConfig()
    line_search: bool = False   # GIANT-style global backtracking
    participation: float = 1.0  # fraction of clients active per round (ext.):
                                # < 1 samples a ⌈pK⌉-client cohort each round
                                # (resolve_cohort_size / _sample_cohort)
    cohort_size: int | None = None  # explicit per-round cohort size C: the
                                # round computes on C gathered clients over
                                # the K-sized ClientStateStore (O(C·d) round
                                # compute, O(K·d) store); None derives C from
                                # ``participation`` (full participation keeps
                                # the dense all-K path). Takes precedence
                                # over ``participation`` when both are set.
    carry_history: int = 0      # extra (s,y) columns carried ACROSS rounds
                                # (paper App. A option 1; FedOSAA-SVRG only)
    dane_newton_iters: int = 20
    dane_cg_iters: int = 100
    aa_impl: str = "auto"       # AA-step implementation: "tree" (leaf-wise
                                # tree_math), "pallas" (fused single-pass
                                # kernels on per-dtype flat buffers; vmap
                                # runtime only), "auto" (pallas on TPU).
                                # The sharded runtime always falls back to
                                # "tree" (see core/anderson.resolve_aa_impl).
    local_impl: str = "auto"    # local-trajectory implementation: "tree"
                                # (autodiff residuals — 2 loss autodiffs =
                                # 4 design-matrix sweeps per local step),
                                # "pallas" (fused dual-gradient kernels,
                                # kernels/local_update — ONE X sweep per
                                # step, at best fully VMEM-resident; only
                                # for linear-design models, see
                                # resolve_local_impl), "auto" (pallas on
                                # TPU where eligible). The sharded runtime
                                # always falls back to "tree", like aa_impl.


class ServerState(NamedTuple):
    params: Pytree
    c: Pytree        # server control variate (SCAFFOLD family; zeros otherwise)
    c_k: Pytree      # [K, ...] client control variates
    t: jax.Array
    rng: jax.Array
    hist_s: Pytree = None   # [K, H, ...] carried AA columns (App. A opt. 1)
    hist_y: Pytree = None
    comm: Pytree = None     # client-side wire-compression state (repro/comm):
                            # {tag: {...}} keyed by the algorithm's uplink
                            # schema (UPLINK_SCHEMAS), per-client [K, ...]
                            # buffers per tag —
                            #   "ef":  error-feedback residuals, re-injected
                            #          into the next upload (lossy codecs)
                            #   "ref": difference-coding reference for
                            #          absolute-state ("aux") uploads
                            #          (gradients, control variates): the
                            #          wire carries g_k − h_k so quantization
                            #          noise decays with the diff instead of
                            #          staying O(1)


class RoundMetrics(NamedTuple):
    loss: jax.Array          # global f(w^t) before the update
    grad_norm: jax.Array     # ‖∇f(w^t)‖ (or control-variate norm for scaffold)
    theta_mean: jax.Array    # mean AA optimization gain across clients (nan if n/a)
    gram_cond_max: jax.Array # worst AA Gram conditioning (nan if n/a)
    gram_cond_mean: jax.Array  # mean AA Gram conditioning (nan if n/a)
    aa_used_min: jax.Array   # fewest AA columns surviving filtering on any
                             # client (nan if n/a; 0 = filtering collapse)
    aa_clipped_max: jax.Array  # most history columns the clip_rtol byzantine
                             # screen dropped on any client (nan if n/a;
                             # 0 whenever the screen is off or inactive)
    cohort_ess: jax.Array    # effective sample size 1/Σw² of the round's
                             # aggregation weights (== C for a uniform cohort)
    comm_bytes: jax.Array    # bytes on the wire this round (codec-exact;
                             # == 4 × Table 1 float units on the fp32 channel)
    arrivals: jax.Array      # deadline-gated rounds: clients whose update
                             # landed this round, fresh or buffered (nan when
                             # AsyncConfig is off — the barriered round)
    staleness_mean: jax.Array  # mean buffer age over this round's landed
                             # contributions, fresh counting as 0 (nan when
                             # async is off or nothing landed)
    staleness_max: jax.Array   # oldest landed contribution's buffer age (nan
                             # when async is off or nothing landed); feeds the
                             # staleness_runaway alarm


def init_state(problem: FLProblem, rng: jax.Array,
               hp: "AlgoHParams | None" = None,
               channel: "CommChannel | str | None" = None,
               algo: str | None = None) -> ServerState:
    rng, init_rng = jax.random.split(rng)
    params = problem.init(init_rng)
    zeros = tm.tree_zeros_like(params)
    K = problem.clients.num_clients
    c_k = jax.tree.map(lambda z: jnp.zeros((K,) + z.shape, z.dtype), zeros)
    hist_s = hist_y = None
    if hp is not None and hp.carry_history > 0:
        H = hp.carry_history
        hist_s = jax.tree.map(
            lambda z: jnp.zeros((K, H) + z.shape, z.dtype), zeros)
        hist_y = jax.tree.map(
            lambda z: jnp.zeros((K, H) + z.shape, z.dtype), zeros)
    channel = make_channel(channel)
    comm = init_comm_state(channel, params, K, algo)
    return ServerState(params, zeros, c_k, jnp.zeros((), jnp.int32), rng,
                       hist_s, hist_y, comm)


def init_comm_state(channel: CommChannel, params: Pytree, K: int,
                    algo: str | None = None) -> Pytree:
    """Per-client carried comm state, allocated from the algorithm's
    declarative uplink schema (None when no uplink carries buffers).

    See ServerState.comm. ``algo`` selects its UPLINK_SCHEMAS entry so
    buffers its round function never reads are not allocated — the AVG family
    has no aux uplink, the Newton family carries "grad"/"dir" instead of
    "grad"/"delta"; at LM scale each skipped buffer is a K×d array.
    ``algo=None`` allocates the union DEFAULT_SCHEMA for algorithm-agnostic
    callers. The store is allocated ONCE at K; a cohort round (participation
    < 1 or an explicit ``cohort_size``) gathers only its C sampled rows into
    the compiled round body and scatters the updated rows back, so a client
    outside the cohort keeps its error-feedback residual / diff-coding
    reference bit-frozen — exactly the offline-client semantics of a real
    deployment (pinned in tests/test_cohort.py).
    """
    schema = DEFAULT_SCHEMA if algo is None else UPLINK_SCHEMAS[algo]
    return init_schema_state(channel, schema, params, K)


# --------------------------------------------------------------------------
# local trajectories
# --------------------------------------------------------------------------

#: legal values of the local-trajectory implementation knob
#: (AlgoHParams.local_impl)
LOCAL_IMPLS = ("auto", "tree", "pallas")

#: private, benchmark-only value: the SEED driver's trajectory form
#: (pre-PR5 L-step scan + standalone r_L dispatch + per-leaf concatenate
#: epilogue). bench_round.py's seed_loop mode replays it so the committed
#: "vs seed" timings stay comparable across PRs; bit-identical VALUES to
#: the folded scan, deliberately not in LOCAL_IMPLS.
LOCAL_IMPL_SEED = "tree_seed"

#: algorithms whose local work is the L-step corrected-GD trajectory — the
#: only ones the fused kernels apply to (the Newton family runs CG/GMRES
#: matvecs, not a trajectory)
TRAJECTORY_ALGOS = ("fedavg", "fedsvrg", "scaffold", "fedosaa_svrg",
                    "fedosaa_scaffold", "fedosaa_avg", "lbfgs")


def fused_local_eligible(problem: FLProblem, algo: str | None = None,
                         params: Pytree | None = None) -> bool:
    """Can ``algo`` on ``problem`` run the fused local-trajectory kernels?

    Requires the model to declare the linear-design protocol
    (FLProblem.linear_design — logreg/linreg do, MLP/decoder do not), the
    params pytree to BE a single flat [d] array (not merely contain one —
    the fused path returns [steps, d] arrays in the params' structure), and
    a trajectory-based algorithm. Everything else keeps the autodiff path.
    """
    if problem.linear_design is None:
        return False
    if algo is not None and algo not in TRAJECTORY_ALGOS:
        return False
    if params is None:
        params = problem.init(jax.random.PRNGKey(0))
    return isinstance(params, jax.Array) and params.ndim == 1


def resolve_local_impl(impl: str, runtime: str = "vmap",
                       problem: FLProblem | None = None,
                       algo: str | None = None,
                       params: Pytree | None = None) -> str:
    """Resolve the ``local_impl`` knob to a concrete "tree"/"pallas".

    Mirrors core/anderson.resolve_aa_impl: "auto" picks the fused path
    where the kernels compile natively (TPU) and the autodiff path
    elsewhere; the sharded runtime ALWAYS resolves to "tree" (client data
    shards stay put; the fused ravel assumes whole per-client designs), and
    an ineligible problem/algorithm (see fused_local_eligible) falls back
    to "tree" without error, as documented — so MLP/decoder and the Newton
    family simply keep autodiff even under an explicit "pallas".
    """
    if impl not in LOCAL_IMPLS + (LOCAL_IMPL_SEED,):
        raise ValueError(f"unknown local_impl {impl!r}; choose from {LOCAL_IMPLS}")
    if impl == LOCAL_IMPL_SEED:   # benchmark-only seed replay, any runtime
        return impl
    if runtime == "sharded" or impl == "tree":
        return "tree"
    if problem is not None and not fused_local_eligible(problem, algo, params):
        return "tree"
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "tree"
    return impl


def _local_trajectory(
    hp: AlgoHParams,
    w0: Pytree,
    residual_fn: Callable[[Pytree, jax.Array], Pytree],
    rng: jax.Array,
):
    """Run L corrected-GD steps from w0 and return the full trajectory.

    Returns (w_traj, r_traj) with leading axis L+1 — FedOSAA evaluates L+1
    gradients (Alg. 1 needs r_L for the last Y column). One scan over L+1
    step keys emits every (w_ℓ, r_ℓ) pair directly: the final residual is
    just the last scan iteration (its unused w_{L+1} is a single axpy), so
    there is no per-leaf concatenate epilogue and no standalone r_L
    dispatch in either runtime.
    """
    rngs = jax.random.split(rng, hp.local_epochs + 1)

    def step(w, step_rng):
        r = residual_fn(w, step_rng)
        return tm.tree_axpy(-hp.eta, r, w), (w, r)

    if hp.local_impl == LOCAL_IMPL_SEED:
        # the seed form, replayed for bench_round's baseline: scan stops at
        # L, r_L dispatches standalone, the history is concatenated per leaf
        L = hp.local_epochs
        w_L, (w_hist, r_hist) = jax.lax.scan(step, w0, rngs[:L])
        r_L = residual_fn(w_L, rngs[L])
        w_traj = jax.tree.map(
            lambda h, last: jnp.concatenate([h, last[None]], axis=0),
            w_hist, w_L)
        r_traj = jax.tree.map(
            lambda h, last: jnp.concatenate([h, last[None]], axis=0),
            r_hist, r_L)
        return w_traj, r_traj

    with jax.named_scope("fl.local_trajectory"):
        _, (w_traj, r_traj) = jax.lax.scan(step, w0, rngs)
    return w_traj, r_traj


def _fused_trajectory(
    problem: FLProblem,
    hp: AlgoHParams,
    w0: Pytree,
    batch: ClientBatch,
    anchor_scale: float,
    corr: Pytree | None,
    rng: jax.Array,
):
    """The fused linear-design twin of _local_trajectory
    (kernels/local_update): both residual gradients of every local step ride
    ONE design-matrix sweep, with the L-step loop VMEM-resident when the
    client's block fits.

    The residual family is r(w;ζ) = ∇f_k(w;ζ) − a·∇f_k(w^t;ζ) + corr, which
    in linear-design form collapses to Xᵀ(c(Xw) − a·c(Xw^t))/n + reg·w + u
    with u = corr − a·reg·w^t:  a=1/corr=∇f(w^t) is the SVRG family,
    a=0/corr=c−c_k is SCAFFOLD, a=0/corr=None is FedAvg. Minibatch mode
    draws the bit-identical per-step row gathers the autodiff path draws
    (sample_minibatch_indices) and evaluates live and anchor on the same
    rows, exactly like _make_residual_fn.
    """
    from repro.kernels.local_update import fused_trajectory

    design = problem.linear_design(batch)
    steps = hp.local_epochs + 1
    if hp.batch_size is None:
        x, y, mask = design.x[None], design.y[None], batch.mask[None]
    else:
        rngs = jax.random.split(rng, steps)
        idx = jax.vmap(
            lambda r: sample_minibatch_indices(batch.mask, r, hp.batch_size)
        )(rngs)
        x, y = design.x[idx], design.y[idx]
        mask = jnp.ones(idx.shape, batch.mask.dtype)
    u = tm.tree_zeros_like(w0) if corr is None else corr
    if anchor_scale:
        u = u - design.reg * w0
    with jax.named_scope("fl.local_trajectory"):
        return fused_trajectory(
            x, y, mask, w0, u, link=design.link, reg=design.reg, eta=hp.eta,
            anchor_scale=anchor_scale, steps=steps)


def _make_residual_fn(
    problem: FLProblem, hp: AlgoHParams, batch: ClientBatch, correction: Pytree | None
):
    """r(w; ζ) = ∇f_k(w; ζ) + correction(ζ).

    correction is either
      * a pytree  (SCAFFOLD: c − c_k — minibatch independent), or
      * a callable (w_anchor-based SVRG term: −∇f_k(w^t;ζ) + ∇f(w^t)), or
      * None (FedAvg).
    """
    def residual(w, rng):
        if hp.batch_size is None:
            mb = batch
        else:
            mb = sample_minibatch(batch, rng, hp.batch_size)
        g = problem.grad(w, mb)
        if correction is None:
            return g
        if callable(correction):
            return tm.tree_add(g, correction(mb))
        return tm.tree_add(g, correction)

    return residual


# --------------------------------------------------------------------------
# per-client updates (to be vmapped over the stacked client axis)
# --------------------------------------------------------------------------

def _svrg_trajectory(problem, hp, w_t, g_global, batch, rng):
    """SVRG-corrected trajectory: fused dual-gradient kernels when resolved,
    else the two-autodiff residual path."""
    if hp.local_impl == "pallas":
        return _fused_trajectory(problem, hp, w_t, batch, 1.0, g_global, rng)

    def svrg_correction(mb):
        # −∇f_k(w^t; ζ) + ∇f(w^t): the SAME minibatch ζ as the live gradient.
        return tm.tree_sub(g_global, problem.grad(w_t, mb))

    residual_fn = _make_residual_fn(problem, hp, batch, svrg_correction)
    return _local_trajectory(hp, w_t, residual_fn, rng)


def _client_svrg(problem, hp, use_aa, w_t, g_global, x, y, mask, rng,
                 hist_s=None, hist_y=None, poison=None):
    batch = ClientBatch(x, y, mask)
    w_traj, r_traj = _svrg_trajectory(problem, hp, w_t, g_global, batch, rng)
    nan_st = AAStats(jnp.nan, jnp.nan, jnp.nan, jnp.array(0), jnp.array(0))
    if not use_aa:
        w_k = jax.tree.map(lambda t: t[-1], w_traj)
        return (w_k, nan_st) if hist_s is None else (w_k, nan_st, hist_s, hist_y)
    s, y_stack = trajectory_to_sy(w_traj, r_traj, hp.aa.residual_ema)
    if poison is not None:
        # byzantine history fault (robust/faults.py, byz_mode="history"):
        # the client's dynamics ran clean but the recorded last residual
        # column is corrupted — injected AFTER the trajectory so exactly one
        # column is poisoned, the regime the clip_rtol screen defends (a
        # mid-flight corruption would propagate through the remaining local
        # steps and poison a majority of columns, defeating any per-client
        # median statistic)
        from repro.robust.faults import poison_last_column
        flag, fkey, scale = poison
        y_stack = poison_last_column(y_stack, flag, fkey, scale)
    if hist_s is not None:
        # App. A option 1: prepend columns carried from previous rounds
        # (stale anchors — valid secant pairs of nearby Jacobians; the
        # filtered/regularized LS solve absorbs the inconsistency)
        s_all = jax.tree.map(lambda h, f: jnp.concatenate([h, f], 0), hist_s, s)
        y_all = jax.tree.map(lambda h, f: jnp.concatenate([h, f], 0), hist_y, y_stack)
        w_k, stats = multisecant_update(w_t, g_global, s_all, y_all, hp.eta,
                                        hp.aa, impl=hp.aa_impl)
        Hn = hp.carry_history
        new_hs = jax.tree.map(lambda f: f[-Hn:], s)
        new_hy = jax.tree.map(lambda f: f[-Hn:], y_stack)
        return w_k, stats, new_hs, new_hy
    w_k, stats = multisecant_update(w_t, g_global, s, y_stack, hp.eta, hp.aa,
                                    impl=hp.aa_impl)
    return w_k, stats


def _client_scaffold(problem, hp, use_aa, w_t, c, x, y, mask, c_k, rng):
    batch = ClientBatch(x, y, mask)
    correction = tm.tree_sub(c, c_k)
    if hp.local_impl == "pallas":
        w_traj, r_traj = _fused_trajectory(problem, hp, w_t, batch, 0.0,
                                           correction, rng)
    else:
        residual_fn = _make_residual_fn(problem, hp, batch, correction)
        w_traj, r_traj = _local_trajectory(hp, w_t, residual_fn, rng)
    if use_aa:
        s, y_stack = trajectory_to_sy(w_traj, r_traj, hp.aa.residual_ema)
        w_k, stats = multisecant_update(w_t, c, s, y_stack, hp.eta, hp.aa,
                                        impl=hp.aa_impl)
    else:
        w_k = jax.tree.map(lambda t: t[-1], w_traj)
        stats = AAStats(jnp.nan, jnp.nan, jnp.nan, jnp.array(0), jnp.array(0))
    new_c_k = problem.grad(w_t, batch)     # c_k ← ∇f_k(w^t), full batch (Alg. 2)
    return w_k, new_c_k, stats


def _client_avg(problem, hp, use_aa, w_t, x, y, mask, rng):
    batch = ClientBatch(x, y, mask)
    if hp.local_impl == "pallas":
        w_traj, r_traj = _fused_trajectory(problem, hp, w_t, batch, 0.0,
                                           None, rng)
    else:
        residual_fn = _make_residual_fn(problem, hp, batch, None)
        w_traj, r_traj = _local_trajectory(hp, w_t, residual_fn, rng)
    if not use_aa:
        w_k = jax.tree.map(lambda t: t[-1], w_traj)
        return w_k, AAStats(jnp.nan, jnp.nan, jnp.nan, jnp.array(0), jnp.array(0))
    s, y_stack = trajectory_to_sy(w_traj, r_traj)
    # negative control: AA against the LOCAL gradient (no correction exists)
    g_local = jax.tree.map(lambda t: t[0], r_traj)
    w_k, stats = multisecant_update(w_t, g_local, s, y_stack, hp.eta, hp.aa,
                                    impl=hp.aa_impl)
    return w_k, stats


def _client_lbfgs(problem, hp, w_t, g_global, x, y, mask, rng):
    batch = ClientBatch(x, y, mask)
    w_traj, r_traj = _svrg_trajectory(problem, hp, w_t, g_global, batch, rng)
    s, y_stack = trajectory_to_sy(w_traj, r_traj)
    direction = lbfgs_two_loop(g_global, s, y_stack, hp.eta)
    w_k = tm.tree_sub(w_t, direction)
    return w_k, AAStats(jnp.nan, jnp.nan, jnp.nan, jnp.array(0), jnp.array(0))


def _cg_solve(matvec, b, iters: int):
    """Plain CG on a pytree SPD system, fixed iteration count (GIANT's q)."""
    x = tm.tree_zeros_like(b)
    r = b
    p = r
    rs = tm.tree_dot(r, r)

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = tm.tree_dot(p, ap)
        alpha = rs / jnp.maximum(denom, 1e-30)
        x = tm.tree_axpy(alpha, p, x)
        r = tm.tree_axpy(-alpha, ap, r)
        rs_new = tm.tree_dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = tm.tree_axpy(beta, p, r)
        return x, r, p, rs_new

    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
    return x


def _client_giant(problem, hp, w_t, g_global, x, y, mask):
    batch = ClientBatch(x, y, mask)
    matvec = lambda v: problem.hvp(w_t, batch, v)
    p_k = _cg_solve(matvec, g_global, hp.local_epochs)
    return p_k


def _client_newton_gmres(problem, hp, w_t, g_global, x, y, mask):
    batch = ClientBatch(x, y, mask)
    matvec = lambda v: problem.hvp(w_t, batch, v)
    p_k, _ = jax.scipy.sparse.linalg.gmres(
        matvec, g_global, maxiter=1, restart=hp.local_epochs, tol=0.0,
        solve_method="incremental",
    )
    return p_k


def _client_dane(problem, hp, w_t, g_global, x, y, mask):
    """Exact local minimization of h_k(w)=f_k(w) − <∇f_k(w^t) − ∇f(w^t), w>
    via damped Newton with backtracking (App. D.1: 'no tuning parameter')."""
    batch = ClientBatch(x, y, mask)
    g_k_t = problem.grad(w_t, batch)
    shift = tm.tree_sub(g_k_t, g_global)        # ∇h_k = ∇f_k(w) − shift

    def h_val(w):
        return problem.loss(w, batch) - tm.tree_dot(shift, w)

    def h_grad(w):
        return tm.tree_sub(problem.grad(w, batch), shift)

    def newton_step(w, _):
        g = h_grad(w)
        matvec = lambda v: problem.hvp(w, batch, v)
        p = _cg_solve(matvec, g, hp.dane_cg_iters)
        # backtracking on h along p
        f0 = h_val(w)
        gTp = tm.tree_dot(g, p)

        def try_step(a):
            return h_val(tm.tree_axpy(-a, p, w))

        steps = jnp.array([1.0, 0.5, 0.25, 0.125, 0.0625])
        vals = jnp.stack([try_step(a) for a in steps])
        ok = vals < f0 - 1e-4 * steps * gTp
        idx = jnp.argmax(ok)          # first satisfying Armijo; 0 if none true
        a = jnp.where(jnp.any(ok), steps[idx], 0.0)
        return tm.tree_axpy(-a, p, w), None

    w_k, _ = jax.lax.scan(newton_step, w_t, None, length=hp.dane_newton_iters)
    return w_k


# --------------------------------------------------------------------------
# cohort sampling (extension: partial client participation as the MEMORY
# model, not just an aggregation mask)
#
# A round with C < K computes on a sampled cohort: client data, rng keys and
# the per-client state rows (ClientStateStore: control variates, carried AA
# columns, comm buffers) are GATHERED to [C, ...] before the round core runs,
# and the updated rows are SCATTERED back afterwards — non-sampled clients'
# state is bit-frozen and the compiled round touches O(C·d), not O(K·d).
# The historical dense path (every client computes, which full participation
# still uses) remains the csize=None branch of _plan_round.
# --------------------------------------------------------------------------

def resolve_cohort_size(hp: AlgoHParams, num_clients: int) -> int | None:
    """The per-round cohort size C, or None for the dense full-K path.

    An explicit ``hp.cohort_size`` always wins (C == K still runs the
    cohort gather/scatter machinery — the identity cohort, bit-identical to
    the dense path and pinned so in tests/test_cohort.py). Otherwise
    ``participation < 1`` derives C = max(1, round(p·K)): a fixed-size
    weighted draw without replacement, replacing the historical Bernoulli
    mask whose inactive clients still computed (and, worse, still advanced
    their comm buffers — the wart init_comm_state used to document).
    """
    if hp.cohort_size is not None:
        c = int(hp.cohort_size)
        if not 1 <= c <= num_clients:
            raise ValueError(
                f"cohort_size={c} must be in [1, num_clients={num_clients}]")
        return c
    if hp.participation >= 1.0:
        return None
    return max(1, int(round(hp.participation * num_clients)))


def _sample_cohort(weight: jax.Array, cohort_size: int, rng: jax.Array):
    """Draw the round's cohort: ([C] indices, [C] renormalized weights).

    Sampling is without replacement, data-size weighted (p ∝ N_k/N), and the
    drawn weights renormalize to sum 1 so the delta-form aggregation stays
    exact. C == K short-circuits to the identity cohort with the RAW
    weights — renormalizing would perturb the last ulp and break the
    bit-identity of the C=K path with the dense path.
    """
    K = weight.shape[0]
    if cohort_size >= K:
        return jnp.arange(K), weight
    idx = jax.random.choice(rng, K, shape=(cohort_size,), replace=False,
                            p=weight)
    cw = weight[idx]
    return idx, cw / jnp.maximum(jnp.sum(cw), 1e-30)


class CohortPlan(NamedTuple):
    """One round's resolved client axis: the [C, ...] views the round core
    consumes plus what the epilogue needs to scatter updates back."""

    idx: jax.Array | None    # [C] cohort indices; None = dense full-K round
    x: jax.Array             # [C, ...] client data views
    y: jax.Array
    mask: jax.Array
    dweight: jax.Array       # [C] reduction weights (losses, global grad)
    pweight: jax.Array       # [C] aggregation weights for the model update
    rngs: jax.Array          # [C, 2] per-client round keys
    store: ClientStateStore  # the FULL K-sized store (scatter target)
    cohort: ClientStateStore # the gathered [C, ...] rows the core reads


def _plan_round(problem: FLProblem, csize: int | None, state: ServerState,
                part_rng: jax.Array, rngs_K: jax.Array) -> CohortPlan:
    """Resolve the round's client axis.

    Dense (csize None): the full stacks and store pass through untouched —
    byte-for-byte the historical round. Cohort: sample C indices, gather
    data + state rows + the C of the K prologue-split client keys
    (``rngs_K[idx]``, NOT a fresh split — cohort client k sees the same key
    the dense path would hand client k, which is what makes the masked-dense
    equivalence in tests/test_cohort.py exact per client).
    """
    C = problem.clients
    store = ClientStateStore.from_state(state)
    if csize is None:
        return CohortPlan(None, C.x, C.y, C.mask, C.weight, C.weight, rngs_K,
                          store, store)
    with jax.named_scope("fl.cohort_plan"):
        idx, cw = _sample_cohort(C.weight, csize, part_rng)
    if csize >= C.num_clients:
        # identity cohort (C == K): gathers at arange are value-identical but
        # perturb XLA fusion by an ulp, which the ill-conditioned AA Gram
        # solve amplifies — so the original arrays ARE the cohort view. The
        # scatter epilogue still runs (an exact write of the computed rows,
        # bit-safe), keeping the commit machinery under test.
        return CohortPlan(idx, C.x, C.y, C.mask, cw, cw, rngs_K, store, store)
    with jax.named_scope("fl.cohort_gather"):
        return CohortPlan(idx, C.x[idx], C.y[idx], C.mask[idx], cw, cw,
                          rngs_K[idx], store, store.gather(idx))


def _commit_plan(plan: CohortPlan, **updates) -> dict:
    """ServerState field updates from a round core's per-client outputs.

    Dense: passed through unchanged. Cohort: the [C, ...] rows scatter into
    the K-sized store — rows outside the cohort are bit-frozen, and fields
    the core did not touch (None here) emit no scatter op at all.
    """
    if plan.idx is None:
        return updates
    rows = ClientStateStore(
        c_k=updates.get("c_k"), hist_s=updates.get("hist_s"),
        hist_y=updates.get("hist_y"), comm=updates.get("comm"))
    with jax.named_scope("fl.scatter"):
        new = plan.store.scatter(plan.idx, rows)
    return {k: getattr(new, k) for k in updates}


def _aggregate(weights: jax.Array, stacked: Pytree, anchor: Pytree | None = None) -> Pytree:
    """Σ_k weights_k · stacked_k.

    When ``anchor`` is given, uses the delta form anchor + Σ w_k(x_k − anchor):
    identical when Σweights = 1, and degrades to a no-op (instead of zeroing
    the model) if a partial-participation round draws no clients.
    """
    if anchor is None:
        return jax.tree.map(lambda s: jnp.tensordot(weights, s, axes=1), stacked)
    return jax.tree.map(
        lambda a, s: a + jnp.tensordot(weights, s - a[None], axes=1), anchor, stacked
    )


class CrossClientReduce:
    """Cross-client reductions + the comm channel, single-process (vmap) runtime.

    The round cores below are written against this interface so the identical
    code runs distributed: core/sharded.py subclasses it to reduce each
    shard's partial result with psum/pmax over the ("pod","data") mesh axes.
    On a 1-device mesh the psum is an identity, so the two runtimes agree
    bit-for-bit.

    The channel methods (``uplink``/``broadcast``) simulate the wire: every
    client→server quantity passes an encode/decode roundtrip BEFORE the
    cross-client reduction (so the psum in the sharded runtime reduces
    dequantized values), and every server→client broadcast passes the
    (deterministic) downlink codec. They are per-client local ops — no
    collective inside — so the shared implementation serves both runtimes.
    """

    def __init__(self, channel: CommChannel | None = None):
        self.channel = channel if channel is not None else IDENTITY_CHANNEL

    def wsum(self, weights: jax.Array, stacked: Pytree,
             anchor: Pytree | None = None) -> Pytree:
        """Σ_k weights_k · stacked_k over every client (all shards)."""
        return _aggregate(weights, stacked, anchor)

    def nanmean(self, x: jax.Array) -> jax.Array:
        """Mean of the non-nan entries of a per-client vector; nan if none."""
        return jnp.nanmean(x)

    def nanmax(self, x: jax.Array) -> jax.Array:
        """Max of the non-nan entries of a per-client vector; nan if none."""
        return jnp.nanmax(x)

    def nanmin(self, x: jax.Array) -> jax.Array:
        """Min of the non-nan entries of a per-client vector; nan if none."""
        return jnp.nanmin(x)

    def ess(self, weights: jax.Array) -> jax.Array:
        """Effective sample size 1/Σw² of the per-client reduction weights
        (== C for a uniform C-client cohort; 1 when one client dominates)."""
        return 1.0 / jnp.maximum(jnp.sum(weights * weights), 1e-30)

    # ---- the wire ----------------------------------------------------------
    def uplink(self, stacked: Pytree, rngs: jax.Array, spec: UplinkSpec,
               anchor: Pytree | None = None, state: Pytree | None = None,
               post_codec=None, post_rngs: jax.Array | None = None):
        """Channel roundtrip of every client's upload, declared by ``spec``.

        The wire quantity is ``stacked_k − anchor`` for anchored specs (model
        uploads travel as deltas — that is what the codecs' relative scaling
        assumes), else ``stacked_k`` itself, further re-based on the carried
        reference ``state[spec.tag]["ref"]`` when present (difference coding:
        the wire carries v_k − h_k, both ends advance h_k by the decoded
        diff). ``state[spec.tag]["ef"]`` is the error-feedback residual,
        added before encoding, with the new residual carried forward. rngs
        are the per-client round keys; ``spec.fold`` is folded in so distinct
        uploads of one round never share draws.

        ``state`` is the WHOLE ServerState.comm dict (or None): the spec's
        tag selects its buffers, tags an algorithm's round never uplinks pass
        through untouched. Returns (reconstructed stacked — the server's
        view, the comm dict with this tag's buffers advanced).

        ``post_codec(dec_k, post_rngs_k)`` — when given — transforms each
        client's DECODED wire value after the codec roundtrip and BEFORE the
        error-feedback residual is taken, so EF and difference-coding
        references track the transformed wire (this is how the robustness
        layer composes client-side DP noise with the codecs: the client adds
        calibrated noise to its payload, so both ends see the noised stream).
        """
        if spec.anchored != (anchor is not None):
            raise ValueError(
                f"uplink {spec.tag!r}: anchored={spec.anchored} but anchor "
                f"{'missing' if anchor is None else 'given'}")
        codec = self.channel.up_codec(spec.kind)
        if isinstance(codec, IdentityCodec) and post_codec is None:
            return stacked, state
        sub = state.get(spec.tag) if state is not None else None
        if not codec.deterministic:
            rngs = jax.vmap(lambda r: jax.random.fold_in(r, spec.fold))(rngs)
        ef = sub.get("ef") if sub else None
        ref = sub.get("ref") if sub else None

        def one(w_k, rng, e, h, pr):
            v = tm.tree_sub(w_k, anchor) if anchor is not None else w_k
            if h is not None:
                v = tm.tree_sub(v, h)
            if e is not None:
                v = tm.tree_add(v, e)
            dec = codec.tree_roundtrip(v, rng)
            if post_codec is not None:
                dec = post_codec(dec, pr)
            new_e = tm.tree_sub(v, dec) if e is not None else None
            if h is not None:
                # h tracks the reconstructed stream on BOTH ends of the wire
                dec = tm.tree_add(dec, h)
            new_h = dec if h is not None else None
            if anchor is not None:
                dec = tm.tree_add(dec, anchor)
            return dec, new_e, new_h

        with jax.named_scope("fl.uplink"):
            dec, new_e, new_h = jax.vmap(one)(stacked, rngs, ef, ref,
                                              post_rngs)
        if not sub:
            return dec, state
        new_sub = {}
        if "ef" in sub:
            new_sub["ef"] = new_e
        if "ref" in sub:
            new_sub["ref"] = new_h
        return dec, {**state, spec.tag: new_sub}

    def broadcast(self, tree: Pytree) -> Pytree:
        """Server→client broadcast through the (deterministic) downlink codec."""
        if isinstance(self.channel.down, IdentityCodec):
            return tree
        return self.channel.broadcast(tree)


VMAP_REDUCE = CrossClientReduce()


# --------------------------------------------------------------------------
# round cores: one round's cross-client math, runtime-agnostic
#
# Each core takes the broadcast server quantities, the (possibly local shard
# of the) stacked client arrays, and a CrossClientReduce. Under the vmap
# runtime the arrays are the full [K, ...] stacks and R reduces in-process;
# under shard_map (core/sharded.py) they are the [K/n_shards, ...] local
# slices and R finishes every reduction with a psum, so a core never needs to
# know which runtime it is running in.
# --------------------------------------------------------------------------

class MetricParts(NamedTuple):
    """Cross-client metric reductions, before comm accounting is attached."""

    loss: jax.Array
    grad_norm: jax.Array
    theta_mean: jax.Array
    gram_cond_max: jax.Array
    gram_cond_mean: jax.Array
    aa_used_min: jax.Array
    aa_clipped_max: jax.Array
    cohort_ess: jax.Array


def _stack_losses(problem: FLProblem, w: Pytree, x, y, mask) -> jax.Array:
    return jax.vmap(lambda xx, yy, mm: problem.loss(w, ClientBatch(xx, yy, mm)))(
        x, y, mask
    )


def _stack_grads(problem: FLProblem, w: Pytree, x, y, mask) -> Pytree:
    return jax.vmap(lambda xx, yy, mm: problem.grad(w, ClientBatch(xx, yy, mm)))(
        x, y, mask
    )


def _nan_stats(k: int) -> AAStats:
    return AAStats(
        jnp.full((k,), jnp.nan), jnp.full((k,), jnp.nan),
        jnp.full((k,), jnp.nan), jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.int32),
    )


def _metric_parts(problem, R, w, g, stats, x, y, mask, dweight,
                  pweight) -> MetricParts:
    """f(w), ‖g‖ and AA/cohort health stats, reduced across every client."""
    # used_columns is 0 (not nan) when a client ran no AA step; key the
    # n/a-ness off theta's nan so non-AA algorithms report nan, and the
    # column-collapse alarm (obs/alarms.py) only ever fires on a real AA run
    used = jnp.where(jnp.isnan(stats.theta), jnp.nan,
                     stats.used_columns.astype(jnp.float32))
    clipped = jnp.where(jnp.isnan(stats.theta), jnp.nan,
                        stats.clipped_columns.astype(jnp.float32))
    return MetricParts(
        loss=R.wsum(dweight, _stack_losses(problem, w, x, y, mask)),
        grad_norm=tm.tree_norm(g),
        theta_mean=R.nanmean(stats.theta),
        gram_cond_max=R.nanmax(stats.gram_cond),
        gram_cond_mean=R.nanmean(stats.gram_cond),
        aa_used_min=R.nanmin(used),
        aa_clipped_max=R.nanmax(clipped),
        cohort_ess=R.ess(pweight),
    )


def _svrg_round_core(problem, hp, use_aa, R, w_t, x, y, mask, dweight, pweight,
                     rngs, hist_s=None, hist_y=None, comm=None, poison=None,
                     poison_scale=0.0):
    """SVRG family: corrected local steps (+ optional AA), delta aggregation.

    Two wire crossings: the local full-batch gradients travel up (round trip
    1), then w^t and ∇f travel down and the model deltas travel up (round
    trip 2, with error feedback). The carried AA history is client-local
    state — it never touches the wire.

    ``poison`` — when the robustness layer injects byz_mode="history" faults
    — is ``(flags [C] bool, keys [C] prng)``: flagged clients' last recorded
    AA history column is corrupted at magnitude ``poison_scale`` before the
    multisecant solve (see _client_svrg).
    """
    w_t = R.broadcast(w_t)
    g_k, comm = R.uplink(_stack_grads(problem, w_t, x, y, mask), rngs,
                         GRAD_UPLINK, state=comm)
    g_global = R.broadcast(R.wsum(dweight, g_k))
    if hist_s is not None and poison is not None:
        flags, fkeys = poison
        w_k, stats, new_hs, new_hy = jax.vmap(
            lambda xx, yy, mm, rr, hs, hy, fl, fk: _client_svrg(
                problem, hp, use_aa, w_t, g_global, xx, yy, mm, rr, hs, hy,
                poison=(fl, fk, poison_scale))
        )(x, y, mask, rngs, hist_s, hist_y, flags, fkeys)
    elif hist_s is not None:
        w_k, stats, new_hs, new_hy = jax.vmap(
            partial(_client_svrg, problem, hp, use_aa, w_t, g_global)
        )(x, y, mask, rngs, hist_s, hist_y)
    elif poison is not None:
        flags, fkeys = poison
        w_k, stats = jax.vmap(
            lambda xx, yy, mm, rr, fl, fk: _client_svrg(
                problem, hp, use_aa, w_t, g_global, xx, yy, mm, rr,
                poison=(fl, fk, poison_scale))
        )(x, y, mask, rngs, flags, fkeys)
        new_hs = new_hy = None
    else:
        w_k, stats = jax.vmap(
            partial(_client_svrg, problem, hp, use_aa, w_t, g_global)
        )(x, y, mask, rngs)
        new_hs = new_hy = None
    w_k, comm = R.uplink(w_k, rngs, DELTA_UPLINK, anchor=w_t, state=comm)
    new_params = R.wsum(pweight, w_k, anchor=w_t)
    parts = _metric_parts(problem, R, w_t, g_global, stats, x, y, mask, dweight, pweight)
    return new_params, parts, new_hs, new_hy, comm


def _scaffold_round_core(problem, hp, use_aa, R, w_t, c, x, y, mask, c_k,
                         dweight, pweight, rngs, comm=None):
    """SCAFFOLD family: control-variate steps; c aggregated with data weights.

    Single exchange: (w^t, c) travel down, (Δw_k, c_k) travel up together.
    The server keeps the decoded wire view only in the aggregates; the
    client's own control variate stays client-side uncompressed (new_c_k).
    """
    w_t = R.broadcast(w_t)
    c = R.broadcast(c)
    w_k, new_c_k, stats = jax.vmap(
        partial(_client_scaffold, problem, hp, use_aa, w_t, c)
    )(x, y, mask, c_k, rngs)
    w_k, comm = R.uplink(w_k, rngs, DELTA_UPLINK, anchor=w_t, state=comm)
    c_up, comm = R.uplink(new_c_k, rngs, CTRL_UPLINK, state=comm)
    new_params = R.wsum(pweight, w_k, anchor=w_t)
    new_c = R.wsum(dweight, c_up)
    parts = _metric_parts(problem, R, w_t, new_c, stats, x, y, mask, dweight, pweight)
    return new_params, new_c, new_c_k, parts, comm


def _avg_round_core(problem, hp, use_aa, R, w_t, x, y, mask, dweight, pweight,
                    rngs, comm=None):
    """FedAvg family (incl. the fedosaa_avg negative control)."""
    w_t = R.broadcast(w_t)
    w_k, stats = jax.vmap(
        partial(_client_avg, problem, hp, use_aa, w_t)
    )(x, y, mask, rngs)
    w_k, comm = R.uplink(w_k, rngs, DELTA_UPLINK, anchor=w_t, state=comm)
    new_params = R.wsum(pweight, w_k, anchor=w_t)
    # diagnostics only — FedAvg ships no gradients, so no wire crossing here
    g = R.wsum(dweight, _stack_grads(problem, w_t, x, y, mask))
    parts = _metric_parts(problem, R, w_t, g, stats, x, y, mask, dweight, pweight)
    return new_params, parts, comm


def _lbfgs_round_core(problem, hp, R, w_t, x, y, mask, dweight, pweight, rngs,
                      comm=None):
    w_t = R.broadcast(w_t)
    g_k, comm = R.uplink(_stack_grads(problem, w_t, x, y, mask), rngs,
                         GRAD_UPLINK, state=comm)
    g_global = R.broadcast(R.wsum(dweight, g_k))
    w_k, _ = jax.vmap(
        partial(_client_lbfgs, problem, hp, w_t, g_global)
    )(x, y, mask, rngs)
    w_k, comm = R.uplink(w_k, rngs, DELTA_UPLINK, anchor=w_t, state=comm)
    new_params = R.wsum(pweight, w_k, anchor=w_t)
    parts = _metric_parts(problem, R, w_t, g_global, _nan_stats(x.shape[0]),
                          x, y, mask, dweight, pweight)
    return new_params, parts, comm


def _newton_round_core(problem, hp, client_fn, R, w_t, x, y, mask, dweight,
                       pweight, rngs, comm=None):
    """GIANT / Newton-GMRES: aggregate directions, optional global backtrack.

    Both uplinks are stateful (schema: "grad" aux + "dir" delta): the
    gradient collection is difference-coded against the carried per-client
    reference and the Newton direction carries an error-feedback residual, so
    lossy codecs ride quantities that vanish at the optimum instead of
    flooring on the O(1) local gradients (benchmarks/ext_compression.py).
    """
    w_t = R.broadcast(w_t)
    g_k, comm = R.uplink(_stack_grads(problem, w_t, x, y, mask), rngs,
                         GRAD_UPLINK, state=comm)
    g_global = R.broadcast(R.wsum(dweight, g_k))
    p_k = jax.vmap(partial(client_fn, problem, hp, w_t, g_global))(x, y, mask)
    p_k, comm = R.uplink(p_k, rngs, DIR_UPLINK, state=comm)
    p = R.wsum(pweight, p_k)
    if hp.line_search:
        # GIANT line search on the aggregated direction: clients evaluate
        # f_k along the BROADCAST view of p (one extra downlink — see
        # comm_bytes_per_round); the server then steps with its exact p.
        p_b = R.broadcast(p)
        steps = jnp.array([4.0, 2.0, 1.0, 0.5, 0.25, 0.125, 0.0625])
        vals = jax.vmap(
            lambda a: R.wsum(
                dweight,
                _stack_losses(problem, tm.tree_axpy(-a, p_b, w_t), x, y, mask),
            )
        )(steps)
        a = steps[jnp.argmin(vals)]
    else:
        a = jnp.asarray(1.0)
    new_params = tm.tree_axpy(-a, p, w_t)
    parts = _metric_parts(problem, R, w_t, g_global, _nan_stats(x.shape[0]),
                          x, y, mask, dweight, pweight)
    return new_params, parts, comm


def _dane_round_core(problem, hp, R, w_t, x, y, mask, dweight, pweight, rngs,
                     comm=None):
    """DANE: stateful wire like the SVRG family (schema: "grad" + "delta")."""
    w_t = R.broadcast(w_t)
    g_k, comm = R.uplink(_stack_grads(problem, w_t, x, y, mask), rngs,
                         GRAD_UPLINK, state=comm)
    g_global = R.broadcast(R.wsum(dweight, g_k))
    w_k = jax.vmap(partial(_client_dane, problem, hp, w_t, g_global))(x, y, mask)
    w_k, comm = R.uplink(w_k, rngs, DELTA_UPLINK, anchor=w_t, state=comm)
    # delta-form aggregation: identical when Σpweight = 1, and a partial-
    # participation round with no active clients keeps w^t instead of zeroing
    new_params = R.wsum(pweight, w_k, anchor=w_t)
    parts = _metric_parts(problem, R, w_t, g_global, _nan_stats(x.shape[0]),
                          x, y, mask, dweight, pweight)
    return new_params, parts, comm


def finalize_metrics(parts: MetricParts, comm_bytes: float,
                     async_stats=None) -> RoundMetrics:
    """Assemble the round's metrics row. ``async_stats`` is the deadline
    gate's (arrivals, staleness_mean, staleness_max) triple
    (repro.robust.async_agg.async_round_stats); None — the barriered round —
    reports NaN for all three (the theta_mean n/a convention)."""
    if async_stats is None:
        nan = jnp.asarray(jnp.nan, jnp.float32)
        arrivals = s_mean = s_max = nan
    else:
        arrivals, s_mean, s_max = (
            jnp.asarray(v, jnp.float32) for v in async_stats)
    return RoundMetrics(
        loss=parts.loss,
        grad_norm=parts.grad_norm,
        theta_mean=parts.theta_mean,
        gram_cond_max=parts.gram_cond_max,
        gram_cond_mean=parts.gram_cond_mean,
        aa_used_min=parts.aa_used_min,
        aa_clipped_max=parts.aa_clipped_max,
        cohort_ess=parts.cohort_ess,
        comm_bytes=jnp.asarray(comm_bytes, jnp.float32),
        arrivals=arrivals,
        staleness_mean=s_mean,
        staleness_max=s_max,
    )


# --------------------------------------------------------------------------
# round functions (vmap runtime)
# --------------------------------------------------------------------------

def make_round_fn(algo: str, problem: FLProblem, hp: AlgoHParams,
                  channel: "CommChannel | str | None" = None,
                  faults: "FaultPlan | None" = None,
                  async_cfg: "AsyncConfig | None" = None):
    """Return a jittable round(state) -> (state, RoundMetrics).

    Single-process runtime: the K stacked clients are vmapped. The distributed
    runtime with identical numerics is core/sharded.py::make_sharded_round_fn.
    ``channel`` (repro/comm) compresses every wire crossing; None keeps the
    historical lossless fp32 wire. ``faults`` (repro/robust) injects the
    plan's dropout/stale/byzantine/DP/latency perturbations inside the
    compiled body; None (or an inactive plan) compiles the exact fault-free
    graph. ``async_cfg`` (repro.robust.async_agg) replaces the barriered
    round close with the deadline gate — only clients whose realized latency
    beats the deadline land, late updates buffer and fold in later with
    staleness-discounted weight; None (or ``deadline == 0``) compiles the
    byte-identical synchronous graph.
    """
    if algo not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algo!r}; choose from {ALGORITHMS}")
    # resolve the AA and local-trajectory implementations once for this
    # runtime, so the client bodies see a concrete "tree"/"pallas" (never
    # "auto") and ineligible problems/algos fall back before tracing
    p0 = problem.init(jax.random.PRNGKey(0))
    hp = dataclasses.replace(
        hp, aa_impl=resolve_aa_impl(hp.aa_impl, "vmap"),
        local_impl=resolve_local_impl(hp.local_impl, "vmap", problem, algo, p0))
    channel = make_channel(channel)
    comm_bytes = comm_bytes_per_round(algo, p0, channel, hp.line_search)
    C = problem.clients
    csize = resolve_cohort_size(hp, C.num_clients)
    R = CrossClientReduce(channel)

    def prologue(state: ServerState):
        """Shared round prologue: rng splits + the resolved client axis.
        The split order matches the historical dense round exactly, and the
        dense branch of _plan_round forwards the original arrays — so the
        csize=None graph is byte-identical to the pre-cohort round."""
        rng, part_rng, cl_rng = jax.random.split(state.rng, 3)
        rngs_K = jax.random.split(cl_rng, C.num_clients)
        return rng, _plan_round(problem, csize, state, part_rng, rngs_K)

    # ---------------- fault injection (repro/robust) ----------------
    # python-gated: an absent/inactive plan leaves every closure below
    # compiling the identical fault-free graph
    faults = faults if (faults is not None and faults.active) else None
    if faults is not None:
        from repro.robust.faults import (FAULT_ANCHOR_KEY, FaultyReduce,
                                         advance_anchor, drop_weights,
                                         freeze_dropped, realize)

    def fault_ctx(plan: CohortPlan, t):
        """(reduce, dweight, pweight, realization) for this round: realize
        the plan's per-client draws (keyed by global client id — identical
        across runtimes and runs), zero + renormalize dropped clients'
        aggregation weights, and wrap the reduce so uplinks see the
        byzantine/stale/DP perturbations."""
        if faults is None:
            return R, plan.dweight, plan.pweight, None
        fr = realize(faults, t, C.num_clients, plan.idx)
        dw, pw = plan.dweight, plan.pweight
        if faults.drop_rate > 0.0:
            pw = drop_weights(fr.drop, pw)
            if algo in ("scaffold", "fedosaa_scaffold"):
                # scaffold's single exchange: the control variates ride the
                # lost uplink, so the dweight aggregation drops too; the
                # two-round-trip families' gradient collection landed before
                # the mid-round drop, so their dweight keeps every client
                dw = drop_weights(fr.drop, dw)
        anchors = None
        if faults.stale_rate > 0.0:
            anchors = plan.cohort.comm[FAULT_ANCHOR_KEY]
        return FaultyReduce(R, faults, fr, anchors), dw, pw, fr

    def fault_epilogue(plan: CohortPlan, fr, w_t, upd: dict) -> dict:
        """Post-core state landing: stale-anchor refresh first, then the
        dropped-row bit-freeze (order matters — a dropped client's refreshed
        anchor must freeze back to its pre-round value too)."""
        if faults is None:
            return upd
        if faults.stale_rate > 0.0 and upd.get("comm") is not None:
            upd = {**upd, "comm": advance_anchor(upd["comm"], fr.stale, w_t)}
        if faults.drop_rate > 0.0:
            upd = freeze_dropped(fr.drop, plan.cohort, upd)
        return upd

    # ---------------- deadline gate (repro/robust/async_agg) ----------------
    # python-gated exactly like the fault plan: an absent/inactive config
    # compiles the byte-identical synchronous (barriered) round
    async_cfg = async_cfg if (async_cfg is not None and async_cfg.active) \
        else None
    if async_cfg is not None:
        if algo in ("giant", "newton_gmres"):
            raise ValueError(
                f"AsyncConfig requires a delta-form model aggregation; "
                f"{algo!r} aggregates Newton directions and cannot buffer "
                "client deltas")
        from repro.robust.async_agg import (ASYNC_AGE_KEY, ASYNC_BUF_KEY,
                                            CaptureReduce, advance_buffer,
                                            async_round_stats, fold_buffered,
                                            guard_history_rows, plan_async)
        from repro.robust.faults import _bc

    def async_ctx(plan: CohortPlan, Rr, fr, dw, pw):
        """Deadline-gate this round: partition the cohort by realized latency
        vs the (possibly extended) deadline, hand the core only the fresh
        contributors' discounted weights, and wrap the reduce so the anchored
        model uplink's post-codec rows are captured for the buffer write. A
        run without a latency plan gates on all-zero latencies (everyone on
        time — the gate still exercises the buffer machinery under drops)."""
        if async_cfg is None:
            return Rr, dw, pw, None
        latency = fr.latency if fr is not None else jnp.zeros_like(pw)
        drop = fr.drop if (faults is not None and faults.drop_rate > 0.0) \
            else None
        ar = plan_async(async_cfg, latency,
                        plan.cohort.comm[ASYNC_AGE_KEY], pw, drop=drop)
        if algo in ("scaffold", "fedosaa_scaffold"):
            # the control variates ride the model uplink, so only fresh
            # arrivals contribute to the c aggregation (the buffer carries
            # model deltas only — a fold's c_up is lost on the floor); the
            # two-round-trip families' gradient collection is a cheap sync
            # that lands before the deadline applies to the local-update leg
            dwz = jnp.where(ar.fresh, dw, jnp.zeros_like(dw))
            dw = dwz / jnp.maximum(jnp.sum(dwz), 1e-30)
        return CaptureReduce(Rr), dw, ar.fresh_weights, ar

    def async_epilogue(plan: CohortPlan, ar, Rc, w_t, new_params, upd):
        """Jit-level buffer fold + transition, run AFTER fault_epilogue so
        the dropped-row freeze cannot clobber this round's buffer/age writes
        (drop-awareness lives in the plan_async masks instead). Returns the
        folded params, the patched updates, and the round's async stats."""
        if async_cfg is None:
            return new_params, upd, None
        comm_in = plan.cohort.comm
        new_params = fold_buffered(new_params, ar.fold_weights,
                                   comm_in[ASYNC_BUF_KEY])
        # encode-at-send: the deferred client's buffered row is its post-codec
        # delta against this round's anchor, captured off the model uplink
        delta = jax.tree.map(lambda c, w: c - w, Rc.captured, w_t)
        new_buf, new_age = advance_buffer(ar, delta, comm_in[ASYNC_BUF_KEY],
                                          comm_in[ASYNC_AGE_KEY])
        comm = dict(upd["comm"] if upd.get("comm") is not None else comm_in)
        comm[ASYNC_BUF_KEY] = new_buf
        comm[ASYNC_AGE_KEY] = new_age
        upd = {**upd, "comm": comm}
        if upd.get("c_k") is not None:
            # a non-fresh client's control-variate update never arrived
            old_ck = plan.cohort.c_k
            upd["c_k"] = jax.tree.map(
                lambda o, n: jnp.where(_bc(~ar.fresh, n), o, n),
                old_ck, upd["c_k"])
        if async_cfg.guard_history:
            upd = guard_history_rows(ar.fold | ar.retain, plan.cohort, upd)
        return new_params, upd, async_round_stats(ar)

    # ---------------- SVRG family ----------------
    if algo in ("fedsvrg", "fedosaa_svrg"):
        use_aa = algo == "fedosaa_svrg"

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            Rr, dw, pw, fr = fault_ctx(plan, state.t)
            Rr, dw, pw, ar = async_ctx(plan, Rr, fr, dw, pw)
            carry = hp.carry_history > 0 and state.hist_s is not None
            core_kw = {}
            if faults is not None and faults.poisons_history and use_aa:
                core_kw = dict(poison=(fr.byz, fr.keys),
                               poison_scale=faults.byz_scale)
            new_params, parts, new_hs, new_hy, new_comm = _svrg_round_core(
                problem, hp, use_aa, Rr, state.params, plan.x, plan.y,
                plan.mask, dw, pw, plan.rngs,
                plan.cohort.hist_s if carry else None,
                plan.cohort.hist_y if carry else None,
                plan.cohort.comm, **core_kw,
            )
            upd = dict(comm=new_comm)
            if carry:
                upd.update(hist_s=new_hs, hist_y=new_hy)
            upd = fault_epilogue(plan, fr, state.params, upd)
            new_params, upd, astats = async_epilogue(
                plan, ar, Rr, state.params, new_params, upd)
            metrics = finalize_metrics(parts, comm_bytes, astats)
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1, rng=rng,
                                  **upd), metrics

        return round_fn

    # ---------------- SCAFFOLD family ----------------
    if algo in ("scaffold", "fedosaa_scaffold"):
        use_aa = algo == "fedosaa_scaffold"

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            Rr, dw, pw, fr = fault_ctx(plan, state.t)
            Rr, dw, pw, ar = async_ctx(plan, Rr, fr, dw, pw)
            new_params, new_c, new_c_k, parts, new_comm = _scaffold_round_core(
                problem, hp, use_aa, Rr, state.params, state.c,
                plan.x, plan.y, plan.mask, plan.cohort.c_k,
                dw, pw, plan.rngs, plan.cohort.comm,
            )
            upd = fault_epilogue(plan, fr, state.params,
                                 dict(c_k=new_c_k, comm=new_comm))
            new_params, upd, astats = async_epilogue(
                plan, ar, Rr, state.params, new_params, upd)
            if ar is not None:
                # c's aggregation is not delta-form: a zero-fresh round would
                # zero the server control variate, so keep the old c instead
                any_fresh = jnp.any(ar.fresh)
                new_c = jax.tree.map(
                    lambda n, o: jnp.where(any_fresh, n, o), new_c, state.c)
            metrics = finalize_metrics(parts, comm_bytes, astats)
            upd = _commit_plan(plan, **upd)
            return (
                state._replace(params=new_params, c=new_c, t=state.t + 1,
                               rng=rng, **upd),
                metrics,
            )

        return round_fn

    # ---------------- AVG family (incl. negative control) ----------------
    if algo in ("fedavg", "fedosaa_avg"):
        use_aa = algo == "fedosaa_avg"

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            Rr, dw, pw, fr = fault_ctx(plan, state.t)
            Rr, dw, pw, ar = async_ctx(plan, Rr, fr, dw, pw)
            new_params, parts, new_comm = _avg_round_core(
                problem, hp, use_aa, Rr, state.params, plan.x, plan.y,
                plan.mask, dw, pw, plan.rngs,
                plan.cohort.comm,
            )
            upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
            new_params, upd, astats = async_epilogue(
                plan, ar, Rr, state.params, new_params, upd)
            metrics = finalize_metrics(parts, comm_bytes, astats)
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1, rng=rng,
                                  **upd), metrics

        return round_fn

    # ---------------- one-step L-BFGS ----------------
    if algo == "lbfgs":

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            Rr, dw, pw, fr = fault_ctx(plan, state.t)
            Rr, dw, pw, ar = async_ctx(plan, Rr, fr, dw, pw)
            new_params, parts, new_comm = _lbfgs_round_core(
                problem, hp, Rr, state.params, plan.x, plan.y, plan.mask,
                dw, pw, plan.rngs, plan.cohort.comm,
            )
            upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
            new_params, upd, astats = async_epilogue(
                plan, ar, Rr, state.params, new_params, upd)
            metrics = finalize_metrics(parts, comm_bytes, astats)
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1, rng=rng,
                                  **upd), metrics

        return round_fn

    # ---------------- Newton-type ----------------
    if algo in ("giant", "newton_gmres"):
        client_fn = _client_giant if algo == "giant" else _client_newton_gmres

        def round_fn(state: ServerState):
            rng, plan = prologue(state)
            Rr, dw, pw, fr = fault_ctx(plan, state.t)
            new_params, parts, new_comm = _newton_round_core(
                problem, hp, client_fn, Rr, state.params, plan.x, plan.y,
                plan.mask, dw, pw, plan.rngs,
                plan.cohort.comm,
            )
            metrics = finalize_metrics(parts, comm_bytes)
            upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
            upd = _commit_plan(plan, **upd)
            return state._replace(params=new_params, t=state.t + 1, rng=rng,
                                  **upd), metrics

        return round_fn

    # ---------------- DANE ----------------
    assert algo == "dane"

    def round_fn(state: ServerState):
        rng, plan = prologue(state)
        Rr, dw, pw, fr = fault_ctx(plan, state.t)
        Rr, dw, pw, ar = async_ctx(plan, Rr, fr, dw, pw)
        new_params, parts, new_comm = _dane_round_core(
            problem, hp, Rr, state.params, plan.x, plan.y, plan.mask,
            dw, pw, plan.rngs, plan.cohort.comm,
        )
        upd = fault_epilogue(plan, fr, state.params, dict(comm=new_comm))
        new_params, upd, astats = async_epilogue(
            plan, ar, Rr, state.params, new_params, upd)
        metrics = finalize_metrics(parts, comm_bytes, astats)
        upd = _commit_plan(plan, **upd)
        return state._replace(params=new_params, t=state.t + 1, rng=rng,
                              **upd), metrics

    return round_fn
