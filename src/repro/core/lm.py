"""Bridge: federated optimization (core/algorithms) over LM models
(models/decoder) — FedOSAA training of transformers/SSMs.

Clients hold token corpora; the FLProblem's loss is the model's next-token
cross entropy over the client's documents. Everything downstream (FedSVRG /
FedOSAA rounds, AA step, server aggregation) is unchanged — the paper's
algorithm is architecture-agnostic (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import ClientBatch, FLProblem, StackedClients

Pytree = Any


def make_lm_clients(tokens: np.ndarray, num_clients: int,
                    docs_per_client: int | None = None) -> StackedClients:
    """tokens: [n_docs, S] int32. IID split into K clients."""
    n_docs = tokens.shape[0]
    per = docs_per_client or n_docs // num_clients
    xs, ys = [], []
    for k in range(num_clients):
        chunk = tokens[k * per:(k + 1) * per]
        xs.append(chunk)
        ys.append(np.zeros((chunk.shape[0],), np.float32))   # labels unused
    from repro.core.problem import stack_client_arrays
    return stack_client_arrays(xs, ys)


def make_lm_problem(model, clients: StackedClients) -> FLProblem:
    def loss(params, batch: ClientBatch) -> jax.Array:
        # batch.x: [n, S] tokens; batch.mask: [n] doc validity
        lm_batch = {
            "tokens": batch.x,
            "loss_mask": jnp.broadcast_to(
                batch.mask[:, None], batch.x.shape
            ).astype(jnp.float32),
        }
        return model.loss(params, lm_batch)

    return FLProblem(loss=loss, init=model.init, clients=clients)
