"""Preemption-tolerant sharded checkpoint format: per-process shard files,
digest-carrying manifest, newest-complete discovery.

Layout of one committed checkpoint (all staged in a ``.tmp-*`` sibling and
renamed into place by checkpoint/atomic.commit_dir — the manifest is written
last, so a ``ckpt_<round>`` directory with a valid manifest IS the commit
marker)::

    <dir>/ckpt_00000042/
        shards_p0000.npz     one npz per writing process: every addressable
        ...                  shard of every ServerState leaf that process
        manifest.json        holds, entries keyed "<leaf-key>::<shard#>"

Manifest (format version :data:`CKPT_FORMAT`)::

    round            global round the state is AFTER
    leaves           per-leaf: global shape, dtype, stored dtype, and the
                     shard list [{file, entry, box, sha256, bytes}] — box is
                     [(start, stop)] per dim in the global index space
    inventory        what rode along (rng / comm tags incl. async buffers
                     and fault anchors / AA history) — a resumed run can see
                     at a glance that nothing was silently dropped
    config           run fingerprint (algo/runtime/channel/fault params/…);
                     ``expect_config`` on load REFUSES a mismatch instead of
                     letting a resumed run silently diverge

Completeness is verified on load, never assumed: every referenced shard file
must exist, every entry's sha256 must match, and the deduped shard boxes of
every leaf must tile its full global shape. :func:`load_latest` walks the
committed rounds newest-first and restores from the first checkpoint that
passes — torn manifests, bad digests, missing shards, stray garbage files
are all skipped (and reported), exactly the recovery a preempted run needs.

Save never gathers: each process writes only ``leaf_addressable_shards``
(core/sharded.py) of the donated state, host-copied at the engine's existing
chunk-boundary sync. This module is pure host I/O — the async dispatch and
backpressure live in checkpoint/policy.py.
"""
from __future__ import annotations

import io
import json
import logging
import os
import re
from typing import Any

import numpy as np

from repro.checkpoint.atomic import (
    LOCAL_FS, LocalFs, commit_dir, sha256_hex, write_bytes_atomic,
)

Pytree = Any

logger = logging.getLogger("repro.checkpoint")

CKPT_FORMAT = 1
MANIFEST = "manifest.json"

_CKPT_RE = re.compile(r"^ckpt_(\d{8})$")


class CheckpointConfigMismatch(RuntimeError):
    """The newest complete checkpoint was written by a run with a different
    config fingerprint — resuming would silently diverge, so refuse."""


def ckpt_name(round_idx: int) -> str:
    return f"ckpt_{round_idx:08d}"


def _leaf_keys(tree: Pytree) -> "list[tuple[str, Any]]":
    """'/'-joined key paths, the same naming the legacy npz format uses."""
    import jax

    out = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in kp)
        out.append((key, leaf))
    return out


def snapshot_shards(state: Pytree) -> "dict[str, dict]":
    """Host-side snapshot of every leaf's process-addressable shards.

    Returns ``{leaf_key: {"shape", "dtype", "shards": [(box, np.ndarray)]}}``
    with every array a fresh host COPY (safe against the engine donating the
    device buffers to the next chunk). bf16 & friends are stored as f32 —
    npz cannot hold ml_dtypes — and the manifest records the true dtype so
    restore casts back (the same convention as the legacy path).
    """
    from repro.core.sharded import dedupe_shard_boxes, leaf_addressable_shards

    snap = {}
    for key, leaf in _leaf_keys(state):
        shards = dedupe_shard_boxes(leaf_addressable_shards(leaf))
        dtype = str(np.asarray(shards[0][1]).dtype) \
            if not hasattr(leaf, "dtype") else str(leaf.dtype)
        stored = []
        for box, arr in shards:
            if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
                arr = arr.astype(np.float32)
            stored.append((box, arr))
        snap[key] = {
            "shape": tuple(int(n) for n in getattr(leaf, "shape", shards[0][1].shape)),
            "dtype": dtype,
            "shards": stored,
        }
    return snap


def inventory_of(snapshot: "dict[str, dict]") -> dict:
    """What the checkpoint carries, by subsystem — the manifest field that
    lets `load` (and a human) confirm nothing was silently dropped."""
    # tree-path keys carry a leading "." for NamedTuple attrs (".hist_s",
    # ".comm/grad/ef") — normalize before classifying
    keys = sorted(k.lstrip(".") for k in snapshot)
    comm_tags = sorted({k.split("/")[1] for k in keys
                        if k.startswith("comm/")})
    return {
        "num_leaves": len(keys),
        "rng": any(k == "rng" or k.startswith("rng/") for k in keys),
        "round_counter": "t" in keys,
        "comm_tags": comm_tags,
        "aa_history": any(k.startswith("hist_s") for k in keys),
        "async_buffers": any("__async_buf__" in k for k in keys),
        "fault_anchors": any("__fault_anchor__" in k for k in keys),
    }


def write_checkpoint(directory: str, snapshot: "dict[str, dict]",
                     round_idx: int, *, config: dict | None = None,
                     fs: LocalFs = LOCAL_FS, process_index: int = 0,
                     retries: int = 3, backoff_s: float = 0.05,
                     sleep=None) -> "tuple[str, int]":
    """Stage this process's shards + the manifest and commit atomically.

    Returns ``(committed_path, bytes_written)``. Single-process commit: on a
    one-host runtime (this container) the writing process also writes the
    manifest and renames; a true multi-host deployment would barrier before
    the manifest (levanter's commit-marker idiom) — the on-disk format
    already carries per-process files so only that barrier is missing.
    """
    import time as _time

    sleep = sleep or _time.sleep
    final = os.path.join(directory, ckpt_name(round_idx))
    tmp = os.path.join(directory, f".tmp-{ckpt_name(round_idx)}-{os.getpid()}")
    fs.makedirs(tmp)
    total_bytes = 0
    try:
        fname = f"shards_p{process_index:04d}.npz"
        entries: "dict[str, np.ndarray]" = {}
        leaves = {}
        for key, rec in snapshot.items():
            shard_meta = []
            for i, (box, arr) in enumerate(rec["shards"]):
                entry = f"{key}::{i}"
                entries[entry] = arr
                shard_meta.append({
                    "file": fname,
                    "entry": entry,
                    "box": [[int(a), int(b)] for a, b in box],
                    "sha256": sha256_hex(arr.tobytes()),
                    "bytes": int(arr.nbytes),
                })
            leaves[key] = {
                "shape": list(rec["shape"]),
                "dtype": rec["dtype"],
                "stored_dtype": str(rec["shards"][0][1].dtype),
                "shards": shard_meta,
            }
        buf = io.BytesIO()
        # npz keys with '/' are legal (zip member names); savez handles them
        np.savez(buf, **entries)
        payload = buf.getvalue()
        write_bytes_atomic(os.path.join(tmp, fname), payload, fs=fs,
                           retries=retries, backoff_s=backoff_s, sleep=sleep)
        total_bytes += len(payload)

        manifest = {
            "format": CKPT_FORMAT,
            "round": int(round_idx),
            "processes": 1,
            "files": [fname],
            "leaves": leaves,
            "inventory": inventory_of(snapshot),
            "config": config or {},
        }
        mbytes = json.dumps(manifest, indent=1, sort_keys=True).encode()
        # manifest LAST: its presence inside a committed dir is the marker
        write_bytes_atomic(os.path.join(tmp, MANIFEST), mbytes, fs=fs,
                           retries=retries, backoff_s=backoff_s, sleep=sleep)
        total_bytes += len(mbytes)
        if fs.exists(final):
            # a prior run already committed this round (e.g. rerun into the
            # same directory without --resume): the new save supersedes it.
            # os.replace cannot overwrite a non-empty directory, so drop the
            # stale one first — the only window without a ckpt for this
            # round is here, and the previous-newest checkpoint still covers
            # recovery.
            logger.warning("checkpoint %s already exists; overwriting", final)
            fs.rmtree(final)
        commit_dir(tmp, final, fs=fs, retries=retries, backoff_s=backoff_s,
                   sleep=sleep)
    except BaseException as e:
        # a failed (not killed) save must not leave its temp dir to confuse
        # the NEXT save's staging; SimulatedKill skips even this cleanup,
        # exactly like a real process death would
        from repro.robust.fs_faults import SimulatedKill

        if not isinstance(e, SimulatedKill):
            try:
                fs.rmtree(tmp)
            except OSError:
                pass
        raise
    return final, total_bytes


def list_checkpoints(directory: str, fs: LocalFs = LOCAL_FS) \
        -> "list[tuple[int, str]]":
    """Committed checkpoints in ``directory``, newest round first. Garbage
    entries (tmp remnants, stray files) are ignored, never raised on."""
    if not fs.exists(directory):
        return []
    out = []
    for name in fs.listdir(directory):
        m = _CKPT_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    return sorted(out, reverse=True)


def verify_checkpoint(path: str, fs: LocalFs = LOCAL_FS) \
        -> "tuple[dict, dict] | None":
    """Verify one committed checkpoint end-to-end.

    Returns ``(manifest, data)`` — ``data[leaf_key] = [(box, np.ndarray)]``
    — when the checkpoint is COMPLETE: manifest parses, every shard file
    exists, every entry's digest matches, every leaf's boxes tile its global
    shape. Returns None (with a logged reason) on any defect; never raises
    on garbage.
    """
    try:
        manifest = json.loads(fs.read_bytes(os.path.join(path, MANIFEST)))
    except (OSError, ValueError):
        logger.warning("checkpoint %s: missing/torn manifest — skipped", path)
        return None
    if not isinstance(manifest, dict) or manifest.get("format") != CKPT_FORMAT:
        logger.warning("checkpoint %s: unknown format %r — skipped", path,
                       manifest.get("format") if isinstance(manifest, dict)
                       else type(manifest).__name__)
        return None
    files = {}
    for fname in manifest.get("files", []):
        try:
            raw = fs.read_bytes(os.path.join(path, fname))
            files[fname] = np.load(io.BytesIO(raw))
        except (OSError, ValueError):
            logger.warning("checkpoint %s: shard file %s unreadable — "
                           "skipped", path, fname)
            return None
    data: "dict[str, list]" = {}
    try:
        for key, rec in manifest["leaves"].items():
            shape = tuple(rec["shape"])
            shards = []
            covered = 0
            for sm in rec["shards"]:
                npz = files.get(sm["file"])
                if npz is None or sm["entry"] not in npz.files:
                    logger.warning("checkpoint %s: leaf %s missing shard "
                                   "%s — skipped", path, key, sm["entry"])
                    return None
                arr = npz[sm["entry"]]
                if sha256_hex(arr.tobytes()) != sm["sha256"]:
                    logger.warning("checkpoint %s: leaf %s shard %s digest "
                                   "mismatch — skipped", path, key,
                                   sm["entry"])
                    return None
                box = tuple((int(a), int(b)) for a, b in sm["box"])
                vol = 1
                for (a, b), dim in zip(box, shape):
                    if not 0 <= a <= b <= dim:
                        logger.warning("checkpoint %s: leaf %s shard box out "
                                       "of range — skipped", path, key)
                        return None
                    vol *= b - a
                covered += vol
                shards.append((box, arr))
            total = int(np.prod(shape)) if shape else 1
            if covered != total:
                logger.warning("checkpoint %s: leaf %s shards cover %d of %d "
                               "elements — skipped (partial shard set)",
                               path, key, covered, total)
                return None
            data[key] = shards
    except (KeyError, TypeError, ValueError):
        logger.warning("checkpoint %s: malformed manifest — skipped", path)
        return None
    return manifest, data


def _assemble(like: Pytree, manifest: dict, data: "dict[str, list]",
              shardings: Pytree | None = None) -> Pytree:
    """Reassemble the pytree of ``like`` from verified shard data."""
    import jax

    leaves_like, _ = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for (kp, leaf), shard in zip(leaves_like, shard_leaves):
        if shard is None and getattr(leaf, "_committed", False):
            # bit-exact sharded resume without an explicit shardings tree:
            # put each leaf back where the template leaf lives. Only for
            # COMMITTED templates (explicitly placed / mesh-sharded) — an
            # uncommitted leaf's default device-0 placement must not be
            # pinned onto the restored array, or jit loses the right to
            # migrate it into a shard_map's mesh (dryrun --resume)
            shard = getattr(leaf, "sharding", None)
        key = "/".join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in kp)
        if key not in data:
            raise KeyError(f"checkpoint has no leaf {key!r} for the given "
                           "template (structure mismatch)")
        rec = manifest["leaves"][key]
        shape = tuple(rec["shape"])
        if shape != tuple(leaf.shape):
            raise ValueError(f"leaf {key}: checkpoint shape {shape} != "
                             f"template {tuple(leaf.shape)}")
        full = np.empty(shape, dtype=data[key][0][1].dtype)
        for box, arr in data[key]:
            idx = tuple(slice(a, b) for a, b in box)
            full[idx] = arr.reshape(full[idx].shape)
        if shard is not None:
            out.append(jax.device_put(full.astype(leaf.dtype), shard))
        else:
            out.append(jax.numpy.asarray(full, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def _check_config(manifest: dict, expect_config: dict | None,
                  path: str) -> None:
    if not expect_config:
        return
    got = manifest.get("config", {})
    # the manifest round-tripped through JSON (tuples → lists, int keys →
    # str): normalize the expectation the same way before comparing
    expect = json.loads(json.dumps(expect_config))
    diff = {k: (got.get(k), v) for k, v in expect.items()
            if got.get(k) != v}
    if diff:
        detail = ", ".join(f"{k}: checkpoint={a!r} run={b!r}"
                           for k, (a, b) in sorted(diff.items()))
        raise CheckpointConfigMismatch(
            f"{path} was written by a different run configuration — "
            f"refusing to resume ({detail})")


def load_checkpoint(path: str, like: Pytree, shardings: Pytree | None = None,
                    fs: LocalFs = LOCAL_FS, expect_config: dict | None = None
                    ) -> "tuple[Pytree, dict]":
    """Verify + restore ONE committed checkpoint directory (explicit-path
    resume). Raises on any defect — an explicitly named checkpoint that
    fails verification is an error, not something to silently skip."""
    found = verify_checkpoint(path, fs=fs)
    if found is None:
        raise ValueError(f"checkpoint {path} is incomplete or corrupt")
    manifest, data = found
    _check_config(manifest, expect_config, path)
    return _assemble(like, manifest, data, shardings), manifest


def load_latest(directory: str, like: Pytree,
                shardings: Pytree | None = None, fs: LocalFs = LOCAL_FS,
                expect_config: dict | None = None
                ) -> "tuple[Pytree, dict] | None":
    """Restore from the newest COMPLETE checkpoint under ``directory``.

    Walks the committed rounds newest-first, verifying each (digests, shard
    coverage); torn/corrupt/partial entries are skipped with a logged
    reason. Returns None when nothing restorable exists. A complete
    checkpoint whose config fingerprint mismatches ``expect_config`` raises
    :class:`CheckpointConfigMismatch` — resuming it would silently diverge.
    """
    for round_idx, path in list_checkpoints(directory, fs=fs):
        found = verify_checkpoint(path, fs=fs)
        if found is None:
            continue
        manifest, data = found
        _check_config(manifest, expect_config, path)
        return _assemble(like, manifest, data, shardings), manifest
    return None


def prune_checkpoints(directory: str, keep: int, fs: LocalFs = LOCAL_FS,
                      active_tmp: str | None = None) -> "list[str]":
    """Retention/GC: drop the oldest committed checkpoints beyond ``keep``
    and sweep dead ``.tmp-*`` staging remnants (crashed saves). ``active_tmp``
    names the one staging dir an in-flight save owns, which GC must not
    touch. Returns the removed paths."""
    removed = []
    if keep > 0:
        for _, path in list_checkpoints(directory, fs=fs)[keep:]:
            fs.rmtree(path)
            removed.append(path)
    if fs.exists(directory):
        for name in fs.listdir(directory):
            full = os.path.join(directory, name)
            if name.startswith(".tmp-") and full != active_tmp:
                fs.rmtree(full)
                removed.append(full)
    if removed:
        logger.info("checkpoint GC removed %d entries under %s",
                    len(removed), directory)
    return removed


__all__ = [
    "CKPT_FORMAT",
    "CheckpointConfigMismatch",
    "ckpt_name",
    "inventory_of",
    "list_checkpoints",
    "load_checkpoint",
    "load_latest",
    "prune_checkpoints",
    "snapshot_shards",
    "verify_checkpoint",
    "write_checkpoint",
]
