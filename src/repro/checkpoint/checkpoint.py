"""Checkpointing: npz-based pytree save/restore (no orbax offline).

Flattens the pytree with '/'-joined key paths; restores into an identical
structure. Sharded arrays are fetched to host (per-process save) and restored
with ``jax.device_put`` against provided shardings when given.

Saves are ATOMIC: both the ``.npz`` and its ``.meta.json`` go through the
write-temp-then-rename helper (checkpoint/atomic.py), so an interrupted or
concurrent save never leaves a torn file under the final name — a reader
sees the previous complete checkpoint or the new one, nothing in between.
For the preemption-tolerant sharded directory format (per-shard saves,
manifest commit marker, crash recovery) see checkpoint/sharded_ckpt.py.
"""
from __future__ import annotations

import io
import json
import os
from typing import Any

import jax
import numpy as np

from repro.checkpoint.atomic import LOCAL_FS, LocalFs, write_bytes_atomic

Pytree = Any


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in kp
        )
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot hold ml_dtypes (bf16 etc.): store as f32; restore
            # casts back to the template dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path: str, params: Pytree, step: int = 0,
                    extra: dict | None = None, fs: LocalFs = LOCAL_FS) -> None:
    flat = _flatten(params)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    npz_path = path if path.endswith(".npz") else path + ".npz"
    write_bytes_atomic(npz_path, buf.getvalue(), fs=fs)
    meta = {"step": step, "keys": sorted(flat), **(extra or {})}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    write_bytes_atomic(meta_path, json.dumps(meta).encode(), fs=fs)


def restore_checkpoint(path: str, like: Pytree, shardings: Pytree | None = None) -> Pytree:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(leaves_like)
    )
    out = []
    for (kp, leaf), shard in zip(leaves_like, shard_leaves):
        key = "/".join(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in kp
        )
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if shard is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), shard))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out)


def latest_step(ckpt_dir: str) -> int | None:
    metas = [f for f in os.listdir(ckpt_dir) if f.endswith(".meta.json")]
    if not metas:
        return None
    steps = []
    for m in metas:
        with open(os.path.join(ckpt_dir, m)) as f:
            steps.append(json.load(f).get("step", 0))
    return max(steps)
