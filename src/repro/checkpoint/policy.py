"""Async checkpoint policy: chunk-boundary snapshots, background commits,
one-in-flight backpressure, graceful failure.

``CheckpointPolicy`` is the declarative knob set (directory / every / keep /
mode); ``CheckpointManager`` is the engine-side driver:

  * ``maybe_save(state, round_idx, chunk_wall)`` runs at the engine's
    existing chunk-boundary host sync. When a save is due it snapshots the
    state's addressable shards to host numpy (a COPY — the engine donates
    the device buffers to the next chunk) and dispatches serialization +
    checksums + atomic commit + GC to ONE background thread, so the write
    overlaps the next chunk's compute. The snapshot itself adds no
    ``jax.device_get``: the chunk results are already host-synced, and the
    per-shard copies go through the arrays' own host buffers
    (core/sharded.leaf_addressable_shards) — pinned by the same
    device_get-counting idiom as the sinks.
  * **backpressure, wait-and-warn**: at most one save is in flight. If the
    next save comes due while the previous one is still writing, the
    manager WAITS for it (state consistency beats save frequency) and
    records a ``checkpoint_stalled`` event — the save exceeded the chunk
    wall time, i.e. the chunk compute no longer hides the write. The event
    rides the run footer's alarm list like any obs/alarms event.
  * **graceful failure**: a save that exhausts its I/O retries (ENOSPC, a
    dying disk) is counted and alarmed (``checkpoint_failed``), its staging
    remnant is swept, and the run continues — the next due save starts
    clean. A :class:`repro.robust.fs_faults.SimulatedKill` is NOT handled:
    the manager marks itself dead and stops writing, modeling the process
    death it simulates.
  * ``mode="sync_gather"`` is the deliberately-bad baseline the benchmark
    compares against: a blocking full ``jax.device_get`` of the state
    through this one process and an inline legacy npz save — the stall the
    async path exists to remove (benchmarks/ext_checkpoint.py).

Telemetry: ``telemetry()`` returns the SCHEMA_VERSION-4 footer fields
(checkpoint_save_ms / checkpoint_bytes / checkpoint_failures); ``events``
holds the structured alarm records.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
import time
from typing import Any

from repro.checkpoint.atomic import LOCAL_FS, LocalFs
from repro.checkpoint.sharded_ckpt import (
    prune_checkpoints, snapshot_shards, write_checkpoint,
)

Pytree = Any

logger = logging.getLogger("repro.checkpoint")

MODES = ("async", "sync", "sync_gather")


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """When and where to checkpoint.

    every — save at the first chunk boundary at/after each multiple of
    ``every`` rounds (the engine only has host control at chunk boundaries;
    with ``every`` a multiple of the chunk size the boundary is exact).
    keep — retention: committed checkpoints beyond the newest ``keep`` are
    GC'd after each successful commit (0 = keep everything).
    """

    directory: str
    every: int = 10
    keep: int = 3
    mode: str = "async"
    retries: int = 3
    backoff_s: float = 0.05

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if self.keep < 0:
            raise ValueError(f"keep must be >= 0, got {self.keep}")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode}")


class CheckpointManager:
    """Drives a CheckpointPolicy from the engine's chunk loop. Not
    thread-safe beyond its own single worker: one manager per run."""

    def __init__(self, policy: CheckpointPolicy, *,
                 config: dict | None = None, fs: LocalFs = LOCAL_FS,
                 last_saved: int = 0):
        self.policy = policy
        self.config = config or {}
        self.fs = fs
        self.events: "list[dict]" = []
        self.dead = False          # a (simulated) kill landed mid-save
        self._worker: threading.Thread | None = None
        # round of the last DISPATCHED save; a resumed run seeds this with
        # its resume round so the cadence stays aligned across preemptions
        self._last_saved = last_saved
        self._save_ms_total = 0.0
        self._bytes_total = 0
        self._failures = 0
        self._saves = 0
        self._lock = threading.Lock()

    # -- engine hooks -----------------------------------------------------
    def maybe_save(self, state: Pytree, round_idx: int,
                   chunk_wall: float | None = None) -> bool:
        """Call at every chunk boundary with the state AFTER ``round_idx``
        global rounds. Returns True when a save was dispatched."""
        if self.dead:
            return False
        if round_idx - self._last_saved < self.policy.every:
            return False
        self._wait_for_inflight(round_idx, chunk_wall)
        if self.dead:
            return False
        self._last_saved = round_idx
        t0 = time.perf_counter()
        if getattr(self.fs, "on_save_start", None) is not None:
            self.fs.on_save_start()   # crash-injection save counter
        if self.policy.mode == "sync_gather":
            self._sync_gather_save(state, round_idx, t0)
            return True
        snapshot = snapshot_shards(state)
        snap_ms = 1e3 * (time.perf_counter() - t0)
        if self.policy.mode == "sync":
            self._write(snapshot, round_idx, t0, snap_ms)
            return True
        self._worker = threading.Thread(
            target=self._write, args=(snapshot, round_idx, t0, snap_ms),
            name=f"ckpt-save-{round_idx}", daemon=True)
        self._worker.start()
        return True

    def finalize(self) -> None:
        """Join any in-flight save (end of run / driver finally-block)."""
        w = self._worker
        if w is not None and w.is_alive():
            w.join()
        self._worker = None

    def telemetry(self) -> dict:
        """The v4 footer fields."""
        with self._lock:
            return {
                "checkpoint_save_ms": round(self._save_ms_total, 3),
                "checkpoint_bytes": int(self._bytes_total),
                "checkpoint_failures": int(self._failures),
            }

    @property
    def saves_completed(self) -> int:
        with self._lock:
            return self._saves

    # -- internals --------------------------------------------------------
    def _wait_for_inflight(self, round_idx: int, chunk_wall: float | None):
        w = self._worker
        if w is None or not w.is_alive():
            return
        t0 = time.perf_counter()
        w.join()
        waited_ms = 1e3 * (time.perf_counter() - t0)
        event = {
            "rule": "checkpoint_stalled",
            "field": "checkpoint_save_ms",
            "op": "gt",
            "threshold": None if chunk_wall is None
            else round(1e3 * chunk_wall, 3),
            "round": int(round_idx),
            "value": round(waited_ms, 3),
            "action": "warn",
        }
        self.events.append(event)
        logger.warning(
            "alarm checkpoint_stalled: save still in flight at round %d — "
            "backpressure engaged, waited %.1fms (chunk wall %.1fms)",
            round_idx, waited_ms,
            1e3 * chunk_wall if chunk_wall is not None else float("nan"))

    def _write(self, snapshot, round_idx: int, t0: float, snap_ms: float):
        from repro.robust.fs_faults import SimulatedKill

        try:
            path, nbytes = write_checkpoint(
                self.policy.directory, snapshot, round_idx,
                config=self.config, fs=self.fs,
                retries=self.policy.retries,
                backoff_s=self.policy.backoff_s)
            prune_checkpoints(self.policy.directory, self.policy.keep,
                              fs=self.fs)
        except SimulatedKill:
            # the process "died" between save-start and commit: stop doing
            # anything at all (the torn .tmp-* stays on disk for recovery
            # tests to trip over, exactly like a real preemption)
            self.dead = True
            return
        except Exception as e:
            with self._lock:
                self._failures += 1
                self._save_ms_total += 1e3 * (time.perf_counter() - t0)
            event = {
                "rule": "checkpoint_failed",
                "field": "checkpoint_failures",
                "op": "gt",
                "threshold": 0.0,
                "round": int(round_idx),
                "value": float(self._failures),
                "action": "warn",
            }
            self.events.append(event)
            logger.warning("alarm checkpoint_failed: save at round %d "
                           "failed after retries: %s", round_idx, e)
            return
        with self._lock:
            self._saves += 1
            self._bytes_total += nbytes
            self._save_ms_total += 1e3 * (time.perf_counter() - t0)
        logger.info("checkpoint committed: %s (%.1f KiB, %.1fms incl. "
                    "%.1fms snapshot)", path, nbytes / 1024,
                    1e3 * (time.perf_counter() - t0), snap_ms)

    def _sync_gather_save(self, state, round_idx: int, t0: float):
        """The legacy stall, kept as the benchmark baseline: full-state
        device_get through this one process + blocking npz save."""
        import jax

        from repro.checkpoint.checkpoint import save_checkpoint

        host_state = jax.device_get(state)
        path = os.path.join(self.policy.directory, "sync_gather",
                            f"state_{round_idx:08d}")
        save_checkpoint(path, host_state, step=round_idx, fs=self.fs)
        nbytes = 0
        npz = path + ".npz"
        if self.fs.exists(npz):
            try:
                nbytes = len(self.fs.read_bytes(npz))
            except OSError:
                pass
        with self._lock:
            self._saves += 1
            self._bytes_total += nbytes
            self._save_ms_total += 1e3 * (time.perf_counter() - t0)


__all__ = ["MODES", "CheckpointManager", "CheckpointPolicy"]
