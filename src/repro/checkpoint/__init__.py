from repro.checkpoint.atomic import (  # noqa: F401
    LOCAL_FS, LocalFs, commit_dir, sha256_hex, with_retries,
    write_bytes_atomic,
)
from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_step, restore_checkpoint, save_checkpoint,
)
from repro.checkpoint.policy import (  # noqa: F401
    CheckpointManager, CheckpointPolicy,
)
from repro.checkpoint.sharded_ckpt import (  # noqa: F401
    CheckpointConfigMismatch, ckpt_name, inventory_of, list_checkpoints,
    load_checkpoint, load_latest, prune_checkpoints, snapshot_shards,
    verify_checkpoint, write_checkpoint,
)
