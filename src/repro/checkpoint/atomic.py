"""Atomic filesystem commit protocol for checkpoints.

Every durable artifact this package writes goes through two rules:

  1. **write-temp-then-rename** — bytes land in a temp name on the same
     filesystem, are flushed AND fsync'd, and only then ``os.replace``d over
     the final name. A reader can observe the old file or the new file,
     never a torn hybrid. The same helper serves the legacy single-file npz
     path (checkpoint.py) and the sharded directory format (sharded_ckpt.py).
  2. **directory commit marker** — a multi-file checkpoint is staged in a
     ``.tmp-*`` directory; its manifest is written (fsync'd) LAST, then the
     whole directory is renamed into its final ``ckpt_<round>`` name. A
     checkpoint therefore exists completely or not at all: a crash at ANY
     byte of the save leaves either the previous committed set untouched or
     a ``.tmp-*`` remnant that discovery ignores and GC later removes.

All OS access goes through an injectable ``Fs`` object so the recovery
harness (repro/robust/fs_faults.py) can deterministically inject torn
writes, ENOSPC, and process kills between save-start and commit. Production
code uses :data:`LOCAL_FS`, which is the plain ``os`` module behavior.

Transient I/O errors are retried with exponential backoff
(:func:`with_retries`); a persistent error (e.g. a truly full disk)
exhausts the retries and surfaces to the caller, which degrades gracefully
(the run continues, the failure is counted and alarmed — policy.py).
"""
from __future__ import annotations

import hashlib
import logging
import os
import shutil
import time

logger = logging.getLogger("repro.checkpoint")


class LocalFs:
    """The real filesystem. One method per OS primitive the checkpoint path
    needs, so a fault-injecting subclass can intercept each individually
    (repro/robust/fs_faults.FaultyFs)."""

    def write_bytes(self, path: str, data: bytes) -> None:
        with open(path, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())

    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as f:
            return f.read()

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)

    def makedirs(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)

    def listdir(self, path: str) -> "list[str]":
        return os.listdir(path)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def rmtree(self, path: str) -> None:
        shutil.rmtree(path, ignore_errors=True)

    def fsync_dir(self, path: str) -> None:
        """Durably record a rename/creation in the parent directory entry
        (POSIX: fsync the directory fd). Best-effort on platforms without
        directory fds."""
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(fd)
        finally:
            os.close(fd)


LOCAL_FS = LocalFs()


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def with_retries(fn, *, retries: int = 3, backoff_s: float = 0.05,
                 sleep=time.sleep, what: str = "io"):
    """Run ``fn()``, retrying transient OSErrors with exponential backoff.

    ``retries`` is the number of RE-tries (retries=3 → up to 4 attempts).
    Non-OSError exceptions propagate immediately — a SimulatedKill from the
    crash-injection harness must behave like a process death, not a flaky
    disk.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except OSError as e:
            if attempt >= retries:
                raise
            delay = backoff_s * (2 ** attempt)
            logger.warning("checkpoint %s failed (%s); retry %d/%d in %.3fs",
                           what, e, attempt + 1, retries, delay)
            sleep(delay)
            attempt += 1


def write_bytes_atomic(path: str, data: bytes, fs: LocalFs = LOCAL_FS,
                       retries: int = 3, backoff_s: float = 0.05,
                       sleep=time.sleep) -> None:
    """Write ``data`` to ``path`` via temp-file + fsync + rename: a reader
    (or a crash) never observes a torn ``path``. The temp name carries the
    pid so two writers cannot collide on it."""
    fs.makedirs(os.path.dirname(path) or ".")
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with_retries(lambda: fs.write_bytes(tmp, data),
                     retries=retries, backoff_s=backoff_s, sleep=sleep,
                     what=f"write {os.path.basename(path)}")
        with_retries(lambda: fs.replace(tmp, path),
                     retries=retries, backoff_s=backoff_s, sleep=sleep,
                     what=f"commit {os.path.basename(path)}")
    except BaseException:
        if fs.exists(tmp):
            try:
                fs.rmtree(tmp)
            except OSError:
                pass
        raise
    fs.fsync_dir(os.path.dirname(path) or ".")


def commit_dir(tmp_dir: str, final_dir: str, fs: LocalFs = LOCAL_FS,
               retries: int = 3, backoff_s: float = 0.05,
               sleep=time.sleep) -> None:
    """Atomically publish a fully-staged checkpoint directory. The rename is
    the commit point — everything before it is invisible to discovery."""
    with_retries(lambda: fs.replace(tmp_dir, final_dir),
                 retries=retries, backoff_s=backoff_s, sleep=sleep,
                 what=f"commit {os.path.basename(final_dir)}")
    fs.fsync_dir(os.path.dirname(final_dir) or ".")


__all__ = ["LOCAL_FS", "LocalFs", "commit_dir", "sha256_hex",
           "with_retries", "write_bytes_atomic"]
