"""Pluggable wire-compression subsystem for the FL runtimes.

``make_channel("int8")`` → a CommChannel whose uplink codec, broadcast codec
and error-feedback policy the round cores (core/algorithms.py) and both
runtimes (vmap + core/sharded.py) honor, with byte-accurate per-round cost
accounting replacing the historical fp32 float counting.
"""
from repro.comm.channel import (  # noqa: F401
    CODECS,
    IDENTITY_CHANNEL,
    CommChannel,
    make_channel,
)
from repro.comm.codecs import (  # noqa: F401
    Bf16Codec,
    Codec,
    Fp32Codec,
    IdentityCodec,
    Int8SRCodec,
    TopKCodec,
    parse_codec,
)
from repro.comm.schema import (  # noqa: F401
    CTRL_UPLINK,
    DELTA_UPLINK,
    DIR_UPLINK,
    GRAD_UPLINK,
    UplinkSpec,
    init_schema_state,
    validate_schema,
)
