"""CommChannel: the client↔server wire of a federated round.

A channel pairs an uplink codec (client→server: local deltas, gradients,
control variates) with a broadcast codec (server→client: w^t, ∇f) and a
client-side error-feedback policy. The round cores in core/algorithms.py pass
every uplink through ``CrossClientReduce.uplink``/``uplink_ef`` and every
broadcast through ``CrossClientReduce.broadcast``, so the SAME channel drives
both the vmap and the shard_map runtimes (the encoded representation is what
crosses the mesh: the psum reduces dequantized values).

Error feedback (Seide et al. 2014 / EF-SGD): the compression residual
e_k ← u_k − decode(encode(u_k)) is kept ON THE CLIENT (carried in
ServerState.comm, per-client buffers with leading axis K) and added to the
next round's upload, so biased codecs (topk) still converge to the exact
optimum and unbiased ones (int8-SR) lose no signal to quantization noise
accumulation. Absolute-state uploads additionally carry a difference-coding
reference there (see ServerState.comm / CrossClientReduce.uplink).

Byte accounting convention: a round costs the sum of ``uplink_bytes(params,
kind)`` over the algorithm's declarative uplink schema (comm/schema.py, one
model-sized record per Table 1 client-uplink unit, each at its kind's
codec-exact rate) plus one ``downlink_bytes`` for the GIANT line-search extra
broadcast. Per-client scalar uplinks (losses, AA stats) are ignored, as the
paper's Table 1 ignores them. The identity channel therefore reproduces the
old counters exactly: comm_bytes == 4 × comm_floats.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.comm.codecs import CODECS, Codec, IdentityCodec, parse_codec

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CommChannel:
    """up — uplink codec; down — broadcast codec (must be deterministic);
    error_feedback — carry per-client compression residuals across rounds."""

    up: Codec = IdentityCodec()
    down: Codec = IdentityCodec()
    error_feedback: bool = False

    def __post_init__(self):
        if not self.down.deterministic:
            raise ValueError(
                f"broadcast codec {self.down} is stochastic; clients cannot "
                "reproduce the server's draws — use identity/fp32/bf16 downlink"
            )
        if self.down.delta_only:
            raise ValueError(
                f"broadcast codec {self.down} is delta-only, but the downlink "
                "carries absolute state (w^t, ∇f) — sparsifying it floors "
                "convergence; use identity/fp32/bf16 downlink"
            )

    @property
    def name(self) -> str:
        tag = f"{self.up}"
        if self.error_feedback:
            tag += "+ef"
        if not isinstance(self.down, IdentityCodec):
            tag += f"/{self.down}"
        return tag

    @property
    def is_identity(self) -> bool:
        return (isinstance(self.up, IdentityCodec)
                and isinstance(self.down, IdentityCodec))

    def up_codec(self, kind: str = "delta") -> Codec:
        """The codec an uplink of ``kind`` actually travels through.

        kind="delta": quantities that vanish at the optimum (model deltas,
        Newton directions) — always the configured uplink codec.
        kind="aux": absolute-state uploads (gradient collection, SCAFFOLD
        control variates) — fp32 for delta-only codecs (see Codec.delta_only).
        """
        if kind == "aux" and self.up.delta_only:
            return IdentityCodec()
        return self.up

    def state_buffers(self, spec) -> "tuple[str, ...]":
        """Which per-client buffers an uplink declared by ``spec`` (a
        comm/schema.py UplinkSpec) carries across rounds under this channel.

        "ef"  — error-feedback residual, added to the next upload (any lossy
                codec with ``error_feedback`` on);
        "ref" — difference-coding reference for absolute-state ("aux")
                uploads: the wire carries v_k − h_k, so quantization noise
                decays with the diff instead of staying O(1) at the optimum.

        Empty for identity wires and for non-stateful specs — the schema's
        allocator (comm/schema.py::init_schema_state) skips those tags.
        """
        codec = self.up_codec(spec.kind)
        if isinstance(codec, IdentityCodec) or not spec.stateful:
            return ()
        buffers = []
        if self.error_feedback:
            buffers.append("ef")
        if spec.kind == "aux":
            buffers.append("ref")
        return tuple(buffers)

    # ---- wire simulation ---------------------------------------------------
    # (uplinks go through CrossClientReduce.uplink, which owns the error-
    # feedback / difference-coding state — there is deliberately no bare
    # uplink roundtrip here that would bypass it)
    def broadcast(self, tree: Pytree) -> Pytree:
        """A server broadcast as every client decodes it (deterministic)."""
        return self.down.tree_roundtrip(tree)

    # ---- exact per-exchange byte costs --------------------------------------
    def uplink_bytes(self, tree: Pytree, kind: str = "delta") -> int:
        return self.up_codec(kind).tree_bytes(tree)

    def downlink_bytes(self, tree: Pytree) -> int:
        return self.down.tree_bytes(tree)


IDENTITY_CHANNEL = CommChannel()


def make_channel(spec: "str | CommChannel | None") -> CommChannel:
    """Parse a ``--comm-codec`` spec into a channel.

    Grammar: ``up[+ef|+noef][/down]`` with up/down from ``codecs.parse_codec``
    (e.g. ``int8``, ``topk:0.05``, ``int8+noef``, ``bf16/bf16``). Error
    feedback defaults ON for lossy uplinks other than bf16 (whose roundtrip
    error is a deterministic last-ulp rounding) and OFF otherwise.
    """
    if spec is None:
        return IDENTITY_CHANNEL
    if isinstance(spec, CommChannel):
        return spec
    up_spec, _, down_spec = spec.partition("/")
    ef = None
    if up_spec.endswith("+ef"):
        up_spec, ef = up_spec[:-3], True
    elif up_spec.endswith("+noef"):
        up_spec, ef = up_spec[:-5], False
    up = parse_codec(up_spec)
    down = parse_codec(down_spec) if down_spec else IdentityCodec()
    if ef is None:
        # fp32/bf16 roundtrip error is a deterministic last-ulp rounding —
        # not worth a carried residual; int8/topk default to EF
        ef = up.lossy and up.name not in ("bf16", "fp32")
    return CommChannel(up=up, down=down, error_feedback=ef)


__all__ = [
    "CODECS",
    "CommChannel",
    "IDENTITY_CHANNEL",
    "make_channel",
]
