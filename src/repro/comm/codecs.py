"""Wire codecs for the FL communication channel (repro/comm).

A codec models what one client↔server exchange of a parameter-sized pytree
costs (``wire_bytes``) and loses (``roundtrip``). Codecs are frozen,
hashable dataclasses so round functions can close over them under jit, and
every ``roundtrip`` is a pure jax function that the round cores vmap over the
client axis — identical under the vmap and shard_map runtimes.

  identity — fp32 on the wire, lossless (the repo's historical model)
  bf16     — round-to-nearest bfloat16, 2 bytes/value, deterministic
  int8     — per-chunk-scaled stochastic-rounding int8 (kernels/quant/):
             unbiased, 1 byte/value + one f32 scale per ``chunk`` values
  topk     — magnitude top-k sparsification: k = ceil(ratio·n) per leaf,
             (f32 value, int32 index) pairs on the wire, deterministic

``wire_bytes`` is static (shape-only), which is what makes the per-round byte
accounting exact rather than sampled.

Codecs never see carried state: statefulness is the CHANNEL's job, driven by
the declarative uplink schemas (repro/comm/schema.py). Every round core —
SVRG/SCAFFOLD families and the Newton family (GIANT, Newton-GMRES, DANE)
alike — declares its uploads as UplinkSpec records, and the channel resolves
error-feedback residuals and difference-coding references for each record
from ServerState.comm. There is deliberately no stateless uplink path left:
before the schema refactor the Newton rounds shipped raw gradients with no
diff-coding reference, and every lossy codec floored them (bf16 1.2e-4, int8
6.7e-4 rel-error vs 5e-7 on the fp32 wire); with the schema'd wire they
converge to 1e-6 under int8 (benchmarks/results/ext_compression.json).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant.ops import chunk_rows, int8_sr_roundtrip
from repro.kernels.quant.quant import DEFAULT_CHUNK

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base: the identity (fp32) wire format."""

    name = "identity"
    #: deterministic codecs never consume rng and may sit on the broadcast
    #: (server→client) leg of a channel; stochastic ones are uplink-only.
    deterministic = True
    #: lossy codecs default to error feedback on the delta uplink.
    lossy = False
    #: delta-only codecs apply to uploads that vanish at the optimum (model
    #: deltas, Newton directions) but NOT to absolute-state uploads (gradient
    #: collection, SCAFFOLD control variates) — sparsifying those leaves an
    #: O(1) noise floor even under error feedback (heterogeneous clients keep
    #: O(1) local gradients at w*, so the dropped mass never shrinks; measured:
    #: fedsvrg stalls at rel-err ~0.2 with topk'd gradients). Channels route
    #: absolute uploads of a delta-only codec through fp32 and charge the
    #: bytes accordingly.
    delta_only = False

    def roundtrip(self, leaf: jax.Array, rng: jax.Array | None = None) -> jax.Array:
        """encode+decode of one leaf: what the server sees of the upload."""
        return leaf

    def wire_bytes(self, shape, dtype=jnp.float32) -> int:
        """Exact bytes on the wire for one leaf of this shape."""
        return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize

    def tree_roundtrip(self, tree: Pytree, rng: jax.Array | None = None) -> Pytree:
        """Leaf-wise roundtrip; stochastic codecs fold the leaf index into rng
        so no two leaves share draws."""
        if self.deterministic:
            return jax.tree.map(self.roundtrip, tree)
        leaves, treedef = jax.tree.flatten(tree)
        out = [self.roundtrip(leaf, jax.random.fold_in(rng, i))
               for i, leaf in enumerate(leaves)]
        return jax.tree.unflatten(treedef, out)

    def tree_bytes(self, tree: Pytree) -> int:
        """Exact bytes for one upload/broadcast of a whole pytree."""
        return sum(self.wire_bytes(l.shape, l.dtype) for l in jax.tree.leaves(tree))

    def __str__(self) -> str:
        return self.name


@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    pass


@dataclasses.dataclass(frozen=True)
class Fp32Codec(Codec):
    """Round to float32 on the wire: 4 bytes/value.

    Identical to ``identity`` when the compute dtype is f32 (the default
    everywhere); under f64 compute (jax_enable_x64 benchmarks) it models the
    realistic 'full-precision' wire — fp32 floats — without pretending the
    wire ships f64.
    """

    name = "fp32"
    lossy = True

    def roundtrip(self, leaf, rng=None):
        return leaf.astype(jnp.float32).astype(leaf.dtype)

    def wire_bytes(self, shape, dtype=jnp.float32):
        return int(np.prod(shape, dtype=np.int64)) * 4


@dataclasses.dataclass(frozen=True)
class Bf16Codec(Codec):
    name = "bf16"
    lossy = True

    def roundtrip(self, leaf, rng=None):
        return leaf.astype(jnp.bfloat16).astype(leaf.dtype)

    def wire_bytes(self, shape, dtype=jnp.float32):
        return int(np.prod(shape, dtype=np.int64)) * 2


@dataclasses.dataclass(frozen=True)
class Int8SRCodec(Codec):
    """Per-chunk-scaled stochastic-rounding int8 (kernels/quant/).

    Unbiased: E[roundtrip(x)] = x with |error| < max|x_chunk|/127 — the error
    scale shrinks with the upload itself, so SVRG-family methods keep their
    linear convergence under quantization (benchmarks/ext_compression.py).
    """

    name = "int8"
    deterministic = False
    lossy = True
    chunk: int = DEFAULT_CHUNK

    def roundtrip(self, leaf, rng=None):
        flat = leaf.reshape(-1).astype(jnp.float32)
        dec = int8_sr_roundtrip(flat, rng, chunk=self.chunk)
        return dec.reshape(leaf.shape).astype(leaf.dtype)

    def wire_bytes(self, shape, dtype=jnp.float32):
        n = int(np.prod(shape, dtype=np.int64))
        return n + 4 * chunk_rows(n, self.chunk)


@dataclasses.dataclass(frozen=True)
class TopKCodec(Codec):
    """Keep the k = ceil(ratio·n) largest-magnitude entries per leaf.

    Biased (everything else is dropped), so it NEEDS the channel's error
    feedback to converge — the dropped mass is re-injected next round.
    """

    name = "topk"
    lossy = True
    delta_only = True
    ratio: float = 0.01

    def __post_init__(self):
        if not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"topk ratio must be in (0, 1], got {self.ratio}")

    def k_for(self, n: int) -> int:
        return min(n, max(1, math.ceil(self.ratio * n)))

    def roundtrip(self, leaf, rng=None):
        flat = leaf.reshape(-1)
        k = self.k_for(flat.shape[0])
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        # kept values ship as f32 (what wire_bytes charges), whatever the
        # compute dtype
        kept = flat[idx].astype(jnp.float32).astype(flat.dtype)
        dec = jnp.zeros_like(flat).at[idx].set(kept)
        return dec.reshape(leaf.shape)

    def wire_bytes(self, shape, dtype=jnp.float32):
        # one (f32 value, int32 index) pair per kept entry
        return self.k_for(int(np.prod(shape, dtype=np.int64))) * 8

    def __str__(self) -> str:
        return f"topk:{self.ratio:g}"


#: registry for the ``--comm-codec`` spec strings (see parse_codec)
CODECS = ("identity", "fp32", "bf16", "int8", "topk")


def parse_codec(spec: str) -> Codec:
    """'identity' | 'fp32' | 'bf16' | 'int8[:chunk]' | 'topk[:ratio]' -> Codec."""
    name, _, param = spec.partition(":")
    if name == "identity":
        return IdentityCodec()
    if name == "fp32":
        return Fp32Codec()
    if name == "bf16":
        return Bf16Codec()
    if name == "int8":
        return Int8SRCodec(chunk=int(param)) if param else Int8SRCodec()
    if name == "topk":
        return TopKCodec(ratio=float(param)) if param else TopKCodec()
    raise ValueError(f"unknown codec {name!r}; choose from {CODECS}")
