"""Declarative uplink schemas: what a round puts on the wire, as data.

Every round core (core/algorithms.py) declares its client→server uploads as a
tuple of :class:`UplinkSpec` records — one per wire crossing, in round order.
The record is the single source of truth three consumers read:

  * ``init_schema_state`` allocates exactly the per-client comm buffers the
    algorithm's channel needs (error-feedback residuals, difference-coding
    references) — nothing more, keyed by ``tag`` in ``ServerState.comm``;
  * ``CrossClientReduce.uplink`` resolves those buffers from the carried
    state uniformly, so EVERY algorithm's uploads are stateful under a lossy
    channel — an algorithm cannot re-introduce a stateless wire by accident,
    it would have to declare one;
  * ``comm_bytes_per_round`` charges each spec its codec-exact bytes
    (``kind`` routes delta-only codecs to the fp32 aux rate).

Fields:

  tag      — unique name of the upload within its round; the key of its
             carried buffers in ``ServerState.comm``.
  kind     — "delta": the quantity vanishes at the optimum (model deltas,
             Newton directions) and always travels through the configured
             uplink codec; "aux": absolute state (gradient collection,
             control variates) — delta-only codecs fall back to fp32, and
             lossy codecs get a DIANA-style difference-coding reference so
             quantization noise decays with the diff instead of staying O(1).
  anchored — the wire quantity is ``value − anchor`` for a broadcast-known
             anchor (model uploads travel as deltas from w^t); the channel
             re-bases on the anchor after decoding.
  stateful — eligible for carried buffers. Every model-sized upload is;
             reserved so future scalar/sketch uploads can opt out.
  fold     — integer folded into the per-client rng keys by stochastic
             codecs; distinct per tag so one round's uploads never share
             quantization draws.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any

#: valid ``UplinkSpec.kind`` values (see CommChannel.up_codec)
UPLINK_KINDS = ("delta", "aux")


class UplinkSpec(NamedTuple):
    tag: str
    kind: str
    anchored: bool
    stateful: bool
    fold: int


#: canonical uplinks shared by the round cores (core/algorithms.py)
GRAD_UPLINK = UplinkSpec("grad", "aux", anchored=False, stateful=True, fold=101)
DELTA_UPLINK = UplinkSpec("delta", "delta", anchored=True, stateful=True, fold=102)
CTRL_UPLINK = UplinkSpec("ctrl", "aux", anchored=False, stateful=True, fold=103)
DIR_UPLINK = UplinkSpec("dir", "delta", anchored=False, stateful=True, fold=104)


def validate_schema(schema: "tuple[UplinkSpec, ...]") -> "tuple[UplinkSpec, ...]":
    """Reject duplicate tags/folds and unknown kinds at declaration time."""
    tags = [s.tag for s in schema]
    folds = [s.fold for s in schema]
    if len(set(tags)) != len(tags):
        raise ValueError(f"duplicate uplink tags in schema: {tags}")
    if len(set(folds)) != len(folds):
        raise ValueError(f"duplicate rng folds in schema: {folds}")
    for s in schema:
        if s.kind not in UPLINK_KINDS:
            raise ValueError(
                f"uplink {s.tag!r}: unknown kind {s.kind!r}; "
                f"choose from {UPLINK_KINDS}")
    return schema


def uplink_byte_breakdown(channel, schema: "tuple[UplinkSpec, ...]",
                          params: Pytree) -> "dict[str, float]":
    """Per-UplinkSpec wire bytes for one round of ``schema`` under ``channel``.

    ``{tag: bytes}`` in round order — each spec charged its codec-exact
    per-client uplink bytes at its kind's rate, exactly the terms
    ``comm_bytes_per_round`` (core/algorithms.py) sums into its total. This
    is the byte attribution the telemetry header row publishes (repro/obs):
    host-side and static per run, so it costs the compiled round nothing.
    """
    validate_schema(schema)
    return {spec.tag: float(channel.uplink_bytes(params, kind=spec.kind))
            for spec in schema}


def init_schema_state(channel, schema: "tuple[UplinkSpec, ...]",
                      params: Pytree, K: int) -> "Pytree | None":
    """Allocate the per-client comm buffers ``schema`` needs under ``channel``.

    Returns ``{tag: {"ef": [K,...] zeros, "ref": [K,...] zeros}}`` with only
    the buffers :meth:`CommChannel.state_buffers` says each uplink carries —
    tags that carry none are omitted entirely, and the whole state is None
    when no uplink carries any (lossless channels stay zero-overhead).
    """
    validate_schema(schema)
    stacked_zeros = lambda: jax.tree.map(
        lambda z: jnp.zeros((K,) + z.shape, z.dtype), params)
    state = {}
    for spec in schema:
        buffers = channel.state_buffers(spec)
        if buffers:
            state[spec.tag] = {b: stacked_zeros() for b in buffers}
    return state or None


__all__ = [
    "CTRL_UPLINK",
    "DELTA_UPLINK",
    "DIR_UPLINK",
    "GRAD_UPLINK",
    "UPLINK_KINDS",
    "UplinkSpec",
    "init_schema_state",
    "uplink_byte_breakdown",
    "validate_schema",
]
