"""PartitionSpec generation for every model/optimizer/cache pytree.

Two parameter regimes (DESIGN.md §6):

* replica  — params TP-sharded over "model", replicated over data/pod axes.
  FL semantics: every client group holds a full (tensor-sharded) replica, so
  per-client divergent local models are representable.
* fsdp     — additionally shards the non-TP dim of every ≥2D weight over
  "data" (ZeRO/FSDP style, gathered per-layer inside the scan). Used for the
  archs whose replica-regime working set exceeds HBM (internvl2-76b,
  llama4-scout); there the FL runtime time-multiplexes clients over the whole
  mesh (sequential-client cross-silo execution).

Specs are derived from leaf PATHS (naming conventions in models/layers.py) —
one place to audit the entire sharding story.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import Sharder, default_axes

Pytree = Any

# archs whose train working set (params+grads+correction, bf16) exceeds a
# single v5e's HBM share under pure TP — see DESIGN.md memory math
FSDP_ARCHS = ("internvl2-76b", "llama4-scout-17b-a16e")


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    cfg: ArchConfig
    mesh: Any
    multi_pod: bool
    regime: str                  # "replica" | "fsdp"
    axes: dict

    @property
    def model_shards(self) -> int:
        return self.mesh.shape["model"]

    def sharder(self) -> Sharder:
        return Sharder(mesh=self.mesh, axes=self.axes)


def make_plan(cfg: ArchConfig, mesh, multi_pod: bool = False,
              regime: str | None = None) -> ShardingPlan:
    shards = mesh.shape["model"]
    cfg = cfg.padded(shards)
    axes = default_axes(multi_pod)
    # divisibility overrides
    if cfg.num_heads and cfg.eff_kv_heads % shards != 0:
        axes["kv_heads"] = None                       # MQA: replicate kv
    if cfg.num_experts:
        if cfg.eff_experts % shards == 0:
            axes["experts"], axes["expert_ff"] = "model", None
        else:
            axes["experts"], axes["expert_ff"] = None, "model"
    if cfg.family in ("ssm", "hybrid") and cfg.d_inner % shards != 0:
        axes["ssm_inner"] = None
    regime = regime or ("fsdp" if cfg.name in FSDP_ARCHS else "replica")
    return ShardingPlan(cfg=cfg, mesh=mesh, multi_pod=multi_pod,
                        regime=regime, axes=axes)


# ---------------------------------------------------------------------------
# param specs by leaf path
# ---------------------------------------------------------------------------

def _fsdp_axis(plan: ShardingPlan):
    if plan.regime != "fsdp":
        return None
    return ("pod", "data") if plan.multi_pod else "data"


def param_spec_for_path(path: str, ndim: int, plan: ShardingPlan) -> P:
    """path: '/'-joined dict keys, e.g. 'blocks/attn/wq'."""
    ax = plan.axes
    fa = _fsdp_axis(plan)
    name = path.split("/")[-1]
    stacked = path.startswith(("blocks", "mamba_groups", "mamba_tail"))
    L = (None,) if stacked else ()

    def spec(*dims):
        return P(*L, *dims)

    # --- embeddings / head ---
    if name == "embed":
        return P(ax["vocab"], fa)
    if name == "lm_head":
        return P(fa, ax["vocab"])
    # --- norms & small vectors: replicated ---
    if name in ("final_norm", "attn_norm", "mlp_norm", "norm", "q_norm",
                "k_norm", "A_log", "D", "dt_bias", "conv_x_b", "conv_bc_b",
                "conv_bc_w"):
        return spec(*([None] * (ndim - len(L))))
    # --- attention ---
    if name == "wq":
        return spec(fa, ax["heads"])
    if name in ("wk", "wv"):
        return spec(fa, ax["kv_heads"])
    if name == "wo" and "attn" in path:
        return spec(ax["heads"], fa)
    # --- dense mlp ---
    if name in ("wi_gate", "wi_up") and "moe" not in path:
        return spec(fa, ax["d_ff"])
    if name == "wo" and "mlp" in path:
        return spec(ax["d_ff"], fa)
    # --- moe ---
    if name == "router":
        return spec(fa, None)
    if name in ("wi_gate", "wi_up") and "moe" in path:
        return spec(ax["experts"], fa, ax["expert_ff"])
    if name == "wo" and "moe" in path:
        return spec(ax["experts"], ax["expert_ff"], fa)
    # --- mamba ---
    if name in ("wx", "wz"):
        return spec(fa, ax["ssm_inner"])
    if name in ("wB", "wC", "wdt"):
        return spec(fa, None)
    if name == "conv_x_w":
        return spec(None, ax["ssm_inner"])
    if name == "out_proj":
        return spec(ax["ssm_inner"], fa)
    if name == "norm":
        return spec(None)
    raise ValueError(f"no sharding rule for param path {path!r} (ndim={ndim})")


def tree_specs(tree: Pytree, plan: ShardingPlan, spec_fn) -> Pytree:
    """Map spec_fn(path_str, ndim) over a pytree of ShapeDtypeStruct/arrays."""
    def visit(kp, leaf):
        path = "/".join(
            k.key if hasattr(k, "key") else str(k) for k in kp
        )
        return spec_fn(path, getattr(leaf, "ndim", len(leaf.shape)))
    return jax.tree_util.tree_map_with_path(visit, tree)


def param_specs(params_shape: Pytree, plan: ShardingPlan) -> Pytree:
    return tree_specs(params_shape, plan,
                      lambda p, nd: param_spec_for_path(p, nd, plan))


# ---------------------------------------------------------------------------
# data / cache specs
# ---------------------------------------------------------------------------

def batch_axis(plan: ShardingPlan, batch_size: int):
    """Shard the batch over as many of (pod, data) as divide it; B=1 decodes
    are model-parallel-only (reported in the roofline)."""
    pod = plan.mesh.shape.get("pod", 1) if plan.multi_pod else 1
    data = plan.mesh.shape["data"]
    if plan.multi_pod and batch_size % (pod * data) == 0:
        return ("pod", "data")
    if batch_size % data == 0:
        return "data"
    return None


def batch_specs(batch_shape: Pytree, plan: ShardingPlan, batch_size: int) -> Pytree:
    ba = batch_axis(plan, batch_size)

    def visit(path, leaf):
        nd = len(leaf.shape)
        return P(ba, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(visit, batch_shape)


def cache_spec_for_path(path: str, ndim: int, plan: ShardingPlan,
                        batch_size: int) -> P:
    ax, ba = plan.axes, batch_axis(plan, batch_size)
    name = path.split("/")[-1]
    # all cache leaves are layer-stacked: leading L axis
    if name in ("k", "v", "k_scale", "v_scale"):   # [L, B, C, KV, hd|1]
        return P(None, ba, None, ax["kv_heads"], None)
    if name == "pos":             # [L, B, C]
        return P(None, ba, None)
    if name == "idx":             # [L]
        return P(None)
    if name == "conv":            # [L, B, W-1, conv_dim]
        return P(None, ba, None, None)
    if name == "ssm":             # [L, B, nh, hd, st]
        return P(None, ba, ax["ssm_inner"] if plan.cfg.ssm_heads % plan.model_shards == 0 else None, None, None)
    raise ValueError(f"no cache rule for {path!r}")


def cache_specs(cache_shape: Pytree, plan: ShardingPlan, batch_size: int) -> Pytree:
    return tree_specs(cache_shape, plan,
                      lambda p, nd: cache_spec_for_path(p, nd, plan, batch_size))
