"""Filesystem fault injection for the checkpoint save path (the robustness
layer's storage half).

The same discipline as faults.py, applied to I/O instead of the wire: a
declarative :class:`FSFaultPlan` names which storage failures to inject, and
the realization is DETERMINISTIC — every injected event is keyed by
``(seed, step)`` where ``step`` is the plan's monotonically increasing write
counter, so a given plan replays bit-identically across runs. No wall-clock,
no global randomness.

Fault kinds
-----------
* **torn writes** (``torn_write_rate``) — the write persists only a prefix
  of its bytes (the draw also picks the cut point), then reports an I/O
  error: what a power cut mid-``write(2)`` leaves behind. The atomic-commit
  protocol must make such a file unobservable under its final name.
* **ENOSPC** (``enospc_writes``) — the named write steps fail with
  ``OSError(ENOSPC)`` on every attempt (retries included): a full disk is
  not transient. The save must degrade gracefully — failure counted,
  alarmed, next save clean.
* **transient errors** (``flaky_writes``) — the named write steps fail ONCE
  with ``EIO`` and succeed on retry: what the exponential-backoff retry in
  checkpoint/atomic.py exists for.
* **kill** (``kill_at_save``) — the N-th checkpoint save dies between
  save-start and manifest commit: after ``kill_after_writes`` staged writes
  the process "dies" — :class:`SimulatedKill` is raised (in-process tests;
  the save manager treats it as death: nothing further is written, the temp
  directory stays torn), or with ``kill_hard=True`` the PROCESS exits
  immediately via ``os._exit`` (the subprocess kill-resume smoke). Either
  way the commit rename never happens, so discovery must fall back to the
  newest complete checkpoint.

``FaultyFs`` wraps any :class:`repro.checkpoint.atomic.LocalFs`; everything
it does not perturb delegates to the wrapped instance.
"""
from __future__ import annotations

import dataclasses
import errno
import hashlib
import os

from repro.checkpoint.atomic import LocalFs

#: exit code the hard-kill path dies with — distinguishable from a python
#: traceback (1) and from SIGKILL (137) in the kill-resume smoke
KILL_EXIT_CODE = 43


class SimulatedKill(BaseException):
    """Process death injected between save-start and commit. Derives from
    BaseException so no ``except Exception`` recovery path can swallow it —
    exactly like a real SIGKILL, the save it interrupts simply never
    finishes."""


@dataclasses.dataclass(frozen=True)
class FSFaultPlan:
    """Declarative, replayable storage-failure schedule.

    ``torn_write_rate`` draws per write step; ``enospc_writes`` /
    ``flaky_writes`` name explicit write-step indices (0-based, counted over
    every ``write_bytes`` the wrapped fs sees); ``kill_at_save`` counts
    checkpoint SAVES (1-based, advanced by the save manager via
    ``on_save_start``) and ``kill_after_writes`` positions the death inside
    that save's write sequence.
    """

    seed: int = 0
    torn_write_rate: float = 0.0
    enospc_writes: "tuple[int, ...]" = ()
    flaky_writes: "tuple[int, ...]" = ()
    kill_at_save: int = 0       # 0 = never
    kill_after_writes: int = 1  # die after this many writes of that save
    kill_hard: bool = False     # os._exit instead of SimulatedKill

    def __post_init__(self):
        if not 0.0 <= self.torn_write_rate <= 1.0:
            raise ValueError("torn_write_rate must be in [0, 1], got "
                             f"{self.torn_write_rate}")
        if self.kill_at_save < 0 or self.kill_after_writes < 0:
            raise ValueError("kill_at_save / kill_after_writes must be >= 0")


def _draw(seed: int, step: int, salt: str) -> float:
    """Uniform [0,1) keyed by (seed, step, salt) — hash-based, so the stream
    is identical across processes and runs (no RNG object state)."""
    h = hashlib.sha256(f"{seed}:{step}:{salt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


class FaultyFs(LocalFs):
    """A ``LocalFs`` view with the plan's storage faults applied.

    Write steps are counted over the whole lifetime of the instance; the
    save manager calls :meth:`on_save_start` so save-scoped faults (the
    kill) know which save is in flight.
    """

    def __init__(self, plan: FSFaultPlan, inner: LocalFs | None = None):
        self.plan = plan
        self.inner = inner or LocalFs()
        self.write_step = 0
        self.save_index = 0           # 1-based once a save starts
        self._save_writes = 0
        self._flaked: set[int] = set()

    # -- save lifecycle (called by the checkpoint manager) ----------------
    def on_save_start(self) -> None:
        self.save_index += 1
        self._save_writes = 0

    def _maybe_kill(self) -> None:
        p = self.plan
        if p.kill_at_save and self.save_index == p.kill_at_save \
                and self._save_writes >= p.kill_after_writes:
            if p.kill_hard:
                os._exit(KILL_EXIT_CODE)
            raise SimulatedKill(
                f"injected kill at save {self.save_index} after "
                f"{self._save_writes} writes")

    # -- faulted primitives ----------------------------------------------
    def write_bytes(self, path: str, data: bytes) -> None:
        step = self.write_step
        self.write_step += 1
        self._save_writes += 1
        p = self.plan
        if step in p.enospc_writes:
            raise OSError(errno.ENOSPC, "injected ENOSPC", path)
        if step in p.flaky_writes and step not in self._flaked:
            self._flaked.add(step)
            raise OSError(errno.EIO, "injected transient EIO", path)
        if p.torn_write_rate > 0.0 \
                and _draw(p.seed, step, "torn") < p.torn_write_rate:
            cut = int(_draw(p.seed, step, "cut") * len(data))
            self.inner.write_bytes(path, data[:cut])
            raise OSError(errno.EIO, "injected torn write", path)
        self.inner.write_bytes(path, data)
        self._maybe_kill()

    def replace(self, src: str, dst: str) -> None:
        self._maybe_kill()  # death between last shard write and the rename
        self.inner.replace(src, dst)

    # -- clean delegations ------------------------------------------------
    def read_bytes(self, path: str) -> bytes:
        return self.inner.read_bytes(path)

    def makedirs(self, path: str) -> None:
        self.inner.makedirs(path)

    def listdir(self, path: str) -> "list[str]":
        return self.inner.listdir(path)

    def exists(self, path: str) -> bool:
        return self.inner.exists(path)

    def rmtree(self, path: str) -> None:
        self.inner.rmtree(path)

    def fsync_dir(self, path: str) -> None:
        self.inner.fsync_dir(path)


__all__ = ["KILL_EXIT_CODE", "FSFaultPlan", "FaultyFs", "SimulatedKill"]
