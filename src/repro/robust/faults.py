"""Fault injection for the federated round (the robustness layer's attack half).

A ``FaultPlan`` declares, per run, which adversarial conditions the compiled
round body must inject; ``realize`` draws the plan's per-round, per-client
realization from a counter-keyed PRNG stream inside jit, so the same plan
seed reproduces bit-identical injected rounds across runs AND across the
vmap/sharded runtimes (the draws are keyed by GLOBAL client id and round
index only — never by cohort position or shard layout).

Fault kinds
-----------
* **dropout** (``drop_rate``) — the client computes its full round but the
  uplink never lands: its aggregation weight is zeroed (survivors renormalize)
  and every per-client state row it would have written (AA history, control
  variates, codec EF/ref buffers, the stale anchor below) is bit-frozen at its
  pre-round value. Distinct from a never-sampled cohort row: the dropped
  client burns the compute and its rng draws advance; only the landing is
  suppressed.
* **staleness** (``stale_rate``) — the client uploads a delta computed against
  an aged anchor ``w^{t-s}`` instead of the round's ``w^t``. Each client
  carries a cached anchor row (under :data:`FAULT_ANCHOR_KEY` in the comm
  state, so it rides the cohort gather/scatter and checkpoints for free);
  a stale draw keeps the cache aged — consecutive draws compound s — and a
  fresh draw refreshes it to the current ``w^t``.
* **byzantine** (``byz_clients`` lowest-id clients, ``byz_mode``):
  ``"sign_flip"`` uploads ``−byz_scale·v``; ``"noise"`` replaces the upload
  with a random direction scaled to ``byz_scale·‖v‖``; ``"history"`` corrupts
  the client's recorded last AA history column post-trajectory (the
  poisoned-Gram-column attack the ``AAConfig.clip_rtol`` screen defends —
  uplink modes poison the *aggregate*, which no per-client defense can undo).
* **DP noise** (``dp_sigma``) — client-side Gaussian noise composed AFTER the
  codec's encode (via ``CrossClientReduce.uplink(post_codec=...)``), so
  error-feedback residuals and difference-coding references track the noised
  wire rather than silently eating the noise.
* **latency** (``latency_scale`` > 0) — per-round, per-client compute-time
  draws from a heavy-tailed ``latency_dist`` ("lognormal": ``scale ·
  exp(shape·N(0,1))``; "pareto": ``scale · U^{-1/shape}``). Pure simulation
  data: the draw alone perturbs nothing — it feeds the deadline gate in
  :mod:`repro.robust.async_agg`, which decides which clients' uplinks land
  this round and which enter the staleness buffer.

``FaultyReduce`` wraps a runtime's ``CrossClientReduce``/``ShardReduce`` and
applies the uplink-level faults; the weight/freeze/anchor plumbing lives in
the round builders (core/algorithms.py, core/sharded.py) at jit level outside
any shard_map so both runtimes share it verbatim.

Scope note: the history-poison fault targets the AA mechanism and is threaded
through the SVRG family (the paper's headline algorithms); every other fault
kind applies to all algorithm families.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_math as tm

Pytree = Any

BYZ_MODES = ("sign_flip", "noise", "history")

LATENCY_DISTS = ("lognormal", "pareto")

#: reserved tag for the per-client [K, ...] lagged-anchor rows in the comm
#: state dict (codec tags are short names like "grad"/"delta" and
#: comm/schema.py rejects duplicates, so the dunder name cannot collide)
FAULT_ANCHOR_KEY = "__fault_anchor__"


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative, jit-compatible fault schedule for a federated run.

    seed keys the entire injection stream: two runs with equal plans produce
    bit-identical injected rounds. Rates are per-round independent Bernoulli
    draws per client; byzantine clients are the fixed ``byz_clients``
    lowest-id clients (persistent attackers, the standard threat model).
    """

    seed: int = 0
    drop_rate: float = 0.0
    stale_rate: float = 0.0
    byz_clients: int = 0
    byz_mode: str = "sign_flip"
    byz_scale: float = 10.0
    dp_sigma: float = 0.0
    latency_dist: str = "lognormal"
    latency_scale: float = 0.0  # 0 = no latency simulation
    latency_shape: float = 1.0  # lognormal sigma / pareto tail index

    def __post_init__(self):
        if self.byz_mode not in BYZ_MODES:
            raise ValueError(
                f"unknown byz_mode {self.byz_mode!r}; choose from {BYZ_MODES}")
        if self.latency_dist not in LATENCY_DISTS:
            raise ValueError(f"unknown latency_dist {self.latency_dist!r}; "
                             f"choose from {LATENCY_DISTS}")
        for name in ("drop_rate", "stale_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.byz_clients < 0:
            raise ValueError(f"byz_clients must be >= 0, got {self.byz_clients}")
        if self.dp_sigma < 0.0:
            raise ValueError(f"dp_sigma must be >= 0, got {self.dp_sigma}")
        if self.latency_scale < 0.0:
            raise ValueError(
                f"latency_scale must be >= 0, got {self.latency_scale}")
        if self.latency_shape <= 0.0:
            raise ValueError(
                f"latency_shape must be > 0, got {self.latency_shape}")

    @property
    def active(self) -> bool:
        """False = the plan is a no-op and the builders compile the exact
        fault-free graph (python-gated: no dead fault code in the jit)."""
        return (self.drop_rate > 0.0 or self.stale_rate > 0.0
                or self.byz_clients > 0 or self.dp_sigma > 0.0
                or self.latency_scale > 0.0)

    @property
    def simulates_latency(self) -> bool:
        return self.latency_scale > 0.0

    @property
    def poisons_history(self) -> bool:
        return self.byz_clients > 0 and self.byz_mode == "history"

    @property
    def perturbs_uplink(self) -> bool:
        return self.byz_clients > 0 and self.byz_mode != "history"


class FaultRealization(NamedTuple):
    """One round's realized faults for the C cohort clients (all [C])."""

    drop: jax.Array     # bool — uplink never lands
    stale: jax.Array    # bool — delta re-based on the aged anchor
    byz: jax.Array      # bool — client is byzantine this round
    keys: jax.Array     # per-client fault PRNG keys (noise draws)
    latency: jax.Array  # float — simulated compute time (0 when not modeled)


def realize(plan: FaultPlan, t: jax.Array, num_clients: int,
            idx: jax.Array | None = None) -> FaultRealization:
    """Draw round ``t``'s [C] fault realization inside jit.

    All draws are taken over the full K-client population keyed by
    ``fold_in(PRNGKey(plan.seed), t)`` and then gathered by the cohort's
    global client ids (``idx``; None = dense identity cohort), so a client's
    fault fate this round is independent of whether/where it was sampled —
    the property that makes the vmap and sharded runtimes (and repeated runs)
    inject identical rounds.
    """
    round_key = jax.random.fold_in(jax.random.PRNGKey(plan.seed), t)
    ids = jnp.arange(num_clients) if idx is None else idx
    drop_k = jax.random.uniform(
        jax.random.fold_in(round_key, 1), (num_clients,)) < plan.drop_rate
    stale_k = jax.random.uniform(
        jax.random.fold_in(round_key, 2), (num_clients,)) < plan.stale_rate
    per_client = jax.vmap(
        lambda i: jax.random.fold_in(jax.random.fold_in(round_key, 3), i))
    if plan.latency_scale > 0.0:
        lat_key = jax.random.fold_in(round_key, 4)
        if plan.latency_dist == "lognormal":
            lat_k = plan.latency_scale * jnp.exp(
                plan.latency_shape
                * jax.random.normal(lat_key, (num_clients,)))
        else:  # "pareto"
            u = jax.random.uniform(lat_key, (num_clients,),
                                   minval=jnp.finfo(jnp.float32).tiny)
            lat_k = plan.latency_scale * u ** (-1.0 / plan.latency_shape)
    else:
        lat_k = jnp.zeros((num_clients,), jnp.float32)
    return FaultRealization(
        drop=drop_k[ids],
        stale=stale_k[ids],
        byz=ids < plan.byz_clients,
        keys=per_client(ids),
        latency=lat_k[ids],
    )


def _bc(flags: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast a [C] flag vector against a [C, ...] leaf."""
    return flags.reshape(flags.shape + (1,) * (like.ndim - 1))


# -- dropout ----------------------------------------------------------------

def drop_weights(drop: jax.Array, weights: jax.Array) -> jax.Array:
    """Zero dropped clients' aggregation weights and renormalize over the
    survivors. An all-dropped round yields all-zero weights — the delta-form
    aggregation then keeps w^t exactly (no update lands)."""
    w = jnp.where(drop, 0.0, weights)
    return w / jnp.maximum(jnp.sum(w), 1e-30)


def freeze_dropped(drop: jax.Array, cohort, updates: dict) -> dict:
    """Bit-freeze dropped clients' per-client state rows.

    ``updates`` maps ClientStateStore field names (c_k / hist_s / hist_y /
    comm) to this round's new [C, ...] rows; every leaf row of a dropped
    client reverts to its pre-round value from ``cohort`` — the client
    computed, but nothing it produced (AA history, control variate, codec
    buffers, stale anchor) lands anywhere. Conservative whole-row semantics:
    this is exactly the frozen-row contract tests/test_cohort.py pins for
    never-sampled clients, applied to sampled-but-dropped ones.
    """
    frozen = {}
    for name, new in updates.items():
        if new is None:
            frozen[name] = None
            continue
        old = getattr(cohort, name)
        frozen[name] = jax.tree.map(
            lambda o, n: jnp.where(_bc(drop, n), o, n), old, new)
    return frozen


# -- staleness --------------------------------------------------------------

def init_fault_comm(comm: dict | None, params: Pytree,
                    num_clients: int) -> dict:
    """Attach the per-client lagged-anchor rows (all clients start at w0)."""
    anchor = jax.tree.map(
        lambda p: jnp.broadcast_to(p, (num_clients,) + p.shape), params)
    return {**(comm or {}), FAULT_ANCHOR_KEY: anchor}


def advance_anchor(comm: dict, stale: jax.Array, w_t: Pytree) -> dict:
    """Post-round anchor refresh: fresh clients re-anchor on this round's
    w^t; clients drawn stale keep their aged copy, so staleness s compounds
    across consecutive stale draws (s = the run length of the draw)."""
    anchor = comm[FAULT_ANCHOR_KEY]
    new = jax.tree.map(
        lambda a, w: jnp.where(_bc(stale, a), a, jnp.broadcast_to(w, a.shape)),
        anchor, w_t)
    return {**comm, FAULT_ANCHOR_KEY: new}


# -- byzantine --------------------------------------------------------------

def poison_last_column(y_stack: Pytree, flag: jax.Array, key: jax.Array,
                       scale: float) -> Pytree:
    """byz_mode="history": corrupt ONE client's last recorded AA residual
    column, scaled to ``scale·‖y_0‖`` (relative to the client's own first
    column so the attack is magnitude-calibrated per client). flag=False adds
    exactly 0.0 — honest clients' history is numerically untouched."""
    y_last = jax.tree.map(lambda c: c[-1], y_stack)
    noise = tm.tree_random_like(key, y_last)
    nn = jnp.maximum(tm.tree_norm(noise), 1e-30)
    ref = jnp.maximum(tm.tree_norm(jax.tree.map(lambda c: c[0], y_stack)),
                      1e-30)
    mag = jnp.where(flag, scale * ref / nn, 0.0)
    return jax.tree.map(
        lambda c, n: c.at[-1].add(mag * n.astype(c.dtype)), y_stack, noise)


# -- the faulty wire --------------------------------------------------------

class FaultyReduce:
    """A ``CrossClientReduce`` view with the round's uplink faults applied.

    Wraps the runtime's reduce (vmap or sharded — every op it injects is
    per-client row-local, so it composes with shard_map bodies) and perturbs
    ``uplink`` only; reductions, broadcast and wire accounting delegate to
    the wrapped instance. Fault order on the wire: byzantine perturbation →
    stale re-basing → codec encode/decode → DP noise (post-codec, so EF sees
    the noised stream).
    """

    def __init__(self, inner, plan: FaultPlan, fr: FaultRealization,
                 anchor_rows: Pytree | None = None):
        self.inner = inner
        self.plan = plan
        self.fr = fr
        self.anchor_rows = anchor_rows  # [C, ...] lagged anchors (stale mode)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def uplink(self, stacked, rngs, spec, anchor=None, state=None, **kw):
        plan, fr = self.plan, self.fr
        fkeys = jax.vmap(
            lambda k: jax.random.fold_in(k, spec.fold))(fr.keys)
        if plan.perturbs_uplink:
            stacked = _byz_uplink(plan, fr.byz, fkeys, stacked, anchor)
        if plan.stale_rate > 0.0 and anchor is not None \
                and self.anchor_rows is not None:
            # the stale client computed its delta against its aged anchor;
            # the server re-bases every delta on the current w^t, so the
            # landed value picks up the anchor drift (w^t − w^{t-s})
            stacked = jax.tree.map(
                lambda s, a, w: jnp.where(
                    _bc(fr.stale, s), s + (w - a), s),
                stacked, self.anchor_rows, anchor)
        post = None
        post_rngs = None
        if plan.dp_sigma > 0.0:
            sigma = plan.dp_sigma

            def post(dec, pr):
                return tm.tree_add(dec, tm.tree_random_like(pr, dec,
                                                            scale=sigma))
            post_rngs = jax.vmap(
                lambda k: jax.random.fold_in(k, 7))(fkeys)
        return self.inner.uplink(stacked, rngs, spec, anchor=anchor,
                                 state=state, post_codec=post,
                                 post_rngs=post_rngs, **kw)


def _byz_uplink(plan: FaultPlan, byz: jax.Array, keys: jax.Array,
                stacked: Pytree, anchor: Pytree | None) -> Pytree:
    """Uplink-value byzantine perturbation (sign_flip / noise), applied to
    the wire quantity (the delta for anchored specs). Honest clients' rows
    are selected through bit-untouched."""
    if anchor is None:
        v = stacked
    else:
        v = jax.tree.map(lambda s, w: s - w, stacked, anchor)
    if plan.byz_mode == "sign_flip":
        pert = jax.tree.map(lambda x: -plan.byz_scale * x, v)
    else:  # "noise"

        def one(key, row):
            n = tm.tree_random_like(key, row)
            nn = jnp.maximum(tm.tree_norm(n), 1e-30)
            vn = tm.tree_norm(row)
            return jax.tree.map(
                lambda e: (plan.byz_scale * vn / nn) * e, n)

        pert = jax.vmap(one)(keys, v)
    if anchor is not None:
        pert = jax.tree.map(lambda p, w: p + w, pert, anchor)
    return jax.tree.map(
        lambda s, p: jnp.where(_bc(byz, s), p, s), stacked, pert)
