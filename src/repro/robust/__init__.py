"""Robustness layer: fault injection (faults.py) + the clip_rtol defense
(core/anderson.py) + the fault-matrix acceptance benchmark
(benchmarks/ext_robustness.py)."""
from repro.robust.faults import (  # noqa: F401
    BYZ_MODES,
    FAULT_ANCHOR_KEY,
    FaultPlan,
    FaultRealization,
    FaultyReduce,
    advance_anchor,
    drop_weights,
    freeze_dropped,
    init_fault_comm,
    poison_last_column,
    realize,
)
