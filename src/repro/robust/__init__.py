"""Robustness layer: fault injection (faults.py), deadline-gated buffered
aggregation (async_agg.py), the clip_rtol defense (core/anderson.py), and the
acceptance benchmarks (benchmarks/ext_robustness.py, benchmarks/ext_async.py)."""
from repro.robust.async_agg import (  # noqa: F401
    ASYNC_AGE_KEY,
    ASYNC_BUF_KEY,
    AsyncConfig,
    AsyncRealization,
    CaptureReduce,
    advance_buffer,
    async_round_stats,
    discounted_weights,
    fold_buffered,
    guard_history_rows,
    init_async_comm,
    plan_async,
)
from repro.robust.faults import (  # noqa: F401
    BYZ_MODES,
    FAULT_ANCHOR_KEY,
    LATENCY_DISTS,
    FaultPlan,
    FaultRealization,
    FaultyReduce,
    advance_anchor,
    drop_weights,
    freeze_dropped,
    init_fault_comm,
    poison_last_column,
    realize,
)
