"""Deadline-gated, staleness-discounted aggregation (the straggler half).

:mod:`repro.robust.faults` simulates per-client compute latency (the
``latency_*`` fields on ``FaultPlan``); this module decides what the server
does with it. An :class:`AsyncConfig` turns the barriered round into a
FedBuff-style deadline-gated one:

* a client whose simulated latency beats the (possibly extended) deadline
  lands **fresh** this round — its post-codec update enters the aggregation
  exactly as in the synchronous round;
* a late client keeps grinding: its post-codec update is parked in a
  per-client **buffer row** (under :data:`ASYNC_BUF_KEY` / :data:`ASYNC_AGE_KEY`
  in the comm state, so it rides the cohort gather/scatter and checkpoints for
  free, the ``FAULT_ANCHOR_KEY`` precedent) and **folds** into the first later
  round in which the client is sampled and on time, with weight discounted by
  its staleness ``s`` (rounds spent in the buffer) as ``(1+s)^-alpha``;
* a client still busy with a buffered round does not start fresh work — a
  sampled busy+late client just ages (``retain``).

Graceful degradation: if fewer than ``min_arrivals`` latencies beat
``deadline``, the deadline extends in-graph to the ``min_arrivals``-th order
statistic (the server waits for the fastest m — never a garbage step from an
empty quorum); a round with zero contributors produces all-zero weights, and
the delta-form aggregation then keeps ``w^t`` bit-exactly (the PR-2
``_participation_weights`` / drop-weights precedent).

Composition with dropout: ``drop`` models the *wire* failing, the deadline
models the *compute* being slow. A dropped on-time client contributes nothing
and buffers nothing (it finished; the upload vanished). A dropped fold means
the buffered delivery failed — the buffer row is retained and ages one more
round. A late client buffers client-side regardless of drop.

Staleness guard for AA: a busy client's recorded residual history this round
describes a trajectory that semantically never ran (the sim computes it, the
deadline says the client didn't finish it). With ``guard_history=True`` the
builders bit-freeze busy clients' ``hist_s``/``hist_y`` rows so stale folds
never enter the Gram solve as fresh secant columns; the alternative —
age-screening via ``AAConfig.clip_rtol`` — is measured against it in
``benchmarks/ext_async.py``.

Like ``FaultPlan``, everything here is python-gated: an inactive config
(``deadline == 0``) compiles the byte-identical synchronous graph.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.robust.faults import _bc

Pytree = Any

#: reserved comm-state tags for the per-client [K, ...] buffered post-codec
#: deltas and their [K] int32 ages (0 = empty; dunder names cannot collide
#: with codec tags, which comm/schema.py restricts to short identifiers)
ASYNC_BUF_KEY = "__async_buf__"
ASYNC_AGE_KEY = "__async_age__"


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Declarative deadline gate for the federated round.

    deadline        simulated-time budget per round; 0 disables the gate
                    entirely (synchronous barriered round, byte-identical
                    graph).
    min_arrivals    extend the deadline in-graph to the m-th latency order
                    statistic whenever fewer than m clients beat it (m is
                    clamped to the cohort size). Note: extension looks at
                    latency only — a simultaneously dropped client still
                    counts toward the quorum it extends for, because the
                    server cannot see wire faults ahead of time.
    staleness_alpha discount exponent: a fold aged s rounds contributes with
                    base weight scaled by ``(1+s)^-alpha``.
    guard_history   bit-freeze busy clients' AA history rows (see module
                    docstring); False leaves the history writes untouched so
                    ``clip_rtol`` age-screening can be measured against it.
    """

    deadline: float = 0.0
    min_arrivals: int = 0
    staleness_alpha: float = 0.5
    guard_history: bool = True

    def __post_init__(self):
        if self.deadline < 0.0:
            raise ValueError(f"deadline must be >= 0, got {self.deadline}")
        if self.min_arrivals < 0:
            raise ValueError(
                f"min_arrivals must be >= 0, got {self.min_arrivals}")
        if self.staleness_alpha < 0.0:
            raise ValueError(
                f"staleness_alpha must be >= 0, got {self.staleness_alpha}")

    @property
    def active(self) -> bool:
        """False = synchronous round; the builders compile the exact
        barriered graph (python-gated, the ``FaultPlan.active`` contract)."""
        return self.deadline > 0.0


class AsyncRealization(NamedTuple):
    """One round's deadline-gate partition for the C cohort clients.

    The five masks are disjoint by construction except ``contribute``
    (= fresh | fold); every [C] client falls in exactly one of
    {fresh, fold, defer, retain, idle} where idle = on-time-but-dropped
    with an empty buffer.
    """

    contribute: jax.Array     # bool — lands this round (fresh or fold)
    fresh: jax.Array          # bool — on time, buffer empty: update lands now
    fold: jax.Array           # bool — on time, buffer full: buffered delta lands
    defer: jax.Array          # bool — late, buffer empty: fresh delta buffers
    retain: jax.Array         # bool — busy and not folding: buffer ages
    staleness: jax.Array      # float — age of what landed (0 for fresh rows)
    weights: jax.Array        # discounted renormalized aggregation weights
    fresh_weights: jax.Array  # weights · fresh (what the in-core wsum uses)
    fold_weights: jax.Array   # weights · fold (the jit-level buffer fold)
    deadline: jax.Array       # scalar — effective deadline after extension


def discounted_weights(base: jax.Array, contribute: jax.Array,
                       staleness: jax.Array, alpha: float) -> jax.Array:
    """Staleness-discounted aggregation weights over the contributors.

    ``base`` is the round's participation weights (non-negative); each
    contributor's weight is scaled by ``(1+s)^-alpha`` and the result is
    renormalized over contributors. Zero contributors yield the all-zero
    vector — the delta-form no-op, never a divide-by-zero.
    """
    s = jnp.maximum(staleness.astype(base.dtype), 0.0)
    w = jnp.where(contribute, base * (1.0 + s) ** (-alpha), 0.0)
    return w / jnp.maximum(jnp.sum(w), 1e-30)


def plan_async(cfg: AsyncConfig, latency: jax.Array, age: jax.Array,
               pweight: jax.Array,
               drop: jax.Array | None = None) -> AsyncRealization:
    """Partition the cohort for one deadline-gated round (all [C] ops).

    ``latency`` is the realized per-client compute time (``FaultRealization
    .latency``), ``age`` the cohort's buffered-round ages (0 = empty buffer),
    ``pweight`` the base participation weights, ``drop`` the optional wire
    dropout mask. Pure function of its arguments — the host-side wall-clock
    replay in benchmarks/ext_async.py calls it with the same realized draws
    the compiled round saw.
    """
    lat = latency.astype(jnp.result_type(latency, jnp.float32))
    d_eff = jnp.asarray(cfg.deadline, lat.dtype)
    if cfg.min_arrivals > 0:
        m = min(int(cfg.min_arrivals), lat.shape[0])
        d_eff = jnp.maximum(d_eff, jnp.sort(lat)[m - 1])
    ontime = lat <= d_eff
    landed = ontime if drop is None else ontime & ~drop
    busy = age > 0
    fresh = landed & ~busy
    fold = landed & busy
    # defer keys off ontime, not landed: a late client buffers client-side
    # whether or not this round's wire would have dropped it
    defer = ~ontime & ~busy
    retain = busy & ~fold
    contribute = fresh | fold
    staleness = jnp.where(fold, age, 0).astype(pweight.dtype)
    w = discounted_weights(pweight, contribute, staleness,
                           cfg.staleness_alpha)
    return AsyncRealization(
        contribute=contribute, fresh=fresh, fold=fold, defer=defer,
        retain=retain, staleness=staleness, weights=w,
        fresh_weights=jnp.where(fresh, w, jnp.zeros_like(w)),
        fold_weights=jnp.where(fold, w, jnp.zeros_like(w)),
        deadline=d_eff,
    )


# -- carried buffer state ----------------------------------------------------

def init_async_comm(comm: dict | None, params: Pytree,
                    num_clients: int) -> dict:
    """Attach the [K, ...] zero buffer rows + [K] zero ages to the comm
    state (rides ClientStateStore gather/scatter and checkpoints for free)."""
    buf = jax.tree.map(
        lambda p: jnp.zeros((num_clients,) + p.shape, p.dtype), params)
    age = jnp.zeros((num_clients,), jnp.int32)
    return {**(comm or {}), ASYNC_BUF_KEY: buf, ASYNC_AGE_KEY: age}


def fold_buffered(params: Pytree, fold_weights: jax.Array,
                  buf: Pytree) -> Pytree:
    """Add the staleness-discounted buffered deltas into the aggregated
    params: ``params + Σ_k w_k · buf_k``. All-zero fold weights add exactly
    0.0 — a no-fold round's params are numerically untouched."""
    return jax.tree.map(
        lambda p, b: p + jnp.tensordot(
            fold_weights.astype(b.dtype), b, axes=1).astype(p.dtype),
        params, buf)


def advance_buffer(ar: AsyncRealization, delta: Pytree, buf: Pytree,
                   age: jax.Array) -> tuple[Pytree, jax.Array]:
    """Post-round buffer transition for the cohort's [C, ...] rows.

    defer  → the client's fresh post-codec delta enters its buffer, age 1;
    retain → the buffered delta is kept, age + 1;
    else   → (fresh landed, fold delivered, or idle) the buffer empties.
    """
    new_buf = jax.tree.map(
        lambda d, b: jnp.where(
            _bc(ar.defer, b), d.astype(b.dtype),
            jnp.where(_bc(ar.retain, b), b, jnp.zeros_like(b))),
        delta, buf)
    new_age = jnp.where(ar.defer, 1,
                        jnp.where(ar.retain, age + 1, 0)).astype(age.dtype)
    return new_buf, new_age


def guard_history_rows(busy: jax.Array, cohort, updates: dict) -> dict:
    """Bit-freeze busy clients' AA history rows (``hist_s``/``hist_y``) at
    their pre-round values: the trajectory the sim computed for a client that
    did not finish must not enter the recorded residual history as fresh
    secant columns (the Gram solve amplifies anchor drift exactly like the
    PR-8 poisoned columns). Same whole-row mechanics as ``freeze_dropped``,
    restricted to the history fields."""
    out = dict(updates)
    for name in ("hist_s", "hist_y"):
        new = out.get(name)
        if new is None:
            continue
        old = getattr(cohort, name)
        out[name] = jax.tree.map(
            lambda o, n: jnp.where(_bc(busy, n), o, n), old, new)
    return out


def async_round_stats(ar: AsyncRealization) -> tuple[jax.Array, jax.Array,
                                                     jax.Array]:
    """(arrivals, staleness_mean, staleness_max) over the round's
    contributors, for RoundMetrics. A zero-contributor round reports
    arrivals=0 and NaN staleness (nothing landed to be stale)."""
    n = jnp.sum(ar.contribute)
    s = ar.staleness
    sm = jnp.where(n > 0,
                   jnp.sum(jnp.where(ar.contribute, s, 0.0))
                   / jnp.maximum(n, 1).astype(s.dtype),
                   jnp.nan)
    sx = jnp.where(n > 0,
                   jnp.max(jnp.where(ar.contribute, s, -jnp.inf)),
                   jnp.nan)
    return n.astype(jnp.float32), sm, sx


# -- the capturing wire ------------------------------------------------------

class CaptureReduce:
    """A reduce view that stashes the anchored model aggregation's post-codec
    stacked updates for the buffer write.

    Every delta-form round core makes exactly one *anchored* ``wsum`` call —
    the model aggregation of the decoded [C, ...] client params — so capturing
    that call's ``stacked`` argument hands the async epilogue the post-codec
    per-client updates without touching any core. Encode-at-send semantics: a
    deferred client encoded its update when it finished computing; only the
    delivery is late, so codec error-feedback (client-local) advances
    normally. Composes outside ``FaultyReduce`` (attribute access delegates
    down the chain) and inside shard_map bodies (the stash is the local
    shard's rows, returned as an extra body output).
    """

    def __init__(self, inner):
        self.inner = inner
        self.captured = None  # [C, ...] post-codec stacked model updates

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def wsum(self, weights, stacked, anchor=None):
        if anchor is not None:
            self.captured = stacked
        return self.inner.wsum(weights, stacked, anchor=anchor)
