"""Pytree-as-vector math.

FedOSAA's Anderson-acceleration step is linear algebra over the *flattened*
parameter vector, but flattening billion-parameter pytrees into one array
destroys sharding and wastes memory. Everything here operates leaf-wise so
that sharded pytrees stay sharded; reductions (dot products, norms) compile
to per-leaf reduces + a scalar psum under pjit.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def _sum_leaves(leaves: Sequence[jax.Array]) -> jax.Array:
    """Sum same-shape per-leaf reductions with one stacked jnp.sum.

    ``functools.reduce(jnp.add, leaves)`` builds an O(n_leaves)-deep chain of
    binary adds — at 70+-leaf transformer scale (configs/ registry) that is a
    long sequential dependency XLA cannot reassociate. Stacking into one
    [n_leaves, ...] array and reducing axis 0 gives a single balanced reduce.
    """
    if len(leaves) == 1:
        return leaves[0]
    return jnp.sum(jnp.stack(leaves), axis=0)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(alpha, a: Pytree) -> Pytree:
    return jax.tree.map(lambda x: alpha * x, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha * x + y, leaf-wise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    """<a, b> over all leaves, accumulated in f32."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return _sum_leaves(jax.tree.leaves(leaves))


def tree_vdot_stacked(stack: Pytree, v: Pytree) -> jax.Array:
    """Given a pytree whose leaves carry a leading history axis [m, ...] and a
    plain pytree v, return the length-m vector of dot products  stackᵀ v.

    Sharding note (§Perf, AA-step iteration): contraction uses tensordot over
    the original axes — NOT reshape-to-flat — so sharded leaves contract
    locally and only the [m] result is psum'd. A reshape across a sharded
    dim would force an all-gather of the whole stack (measured: 157 GiB per
    AA step at qwen3-4b scale).
    """
    def leaf(s, x):
        axes = list(range(1, s.ndim))
        return jnp.tensordot(
            s.astype(jnp.float32), x.astype(jnp.float32),
            axes=(axes, list(range(x.ndim))),
        )

    leaves = jax.tree.leaves(jax.tree.map(leaf, stack, v))
    return _sum_leaves(leaves)


def tree_gram(stack_a: Pytree, stack_b: Pytree) -> jax.Array:
    """[m, m] Gram matrix  AᵀB  between two stacked pytrees (leading axis m).
    Axis-preserving contraction — see tree_vdot_stacked sharding note."""
    def leaf(a, b):
        axes = list(range(1, a.ndim))
        return jnp.tensordot(
            a.astype(jnp.float32), b.astype(jnp.float32), axes=(axes, axes)
        )

    leaves = jax.tree.leaves(jax.tree.map(leaf, stack_a, stack_b))
    return _sum_leaves(leaves)


def tree_combine_stacked(stack: Pytree, coeff: jax.Array) -> Pytree:
    """Σ_i coeff[i] * stack[i]  — contraction of the history axis."""
    def leaf(s):
        s32 = s.astype(jnp.float32)
        return jnp.tensordot(coeff.astype(jnp.float32), s32, axes=1).astype(s.dtype)

    return jax.tree.map(leaf, stack)


def tree_norm(a: Pytree) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_stack(trees: Sequence[Pytree]) -> Pytree:
    """Stack a python list of pytrees into one pytree with leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack_index(stack: Pytree, i) -> Pytree:
    return jax.tree.map(lambda s: s[i], stack)


def tree_dynamic_update(stack: Pytree, i, value: Pytree) -> Pytree:
    """stack[i] = value (dynamic index), for scan-friendly history buffers."""
    return jax.tree.map(
        lambda s, v: jax.lax.dynamic_update_index_in_dim(s, v.astype(s.dtype), i, 0),
        stack,
        value,
    )


def tree_cast(a: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_size(a: Pytree) -> int:
    return sum(x.size for x in jax.tree.leaves(a))


def tree_where(pred, a: Pytree, b: Pytree) -> Pytree:
    """Leaf-wise select; leaves that are the SAME object in a and b pass
    through untouched (no select op). ServerState._replace preserves object
    identity of unchanged fields, so the engine's live/stop select never
    drags pass-through state — e.g. the frozen [K, ...] client store rows of
    a cohort round — into the compiled graph."""
    return jax.tree.map(lambda x, y: x if x is y else jnp.where(pred, x, y), a, b)


def tree_random_like(key: jax.Array, a: Pytree, scale: float = 1.0) -> Pytree:
    leaves, treedef = jax.tree.flatten(a)
    keys = jax.random.split(key, len(leaves))
    new = [
        jax.random.normal(k, x.shape, x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32) * scale
        for k, x in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, new)


def global_norm(a: Pytree) -> jax.Array:
    return tree_norm(a)


def tree_map_with_path_filter(
    fn: Callable, tree: Pytree, predicate: Callable[[tuple], bool]
) -> Pytree:
    """Apply fn only to leaves whose key-path satisfies predicate."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(leaf) if predicate(path) else leaf, tree
    )
