"""Version-tolerant shims for fast-moving jax APIs.

``shard_map`` has lived in three places across jax releases:

  * jax <= 0.4.x      — ``jax.experimental.shard_map.shard_map`` with a
                        ``check_rep`` kwarg;
  * jax >= 0.5/0.6    — promoted to top-level ``jax.shard_map``, with the
                        replication check renamed to ``check_vma``.

Every shard_map call site in this repo (models/layers.py expert-parallel MoE,
core/sharded.py distributed FL round) goes through this wrapper so version
drift is absorbed in exactly one place.
"""
from __future__ import annotations

from typing import Any, Callable

try:  # jax >= 0.5: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(
    f: Callable,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """Call jax's shard_map, normalizing the replication-check kwarg name.

    Accepts the new-API name (``check_vma``); older jax spells it
    ``check_rep``. Everything else is passed through unchanged.
    """
    try:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    except TypeError:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )
