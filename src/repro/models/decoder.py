"""Unified decoder model over all assigned families.

Layer stacks are scanned (``jax.lax.scan``) so HLO is depth-independent; the
hybrid (Zamba2) family uses a group-scan: scan over groups of
(period−1 mamba layers + one weight-TIED shared attention/MLP block).

API (all pure functions, built by ``build_model(cfg, sh)``):
  init(rng)                        -> params
  forward(params, tokens, embeds)  -> logits            (train/prefill path)
  loss(params, batch)              -> scalar
  prefill(params, tokens, embeds)  -> (logits_last, caches)
  decode_step(params, caches, tok, pos) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as Lyr
from repro.models.layers import Sharder

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    sh: Sharder
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_caches: Callable


# ---------------------------------------------------------------------------
# per-family layer bodies
# ---------------------------------------------------------------------------

def _dense_block(p, h, cfg, sh, positions, window, cache=None):
    a, new_cache = Lyr.attention(
        p["attn"], Lyr.rms_norm(h, p["attn_norm"]), cfg, sh, positions,
        cache=cache, window=window,
    )
    h = h + a
    h = h + Lyr.mlp(p["mlp"], Lyr.rms_norm(h, p["mlp_norm"]), sh)
    return h, new_cache


def _moe_block(p, h, cfg, sh, positions, window, cache=None):
    a, new_cache = Lyr.attention(
        p["attn"], Lyr.rms_norm(h, p["attn_norm"]), cfg, sh, positions,
        cache=cache, window=window,
    )
    h = h + a
    # decode (cache given) uses dropless routing: capacity dispatch is
    # non-causal, so drops would make decode diverge from teacher forcing
    # Under a mesh, the expert-parallel shard_map path is used (see Perf H1).
    moe_fn = Lyr.moe_sharded if sh.mesh is not None else Lyr.moe
    y, aux = moe_fn(p["moe"], Lyr.rms_norm(h, p["mlp_norm"]), cfg, sh,
                    dropless=cache is not None)
    return h + y, new_cache, aux


def _ssm_block(p, h, cfg, sh, state=None, ssd_fn=None):
    y, new_state = Lyr.mamba_forward(
        p["mixer"], Lyr.rms_norm(h, p["norm"]), cfg, sh, state=state, ssd_fn=ssd_fn
    )
    return h + y, new_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked_init(layer_init: Callable, rng: jax.Array, n: int) -> Pytree:
    return jax.vmap(layer_init)(jax.random.split(rng, n))


def _dense_layer_init(cfg, dtype):
    def one(rng):
        k1, k2 = jax.random.split(rng)
        return {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": Lyr.attn_init(k1, cfg, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp": Lyr.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    return one


def _moe_layer_init(cfg, dtype):
    def one(rng):
        k1, k2 = jax.random.split(rng)
        return {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": Lyr.attn_init(k1, cfg, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            "moe": Lyr.moe_init(k2, cfg, dtype),
        }
    return one


def _ssm_layer_init(cfg, dtype):
    def one(rng):
        return {
            "norm": jnp.ones((cfg.d_model,), dtype),
            "mixer": Lyr.mamba_init(rng, cfg, dtype),
        }
    return one


def _hybrid_counts(cfg):
    """Zamba2 pattern: every ``period``-th block is the shared attn block.
    total = num_layers; n_shared = L // period; mamba fills the rest."""
    p = cfg.shared_attn_period
    n_shared = cfg.num_layers // p
    n_mamba = cfg.num_layers - n_shared
    group = p - 1                       # mamba layers per group
    n_groups = n_shared
    trailing = n_mamba - n_groups * group
    assert trailing >= 0
    return n_groups, group, trailing


# ---------------------------------------------------------------------------
# model builder
# ---------------------------------------------------------------------------

def build_model(cfg: ArchConfig, sh: Sharder | None = None, ssd_fn=None,
                remat: bool = False) -> Model:
    cfg.validate()
    sh = sh or Sharder()
    dtype = jnp.dtype(cfg.dtype)
    V, d, L = cfg.eff_vocab, cfg.d_model, cfg.num_layers
    fam = cfg.family
    window = cfg.sliding_window

    # ------------------------------ init ------------------------------
    def init(rng: jax.Array) -> Pytree:
        ks = jax.random.split(rng, 4)
        params = {
            "embed": Lyr.dense_init(ks[0], (V, d), d, dtype),
            "final_norm": jnp.ones((d,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = Lyr.dense_init(ks[1], (d, V), d, dtype)
        if fam in ("dense", "vlm", "audio"):
            params["blocks"] = _stacked_init(_dense_layer_init(cfg, dtype), ks[2], L)
        elif fam == "moe":
            params["blocks"] = _stacked_init(_moe_layer_init(cfg, dtype), ks[2], L)
        elif fam == "ssm":
            params["blocks"] = _stacked_init(_ssm_layer_init(cfg, dtype), ks[2], L)
        else:  # hybrid
            n_groups, group, trailing = _hybrid_counts(cfg)
            k_m, k_t, k_s = jax.random.split(ks[2], 3)
            params["mamba_groups"] = _stacked_init(
                _ssm_layer_init(cfg, dtype), k_m, n_groups * group
            )
            if trailing:
                params["mamba_tail"] = _stacked_init(
                    _ssm_layer_init(cfg, dtype), k_t, trailing
                )
            params["shared"] = _dense_layer_init(cfg, dtype)(k_s)  # weight-tied
        return params

    # --------------------------- embedding ---------------------------
    def embed_tokens(params, tokens, embeds):
        h = params["embed"][tokens] * jnp.asarray(jnp.sqrt(d), dtype)
        if embeds is not None:
            # modality frontend stub: precomputed embeddings overwrite the
            # first `frontend_tokens` positions (vlm patches / audio frames)
            Pn = embeds.shape[1]
            h = jnp.concatenate([embeds.astype(h.dtype), h[:, Pn:]], axis=1)
        return sh(h, "batch", None, None)

    def unembed(params, h):
        h = Lyr.rms_norm(h, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = h @ head
        return sh(logits, "batch", None, "vocab")

    def unembed_last(params, h):
        """Logits for the LAST position only — prefill must never
        materialize [B, S, V] (a 76B/32k prefill would be 269 GB)."""
        return unembed(params, h[:, -1:])[:, -1]

    # --------------------------- forward ------------------------------
    # Remat policy (§Perf H1 iter 3 — REFUTED): dots_saveable measured WORSE
    # (memory term 0.31s -> 0.67s on granite-moe/train_4k): saving every dot
    # output streams more residual bytes through HBM than the elementwise
    # recompute it avoids. Full remat stays the default.
    _remat = jax.checkpoint

    def _scan_blocks(body, params_stack, h, *extra):
        def f(carry, xs):
            out = body(xs, carry, *extra)
            if isinstance(out, tuple):
                return out[0], out[2] if len(out) > 2 else None
            return out, None
        if remat:
            f = _remat(f)
        h, aux = jax.lax.scan(f, h, params_stack)
        return h, aux

    def forward_hidden(params, tokens, embeds=None):
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = embed_tokens(params, tokens, embeds)
        aux_total = jnp.zeros((), jnp.float32)

        if fam in ("dense", "vlm", "audio"):
            h, _ = _scan_blocks(
                lambda p, hh: _dense_block(p, hh, cfg, sh, positions, window)[0],
                params["blocks"], h,
            )
        elif fam == "moe":
            def body(carry, p):
                hh, aux = carry
                hh, _, a = _moe_block(p, hh, cfg, sh, positions, window)
                return (hh, aux + a), None
            bodyf = _remat(body) if remat else body
            (h, aux_total), _ = jax.lax.scan(bodyf, (h, aux_total), params["blocks"])
        elif fam == "ssm":
            h, _ = _scan_blocks(
                lambda p, hh: _ssm_block(p, hh, cfg, sh, ssd_fn=ssd_fn)[0],
                params["blocks"], h,
            )
        else:  # hybrid group scan
            n_groups, group, trailing = _hybrid_counts(cfg)
            gshape = jax.tree.map(
                lambda x: x.reshape((n_groups, group) + x.shape[1:]),
                params["mamba_groups"],
            )

            def group_body(hh, gp):
                hh, _ = _scan_blocks(
                    lambda p, inner_h: _ssm_block(p, inner_h, cfg, sh, ssd_fn=ssd_fn)[0],
                    gp, hh,
                )
                hh, _ = _dense_block(params["shared"], hh, cfg, sh, positions, window)
                return hh, None

            gb = _remat(group_body) if remat else group_body
            h, _ = jax.lax.scan(gb, h, gshape)
            if trailing:
                h, _ = _scan_blocks(
                    lambda p, hh: _ssm_block(p, hh, cfg, sh, ssd_fn=ssd_fn)[0],
                    params["mamba_tail"], h,
                )
        return h, aux_total

    def forward(params, tokens, embeds=None):
        h, aux = forward_hidden(params, tokens, embeds)
        return unembed(params, h), aux

    # ----------------------------- loss -------------------------------
    XENT_CHUNK = 512

    def _chunked_xent(params, h, tgt, mask):
        """PerfH3 iter 3: scan the unembed+softmax-xent over sequence
        chunks so the [B, S, V] logits never hit HBM (the lm head is the
        single largest activation for big-vocab archs); the chunk body is
        rematerialized, so backward recomputes chunk logits too."""
        B, Sm1, d_ = h.shape
        pad_mask = None
        if cfg.eff_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.eff_vocab) >= cfg.vocab_size
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

        c = min(XENT_CHUNK, Sm1)
        if Sm1 % c != 0:
            pad = c - Sm1 % c
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nchunk = h.shape[1] // c

        def body(carry, xs):
            hb, tb, mb = xs                      # [B, c, ...]
            lg = (hb @ head).astype(jnp.float32)
            lg = sh(lg, "batch", None, "vocab")
            if pad_mask is not None:
                lg = jnp.where(pad_mask[None, None, :], -1e30, lg)
            logp = jax.nn.log_softmax(lg, axis=-1)
            nll = -jnp.take_along_axis(logp, tb[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(nll * mb), None

        xs = (
            h.reshape(B, nchunk, c, d_).transpose(1, 0, 2, 3),
            tgt.reshape(B, nchunk, c).transpose(1, 0, 2),
            mask.reshape(B, nchunk, c).transpose(1, 0, 2),
        )
        total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), xs)
        return total

    def loss(params, batch: dict) -> jax.Array:
        """batch: tokens [B,S] int32, loss_mask [B,S] (optional),
        embeds [B,P,d] (vlm/audio). Next-token cross entropy, computed
        chunked over the sequence (logits never fully materialized)."""
        tokens = batch["tokens"]
        h, aux = forward_hidden(params, tokens, batch.get("embeds"))
        h = Lyr.rms_norm(h, params["final_norm"])[:, :-1]
        tgt = tokens[:, 1:]
        mask = batch.get("loss_mask")
        mask = (jnp.ones(tgt.shape, jnp.float32) if mask is None
                else mask[:, 1:].astype(jnp.float32))
        if cfg.frontend_tokens and batch.get("embeds") is not None:
            Pn = batch["embeds"].shape[1]
            pos_ok = jnp.arange(tgt.shape[1]) >= Pn    # only text positions
            mask = mask * pos_ok[None, :]
        total = _chunked_xent(params, h, tgt, mask)
        l = total / jnp.maximum(jnp.sum(mask), 1.0)
        if cfg.num_experts:
            l = l + 0.01 * aux
        return l

    # --------------------------- caches -------------------------------
    def init_caches(batch: int, cache_len: int) -> Pytree:
        if fam in ("dense", "vlm", "audio", "moe"):
            def one(_):
                return Lyr.init_kv_cache(cfg, batch, cache_len, dtype)
            return jax.vmap(one)(jnp.arange(L))
        if fam == "ssm":
            def one(_):
                return Lyr.init_ssm_state(cfg, batch, dtype)
            return jax.vmap(one)(jnp.arange(L))
        # hybrid: mamba states + shared-block KV caches (one per application)
        n_groups, group, trailing = _hybrid_counts(cfg)
        m_states = jax.vmap(lambda _: Lyr.init_ssm_state(cfg, batch, dtype))(
            jnp.arange(n_groups * group)
        )
        t_states = (
            jax.vmap(lambda _: Lyr.init_ssm_state(cfg, batch, dtype))(
                jnp.arange(trailing)
            ) if trailing else None
        )
        kv = jax.vmap(lambda _: Lyr.init_kv_cache(cfg, batch, cache_len, dtype))(
            jnp.arange(n_groups)
        )
        out = {"mamba": m_states, "shared_kv": kv}
        if t_states is not None:
            out["tail"] = t_states
        return out

    # --------------------------- decode -------------------------------
    def decode_step(params, caches, tokens, pos):
        """tokens: [B,1] int32; pos: [B,1] int32 absolute positions."""
        h = embed_tokens(params, tokens, None)

        if fam in ("dense", "vlm", "audio", "moe"):
            def body(hh, xs):
                p, cache = xs
                if fam == "moe":
                    hh, nc, _ = _moe_block(p, hh, cfg, sh, pos, window, cache=cache)
                else:
                    hh, nc = _dense_block(p, hh, cfg, sh, pos, window, cache=cache)
                return hh, nc
            h, new_caches = jax.lax.scan(body, h, (params["blocks"], caches))
        elif fam == "ssm":
            def body(hh, xs):
                p, st = xs
                hh, ns = _ssm_block(p, hh, cfg, sh, state=st)
                return hh, ns
            h, new_caches = jax.lax.scan(body, h, (params["blocks"], caches))
        else:  # hybrid
            n_groups, group, trailing = _hybrid_counts(cfg)
            gparams = jax.tree.map(
                lambda x: x.reshape((n_groups, group) + x.shape[1:]),
                params["mamba_groups"],
            )
            gstates = jax.tree.map(
                lambda x: x.reshape((n_groups, group) + x.shape[1:]),
                caches["mamba"],
            )

            def group_body(hh, xs):
                gp, gs, kvc = xs
                def inner(ih, ixs):
                    p, st = ixs
                    ih, ns = _ssm_block(p, ih, cfg, sh, state=st)
                    return ih, ns
                hh, new_gs = jax.lax.scan(inner, hh, (gp, gs))
                hh, new_kv = _dense_block(
                    params["shared"], hh, cfg, sh, pos, window, cache=kvc
                )
                return hh, (new_gs, new_kv)

            h, (new_gstates, new_kv) = jax.lax.scan(
                group_body, h, (gparams, gstates, caches["shared_kv"])
            )
            new_caches = {
                "mamba": jax.tree.map(
                    lambda x: x.reshape((n_groups * group,) + x.shape[2:]), new_gstates
                ),
                "shared_kv": new_kv,
            }
            if trailing:
                def body(hh, xs):
                    p, st = xs
                    hh, ns = _ssm_block(p, hh, cfg, sh, state=st)
                    return hh, ns
                h, new_tail = jax.lax.scan(body, h, (params["mamba_tail"], caches["tail"]))
                new_caches["tail"] = new_tail

        logits = unembed(params, h)
        return logits[:, -1], new_caches

    # --------------------------- prefill ------------------------------
    def prefill(params, tokens, embeds=None, cache_len: int | None = None):
        """Full forward that also builds decode caches (training-free path).

        ``cache_len`` reserves headroom for subsequent decode steps (defaults
        to S — i.e. ring-buffer wrap on the first decoded token; serving
        passes S + max_new_tokens, or the sliding window for windowed archs).

        For attention families the per-layer K/V sequences are recomputed into
        cache layout via a scan that emits them as ys; SSM families emit final
        states directly."""
        B, S = tokens.shape
        C = cache_len or S
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = embed_tokens(params, tokens, embeds)

        def pad_kv(kv_stacked, n_stack):
            """[n,B,S,KV,hd] k/v -> cache layout of length C with pos padding."""
            k, v = kv_stacked["k"], kv_stacked["v"]
            if C > S:
                padder = lambda x: jnp.pad(
                    x, ((0, 0), (0, 0), (0, C - S), (0, 0), (0, 0))
                )
                k, v = padder(k), padder(v)
            pos = jnp.pad(
                jnp.broadcast_to(positions, (n_stack, B, S)),
                ((0, 0), (0, 0), (0, C - S)), constant_values=-1,
            )
            return {"k": k, "v": v, "pos": pos,
                    "idx": jnp.full((n_stack,), S, jnp.int32)}

        def attn_with_cache_emit(p, hh):
            hn = Lyr.rms_norm(hh, p["attn_norm"])
            hd = cfg.resolved_head_dim
            H, KV = cfg.eff_heads, cfg.eff_kv_heads
            k = (hn @ p["attn"]["wk"]).reshape(B, S, KV, hd)
            v = (hn @ p["attn"]["wv"]).reshape(B, S, KV, hd)
            if cfg.qk_norm:
                k = Lyr.rms_norm(k, p["attn"]["k_norm"])
            k = Lyr.apply_rope(k, positions, cfg.rope_theta)
            a, _ = Lyr.attention(p["attn"], hn, cfg, sh, positions, window=window)
            hh = hh + a
            return hh, {"k": k, "v": v}

        if fam in ("dense", "vlm", "audio", "moe"):
            def body(hh, p):
                hh, kv = attn_with_cache_emit(p, hh)
                if fam == "moe":
                    moe_fn = Lyr.moe_sharded if sh.mesh is not None else Lyr.moe
                    y, _ = moe_fn(p["moe"], Lyr.rms_norm(hh, p["mlp_norm"]), cfg, sh)
                else:
                    y = Lyr.mlp(p["mlp"], Lyr.rms_norm(hh, p["mlp_norm"]), sh)
                return hh + y, kv
            h, kvs = jax.lax.scan(body, h, params["blocks"])
            caches = pad_kv(kvs, L)
        elif fam == "ssm":
            def body(hh, p):
                hh2, st = _ssm_block(p, hh, cfg, sh, ssd_fn=ssd_fn)
                return hh2, st
            h, caches = jax.lax.scan(body, h, params["blocks"])
        else:  # hybrid
            n_groups, group, trailing = _hybrid_counts(cfg)
            gshape = jax.tree.map(
                lambda x: x.reshape((n_groups, group) + x.shape[1:]),
                params["mamba_groups"],
            )

            def group_body(hh, gp):
                def inner(ih, p):
                    ih2, st = _ssm_block(p, ih, cfg, sh, ssd_fn=ssd_fn)
                    return ih2, st
                hh, gstates = jax.lax.scan(inner, hh, gp)
                hh, kv = attn_with_cache_emit(params["shared"], hh)
                y = Lyr.mlp(params["shared"]["mlp"],
                            Lyr.rms_norm(hh, params["shared"]["mlp_norm"]), sh)
                return hh + y, (gstates, kv)

            h, (gstates, kvs) = jax.lax.scan(group_body, h, gshape)
            caches = {
                "mamba": jax.tree.map(
                    lambda x: x.reshape((n_groups * group,) + x.shape[2:]), gstates
                ),
                "shared_kv": pad_kv(kvs, n_groups),
            }
            if trailing:
                def body(hh, p):
                    hh2, st = _ssm_block(p, hh, cfg, sh, ssd_fn=ssd_fn)
                    return hh2, st
                h, tstates = jax.lax.scan(body, h, params["mamba_tail"])
                caches["tail"] = tstates

        return unembed_last(params, h), caches

    return Model(cfg=cfg, sh=sh, init=init, forward=forward, loss=loss,
                 prefill=prefill, decode_step=decode_step, init_caches=init_caches)
