"""Transformer / MoE / Mamba2 building blocks, sharding-annotated.

Conventions:
* params are nested dicts of jnp arrays; layer stacks carry a leading
  [num_layers] axis and are consumed by ``jax.lax.scan`` so HLO size is
  O(1 layer) regardless of depth (required: 80-layer dry-runs at 512 logical
  devices on a 1-core CPU host).
* every block takes ``sh``: a ``Sharder`` that applies
  with_sharding_constraint when a mesh is active and no-ops otherwise, so the
  same code path serves smoke tests (1 CPU device) and the production mesh.
* GQA head padding: configs' ``eff_heads``/``eff_kv_heads`` may exceed the
  true counts for tensor-parallel divisibility. Padded q-heads have zero
  o_proj rows => exact no-ops at init (documented in DESIGN.md §5).
* dtype policy: params & activations in cfg.dtype (bf16 for the big archs),
  softmax/normalization/SSM state math in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any


# ---------------------------------------------------------------------------
# sharding helper
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Sharder:
    """Applies logical-axis sharding constraints when a mesh is present.

    axes maps logical names -> mesh axis (or None). The FL mapping puts
    clients on ("pod","data") — ``batch`` is sharded over both — and tensor
    parallelism on "model".
    """

    mesh: Any = None
    axes: dict | None = None

    def spec(self, *logical: str | None) -> P:
        ax = self.axes or {}
        return P(*(ax.get(l) if l else None for l in logical))

    def __call__(self, x: jax.Array, *logical: str | None) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical))
        )


def default_axes(multi_pod: bool = False) -> dict:
    batch = ("pod", "data") if multi_pod else "data"
    return {
        "batch": batch,
        "seq": None,
        "heads": "model",
        "kv_heads": "model",     # overridden to None when kv % shards != 0
        "d_model": None,
        "d_ff": "model",
        "experts": "model",      # overridden to None when E % shards != 0
        "expert_ff": None,       # flipped to "model" when experts replicated
        "vocab": "model",
        "ssm_inner": "model",
        "ssm_state": None,
    }


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (int). Rotates pairs (even, odd)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def dense_init(rng, shape, in_axis_size, dtype):
    scale = 1.0 / jnp.sqrt(jnp.asarray(in_axis_size, jnp.float32))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm, full-causal or sliding-window, KV cache)
# ---------------------------------------------------------------------------

def gqa_mode(cfg) -> str:
    """'grouped' when attention can use the block-GQA einsum (q reshaped to
    [.., KV_eff, G, hd] with NO kv materialization — §Perf H2); requires the
    uniform slot map i -> i//G to reproduce the TRUE mapping i -> i·KV//H
    through the replicated-kv weight layout. Otherwise 'gather'."""
    H, KVe = cfg.eff_heads, cfg.eff_kv_heads
    KV, Ht = cfg.num_kv_heads, cfg.num_heads
    if not H or H % KVe != 0 or KVe % KV != 0:
        return "gather"
    G, r = H // KVe, KVe // KV
    for i in range(Ht):
        if (i // G) // r != (i * KV) // Ht:
            return "gather"
    return "grouped"


def attn_init(rng, cfg, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.eff_heads, cfg.eff_kv_heads
    KV_true = cfg.num_kv_heads
    ks = jax.random.split(rng, 4)

    def kv_proj(rng_):
        if gqa_mode(cfg) == "grouped" and KV != KV_true and KV % KV_true == 0:
            # replicated-kv layout: padded slots repeat true kv heads so the
            # uniform grouped mapping stays exact (DESIGN.md §5)
            w = dense_init(rng_, (d, KV_true, hd), d, dtype)
            return jnp.repeat(w, KV // KV_true, axis=1).reshape(d, KV * hd)
        return dense_init(rng_, (d, KV * hd), d, dtype)

    p = {
        "wq": dense_init(ks[0], (d, H * hd), d, dtype),
        "wk": kv_proj(ks[1]),
        "wv": kv_proj(ks[2]),
        "wo": dense_init(ks[3], (H * hd, d), H * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    if cfg.eff_heads != cfg.num_heads:
        # zero the padded heads' output rows => padded heads are no-ops
        mask = (jnp.arange(H * hd) < cfg.num_heads * hd).astype(dtype)
        p["wo"] = p["wo"] * mask[:, None]
    return p


def _attn_scores_mask(q_pos, k_pos, window: int):
    """[.., Sq, Sk] boolean mask: causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        m = jnp.logical_and(m, k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m


def _attention_blocked(q5, k, v, positions, window: int, block: int = 512):
    """Flash-style blocked attention in pure XLA (§Perf H3 iter 2).

    q5: [B, Sq, KV, G, hd] (grouped layout); k, v: [B, Sk, KV, hd].
    Scans over key blocks with an online softmax so the [Sq, Sk] score
    matrix is NEVER materialized in HBM — on the TPU target the Pallas
    kernel (kernels/flash_attention) does the same thing intra-core; this
    version is the GSPMD-shardable train/prefill path. The scan body is
    rematerialized so backward recomputes per-block scores instead of
    saving them.
    """
    B, Sq, KV, G, hd = q5.shape
    Sk = k.shape[1]
    block = min(block, Sk)
    nb = Sk // block
    assert Sk % block == 0
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    q32 = q5.astype(jnp.float32)

    def body(carry, kb):
        acc, m, l = carry
        k_b, v_b, pos_b = kb                              # [B, bk, KV, hd]
        s = jnp.einsum("bqkgd,bskd->bkgqs", q32, k_b.astype(jnp.float32))
        s = s * scale
        mask = _attn_scores_mask(positions, pos_b, window)  # [B, Sq, bk]
        s = jnp.where(mask[:, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, v_b.astype(jnp.float32)
        )
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    kb = (
        k.reshape(B, nb, block, KV, hd).transpose(1, 0, 2, 3, 4),
        v.reshape(B, nb, block, KV, hd).transpose(1, 0, 2, 3, 4),
        positions.reshape(B, nb, block).transpose(1, 0, 2),
    )
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, l0), kb)
    out = acc / jnp.maximum(l[..., None], 1e-30)          # [B, KV, G, Sq, hd]
    return out.transpose(0, 3, 1, 2, 4).astype(q5.dtype)  # [B, Sq, KV, G, hd]


def attention(
    p: dict, x: jax.Array, cfg, sh: Sharder,
    positions: jax.Array,
    cache: dict | None = None,
    window: int = 0,
) -> tuple[jax.Array, dict | None]:
    """x: [B, S, d]. With ``cache`` (decode): S==1, reads/writes the KV ring
    buffer. Returns (out [B,S,d], new_cache)."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.eff_heads, cfg.eff_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = sh(q, "batch", None, "heads", None)
    k = sh(k, "batch", None, "kv_heads", None)
    v = sh(v, "batch", None, "kv_heads", None)

    if cache is not None:
        # decode: write this step's k/v at slot idx (ring buffer when the
        # cache is shorter than the sequence, i.e. sliding-window archs)
        C = cache["k"].shape[1]
        slot = cache["idx"] % C
        quant = "k_scale" in cache
        if quant:
            kq, ks = _quantize_kv(k[:, 0])
            vq, vs = _quantize_kv(v[:, 0])
            k_all = cache["k"].at[:, slot].set(kq)
            v_all = cache["v"].at[:, slot].set(vq)
            k_sc = cache["k_scale"].at[:, slot].set(ks)
            v_sc = cache["v_scale"].at[:, slot].set(vs)
            k_pos = cache["pos"].at[:, slot].set(positions[:, 0])
            new_cache = {"k": k_all, "v": v_all, "k_scale": k_sc,
                         "v_scale": v_sc, "pos": k_pos, "idx": cache["idx"] + 1}
            k_use = k_all.astype(jnp.float32) * k_sc
            v_use = (v_all.astype(jnp.float32) * v_sc).astype(x.dtype)
            k_use = k_use.astype(x.dtype)
        else:
            k_all = cache["k"].at[:, slot].set(k[:, 0])
            v_all = cache["v"].at[:, slot].set(v[:, 0])
            k_pos = cache["pos"].at[:, slot].set(positions[:, 0])
            new_cache = {"k": k_all, "v": v_all, "pos": k_pos, "idx": cache["idx"] + 1}
            k_use, v_use = k_all, v_all
        k_positions = k_pos
    else:
        new_cache = None
        k_use, v_use, k_positions = k, v, positions

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    mask = _attn_scores_mask(positions, k_positions, window)   # [B,Sq,Sk]
    if cache is not None:
        # never-written slots carry pos = -1; exclude them
        valid = (k_positions >= 0)[:, None]
        mask = jnp.logical_and(mask, valid)

    if gqa_mode(cfg) == "grouped":
        # §Perf H2: block-GQA einsum — kv heads are NEVER materialized at q
        # multiplicity (a 4× KV-cache re-read per layer at 76B/decode scale)
        G = H // KV
        q5 = q.reshape(B, S, KV, G, hd)
        if cache is None and S >= 1024 and S % 512 == 0:
            # §Perf H3 iter 2: flash-style blocked path for long train/
            # prefill sequences — scores never hit HBM
            out = _attention_blocked(q5, k_use, v_use, positions, window)
            out = out.reshape(B, S, H * hd)
            return out @ p["wo"], new_cache
        logits = jnp.einsum("bqkgd,bskd->bkgqs", q5, k_use).astype(jnp.float32)
        logits = jnp.where(mask[:, None, None], logits * scale, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_use)
        out = out.reshape(B, S, H * hd)
        return out @ p["wo"], new_cache

    # gather fallback: map each q head to its kv head via the TRUE counts so
    # padded q/kv heads never change the mapping — padded kv heads are never
    # referenced, padded q heads are killed by their zero o_proj rows.
    Ht, KVt = cfg.num_heads, cfg.num_kv_heads
    kv_map = jnp.asarray(
        [(i * KVt) // Ht if i < Ht else i % KV for i in range(H)], jnp.int32
    )
    k_use = jnp.take(k_use, kv_map, axis=2)
    v_use = jnp.take(v_use, kv_map, axis=2)

    if cache is None and S >= 1024 and S % 512 == 0:
        # §Perf H3 iter 2 (gather-mode variant): blocked attention with the
        # gathered kv treated as MHA (KV=H, G=1)
        out = _attention_blocked(
            q.reshape(B, S, H, 1, hd), k_use, v_use, positions, window
        )
        out = out.reshape(B, S, H * hd)
        return out @ p["wo"], new_cache

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_use).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_use)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"], new_cache


def init_kv_cache(cfg, batch: int, cache_len: int, dtype) -> dict:
    KV, hd = cfg.eff_kv_heads, cfg.resolved_head_dim
    if getattr(cfg, "kv_quant", False):
        # int8 cache with per-(slot, head) scales — halves HBM traffic of the
        # dominant decode stream (PerfH2 iter 2)
        return {
            "k": jnp.zeros((batch, cache_len, KV, hd), jnp.int8),
            "v": jnp.zeros((batch, cache_len, KV, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, cache_len, KV, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, cache_len, KV, 1), jnp.float32),
            "pos": jnp.full((batch, cache_len), -1, jnp.int32),
            "idx": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def _quantize_kv(x):
    """x: [B, KV, hd] -> (int8 values, [B, KV, 1] scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    return {
        "wi_gate": dense_init(ks[0], (d, f), d, dtype),
        "wi_up": dense_init(ks[1], (d, f), d, dtype),
        "wo": dense_init(ks[2], (f, d), f, dtype),
    }


def mlp(p: dict, x: jax.Array, sh: Sharder) -> jax.Array:
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    h = sh(h, "batch", None, "d_ff")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE (capacity-based scatter/gather dispatch — no dense one-hot einsum, so
# cost_analysis FLOPs stay honest and XLA emits a real all-to-all when experts
# are sharded on "model")
# ---------------------------------------------------------------------------

def moe_sharded(p: dict, x: jax.Array, cfg, sh: Sharder,
                dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via explicit shard_map (§Perf H1, iteration 2).

    GSPMD lowers the data-dependent scatter dispatch of ``moe`` to an
    all-reduce of the whole [E, C, d] buffer per layer. The communication-
    optimal pattern needs no dispatch collective at all: activations are
    replicated over the 'model' axis (they are sharded over 'data'), so each
    model rank can locally select the tokens routed to ITS experts, run the
    expert FFNs, and contribute a partial [T_loc, d] output — one psum over
    'model' per layer is the entire collective footprint.
    """
    mesh = sh.mesh
    E = cfg.eff_experts
    model_size = mesh.shape["model"]
    if E % model_size != 0:
        return moe(p, x, cfg, sh, dropless=dropless)
    E_loc = E // model_size
    k = cfg.experts_per_token
    batch_ax = sh.axes.get("batch") or None
    batch_tuple = batch_ax if isinstance(batch_ax, tuple) else (
        (batch_ax,) if batch_ax else ())
    # B=1 decodes (long_500k) can't shard the batch — replicate it instead
    n_batch_shards = 1
    for a in batch_tuple:
        n_batch_shards *= mesh.shape[a]
    if x.shape[0] % max(n_batch_shards, 1) != 0:
        batch_ax, batch_tuple = None, ()

    from jax.sharding import PartitionSpec as P
    from repro.utils.compat import shard_map

    def local_fn(router_w, wig, wiu, wo, xl):
        Bl, Sl, d = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router_w
        if E != cfg.num_experts:
            dummy = jnp.arange(E) >= cfg.num_experts
            logits = jnp.where(dummy[None, :], -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        frac = jnp.mean(jax.nn.one_hot(gate_i[:, 0], E, dtype=jnp.float32), 0)
        aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
        if batch_tuple:
            aux = jax.lax.pmean(aux, batch_tuple)

        cap = T * k if dropless else max(int(cfg.capacity_factor * T * k / E), 1)
        flat_e = gate_i.reshape(-1)                       # [T*k] global ids
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(T * k), flat_e]
        e0 = jax.lax.axis_index("model") * E_loc
        local_e = flat_e - e0                             # [T*k]
        mine = jnp.logical_and(local_e >= 0, local_e < E_loc)
        keep = jnp.logical_and(mine, pos < cap)
        safe_e = jnp.where(keep, local_e, 0)
        safe_p = jnp.where(keep, pos, cap - 1)

        # index-based dispatch (§Perf H1 iter 4): scatter 4-byte token ids
        # instead of the [T·k, d] repeated activations, then gather rows —
        # cuts dispatch HBM traffic by ~d·dtype/4 per assignment.
        tok_id = jnp.arange(T * k, dtype=jnp.int32) // k
        idx_buf = jnp.full((E_loc, cap), T, jnp.int32)       # T = sentinel
        idx_buf = idx_buf.at[safe_e, safe_p].set(jnp.where(keep, tok_id, T))
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        buf = xt_pad[idx_buf]                                # [E_loc, cap, d]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wig))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wiu)
        yb = jnp.einsum("ecf,efd->ecd", h, wo)
        y_tok = jnp.where(keep[:, None], yb[safe_e, safe_p], 0)
        w_flat = gate_w.reshape(-1, 1).astype(xl.dtype)
        y = jnp.sum((y_tok * w_flat).reshape(T, k, d), axis=1)
        y = jax.lax.psum(y, "model")                      # THE collective
        return y.reshape(Bl, Sl, d), aux

    ba = batch_ax
    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(ba, None, None)),
        out_specs=(P(ba, None, None), P()),
        check_vma=False,
    )(p["router"], p["wi_gate"], p["wi_up"], p["wo"], x)
    return out, aux


def moe_init(rng, cfg, dtype) -> dict:
    d, E, f = cfg.d_model, cfg.eff_experts, cfg.moe_d_ff
    ks = jax.random.split(rng, 4)
    return {
        "router": dense_init(ks[0], (d, E), d, jnp.float32),
        "wi_gate": dense_init(ks[1], (E, d, f), d, dtype),
        "wi_up": dense_init(ks[2], (E, d, f), d, dtype),
        "wo": dense_init(ks[3], (E, f, d), f, dtype),
    }


def moe(p: dict, x: jax.Array, cfg, sh: Sharder,
        dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (out [B,S,d], aux_loss scalar: load-balance, Switch-style).

    dropless=True sets capacity = T*k so no token can ever be dropped —
    required for decode (capacity routing is non-causal across the batch, so
    teacher-forced decode would diverge from a capacity-based forward).
    Training/prefill keep GShard capacity semantics (cfg.capacity_factor)."""
    B, S, d = x.shape
    E, k = cfg.eff_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    if E != cfg.num_experts:
        # padded (dummy) experts are masked out of routing entirely
        dummy = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(dummy[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                    # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * Σ_e fraction_e * prob_e
    frac = jnp.mean(jax.nn.one_hot(gate_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    if dropless:
        capacity = T * k          # worst case: every assignment to one expert
    else:
        capacity = max(int(cfg.capacity_factor * T * k / E), 1)

    # position of each (token, slot) within its expert, via cumsum of one-hot
    flat_e = gate_i.reshape(-1)                                 # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # [T*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(T * k), flat_e]
    keep = pos_in_e < capacity
    safe_pos = jnp.where(keep, pos_in_e, capacity - 1)

    # scatter tokens into [E, C, d] expert buffers
    xt_rep = jnp.repeat(xt, k, axis=0)                          # [T*k, d]
    buf = jnp.zeros((E, capacity, d), x.dtype)
    buf = buf.at[flat_e, safe_pos].add(jnp.where(keep[:, None], xt_rep, 0))
    buf = sh(buf, "experts", None, None)

    # per-expert FFN (batched over E)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    h = sh(h, "experts", None, "expert_ff")
    yb = jnp.einsum("ecf,efd->ecd", h, p["wo"])                 # [E, C, d]
    yb = sh(yb, "experts", None, None)

    # gather back + weight
    y_tok = yb[flat_e, safe_pos]                                # [T*k, d]
    y_tok = jnp.where(keep[:, None], y_tok, 0)
    w_flat = gate_w.reshape(-1, 1).astype(x.dtype)
    y = jnp.sum((y_tok * w_flat).reshape(T, k, d), axis=1)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) mixer
# ---------------------------------------------------------------------------

def mamba_init(rng, cfg, dtype) -> dict:
    """Projections kept SEPARATE (not fused) so tensor-parallel sharding is
    clean: wx/wz/out_proj shard on d_inner ('model'); B/C/dt projections are
    small and replicated. The causal conv is split accordingly (conv_x over
    the sharded inner channels, conv_bc over the replicated state channels)."""
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    ks = jax.random.split(rng, 8)
    return {
        "wx": dense_init(ks[0], (d, di), d, dtype),
        "wz": dense_init(ks[1], (d, di), d, dtype),
        "wB": dense_init(ks[2], (d, st), d, dtype),
        "wC": dense_init(ks[3], (d, st), d, dtype),
        "wdt": dense_init(ks[4], (d, nh), d, dtype),
        "conv_x_w": dense_init(ks[5], (cfg.ssm_conv_width, di), cfg.ssm_conv_width, dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": dense_init(ks[6], (cfg.ssm_conv_width, 2 * st), cfg.ssm_conv_width, dtype),
        "conv_bc_b": jnp.zeros((2 * st,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[7], (di, d), di, dtype),
    }


def _ssd_chunked_scan(xh, dt, A, Bm, Cm, chunk: int, ssd_fn=None):
    """SSD forward (Mamba2, arXiv:2405.21060 §6): chunked dual form.

    xh: [B, S, nh, hd]; dt: [B, S, nh] (softplus'd); A: [nh] (negative);
    Bm/Cm: [B, S, st]. Returns y [B, S, nh, hd] and final state
    [B, nh, hd, st].

    ``ssd_fn`` optionally overrides the intra-chunk compute with the Pallas
    kernel (kernels/ssd); default is the pure-jnp reference path.
    """
    B, S, nh, hd = xh.shape
    st = Bm.shape[-1]
    nc = S // chunk
    Q = chunk

    xc = xh.reshape(B, nc, Q, nh, hd)
    dtc = dt.reshape(B, nc, Q, nh)
    Bc = Bm.reshape(B, nc, Q, st)
    Cc = Cm.reshape(B, nc, Q, st)

    dA = dtc * A[None, None, None, :]              # [B,nc,Q,nh]  (negative)
    dA_cumsum = jnp.cumsum(dA, axis=2)             # within-chunk cumsum

    if ssd_fn is not None:
        y_diag, chunk_state = ssd_fn(xc, dtc, dA_cumsum, Bc, Cc)
    else:
        # intra-chunk (diagonal block): quadratic attention-like form
        # L[i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j
        seg = dA_cumsum[:, :, :, None, :] - dA_cumsum[:, :, None, :, :]
        causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
        # mask BEFORE exp: the non-causal region has seg > 0 and would
        # overflow, poisoning gradients through the where (NaN-grad trap)
        decay = jnp.exp(jnp.where(causal, seg, -1e30))
        cb = jnp.einsum("bcqs,bcks->bcqk", Cc, Bc)  # [B,nc,Q,Q]
        att = cb[..., None] * decay                  # [B,nc,Q,Q,nh]
        xdt = xc * dtc[..., None]                    # dt-weighted inputs
        y_diag = jnp.einsum("bcqkh,bckhd->bcqhd", att, xdt)
        # chunk final states: sum_j exp(dA_cum[Q-1]-dA_cum[j]) dt_j B_j x_j
        decay_last = jnp.exp(dA_cumsum[:, :, -1:, :] - dA_cumsum)   # [B,nc,Q,nh]
        chunk_state = jnp.einsum(
            "bcqs,bcqh,bcqhd->bchds", Bc, dtc * decay_last, xc
        )                                            # [B,nc,nh,hd,st]

    # inter-chunk recurrence over nc (associative scan on (decay, state))
    chunk_decay = jnp.exp(dA_cumsum[:, :, -1, :])    # [B,nc,nh]

    def combine(a, b):
        d_a, s_a = a
        d_b, s_b = b
        return d_a * d_b, s_a * d_b[..., None, None] + s_b

    decays, states = jax.lax.associative_scan(
        combine, (chunk_decay, chunk_state), axis=1
    )
    # state entering chunk c = states[c-1]; shift right with zero init
    init_state = jnp.zeros_like(states[:, :1])
    prev_states = jnp.concatenate([init_state, states[:, :-1]], axis=1)

    # contribution of carried-in state to each position in the chunk
    state_decay = jnp.exp(dA_cumsum)                 # [B,nc,Q,nh]
    y_off = jnp.einsum(
        "bcqs,bchds,bcqh->bcqhd", Cc, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(B, S, nh, hd)
    final_state = states[:, -1]                      # [B,nh,hd,st]
    return y, final_state


def mamba_forward(
    p: dict, x: jax.Array, cfg, sh: Sharder,
    state: dict | None = None, ssd_fn=None,
) -> tuple[jax.Array, dict | None]:
    """Mamba2 block. Training/prefill when state is None (uses chunked SSD);
    single-token decode when state given (O(1) recurrent update).

    state = {"conv": [B, W-1, conv_dim], "ssm": [B, nh, hd, st]}.
    """
    B, S, d = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = sh(x @ p["wx"], "batch", None, "ssm_inner")
    z = sh(x @ p["wz"], "batch", None, "ssm_inner")
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt_raw = x @ p["wdt"]

    conv_in = jnp.concatenate([xz, Bm, Cm], axis=-1)     # [B,S,di+2st]
    W = cfg.ssm_conv_width
    if state is None:
        pad = jnp.zeros((B, W - 1, conv_in.shape[-1]), conv_in.dtype)
        cseq = jnp.concatenate([pad, conv_in], axis=1)
        new_conv_state = cseq[:, -(W - 1):] if W > 1 else None
    else:
        cseq = jnp.concatenate([state["conv"], conv_in], axis=1)
        new_conv_state = cseq[:, -(W - 1):]
    # depthwise causal conv, split into sharded-x and replicated-B/C parts
    idx = jnp.arange(S)[:, None] + jnp.arange(W)[None, :]
    windows = cseq[:, idx]                                # [B,S,W,di+2st]
    wx_full = jnp.concatenate([p["conv_x_w"], p["conv_bc_w"]], axis=-1)
    bx_full = jnp.concatenate([p["conv_x_b"], p["conv_bc_b"]], axis=-1)
    conv_out = jnp.einsum("bswc,wc->bsc", windows, wx_full) + bx_full
    conv_out = jax.nn.silu(conv_out)
    xc, Bc, Cc = jnp.split(conv_out, [di, di + st], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B,S,nh]
    A = -jnp.exp(p["A_log"])                                          # [nh], < 0
    xh = xc.reshape(B, S, nh, hd).astype(jnp.float32)
    Bc32, Cc32 = Bc.astype(jnp.float32), Cc.astype(jnp.float32)

    if state is None:
        y, final_state = _ssd_chunked_scan(
            xh, dt, A, Bc32, Cc32, min(cfg.ssm_chunk, S), ssd_fn=ssd_fn
        )
        new_state = (
            {"conv": new_conv_state, "ssm": final_state} if new_conv_state is not None
            else {"ssm": final_state}
        )
    else:
        # recurrent step: h ← exp(dtA) h + dt·B⊗x ;  y = C·h + D·x
        dA = jnp.exp(dt[:, 0] * A[None])                 # [B,nh]
        h = state["ssm"] * dA[..., None, None]
        h = h + jnp.einsum("bh,bhd,bs->bhds", dt[:, 0], xh[:, 0], Bc32[:, 0])
        y = jnp.einsum("bs,bhds->bhd", Cc32[:, 0], h)[:, None]  # [B,1,nh,hd]
        final_state = h
        new_state = {"conv": new_conv_state, "ssm": final_state}

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)                                # gated
    y = rms_norm(y, p["norm"])
    y = sh(y, "batch", None, "ssm_inner")
    return y @ p["out_proj"], new_state


def init_ssm_state(cfg, batch: int, dtype) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }
