"""ℓ2-regularized linear (ridge) regression.

The second member of the linear-design family: a strongly-convex quadratic
federated problem whose local trajectories exercise the fused kernels'
"linear" link (kernels/local_update). Useful as a closed-form-checkable
workload — the global optimum solves (XᵀX/N + γI) w = Xᵀy/N — and as the
FL analogue of the least-squares problems the second-order baselines
(GIANT, DANE) were published on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import ClientBatch, FLProblem, LinearDesign, StackedClients


def make_linreg_problem(
    clients: StackedClients, gamma: float = 1e-3, init_scale: float = 0.0,
    dtype=jnp.float32,
) -> FLProblem:
    """f_k(w) = mean_j ½ (wᵀx_j − y_j)² + γ/2 ‖w‖²  over client k's data.

    Declares the linear-design protocol (link "linear") — eligible for the
    fused dual-gradient local-trajectory kernels, like logreg.
    """
    d = clients.x.shape[-1]

    def loss(w: jax.Array, batch: ClientBatch) -> jax.Array:
        z = batch.x.astype(w.dtype) @ w
        per = 0.5 * (z - batch.y.astype(w.dtype)) ** 2
        n = jnp.maximum(jnp.sum(batch.mask), 1.0)
        return jnp.sum(per * batch.mask) / n + 0.5 * gamma * jnp.dot(w, w)

    def init(rng: jax.Array) -> jax.Array:
        if init_scale == 0.0:
            return jnp.zeros((d,), dtype)
        return init_scale * jax.random.normal(rng, (d,), dtype)

    def linear_design(batch: ClientBatch) -> LinearDesign:
        return LinearDesign(batch.x, batch.y, "linear", gamma)

    return FLProblem(loss=loss, init=init, clients=clients,
                     linear_design=linear_design)


def linreg_exact_solution(clients: StackedClients, gamma: float) -> jax.Array:
    """The global ridge optimum of Σ_k (N_k/N)·f_k — the weighted normal
    equations (dense d×d, small-d reference for tests)."""
    K, _, d = clients.x.shape
    A = jnp.zeros((d, d))
    b = jnp.zeros((d,))
    for k in range(K):
        xk, yk, mk = clients.x[k], clients.y[k], clients.mask[k]
        nk = jnp.maximum(jnp.sum(mk), 1.0)
        A = A + clients.weight[k] * (xk.T * mk) @ xk / nk
        b = b + clients.weight[k] * (xk.T * mk) @ yk / nk
    A = A + gamma * jnp.eye(d)
    return jnp.linalg.solve(A, b)
