"""ℓ2-regularized logistic regression (paper Eq. 11)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import ClientBatch, FLProblem, LinearDesign, StackedClients


def make_logreg_problem(
    clients: StackedClients, gamma: float = 1e-3, init_scale: float = 0.0,
    dtype=jnp.float32,
) -> FLProblem:
    """f_k(w) = mean_j log(1+exp(−y_j wᵀx_j)) + γ/2 ‖w‖²  over client k's data.

    y ∈ {−1, +1}. Initial point w⁰ = 0 (paper §4) unless init_scale > 0.
    ``dtype=jnp.float64`` (with jax_enable_x64) reproduces the paper's deep
    rel-error plots — f32 local-step iterations have a fixed-point bias floor
    around 1e-5 (measured in benchmarks/ext_compression.py).

    Declares the linear-design protocol (link "logistic"), so the SVRG /
    SCAFFOLD / FedAvg local trajectories are eligible for the fused
    dual-gradient kernels (``AlgoHParams.local_impl="pallas"``,
    kernels/local_update).
    """
    d = clients.x.shape[-1]

    def loss(w: jax.Array, batch: ClientBatch) -> jax.Array:
        logits = batch.x.astype(w.dtype) @ w * batch.y
        # log(1+exp(−z)) = softplus(−z), numerically stable
        per = jax.nn.softplus(-logits)
        n = jnp.maximum(jnp.sum(batch.mask), 1.0)
        return jnp.sum(per * batch.mask) / n + 0.5 * gamma * jnp.dot(w, w)

    def init(rng: jax.Array) -> jax.Array:
        if init_scale == 0.0:
            return jnp.zeros((d,), dtype)
        return init_scale * jax.random.normal(rng, (d,), dtype)

    def linear_design(batch: ClientBatch) -> LinearDesign:
        return LinearDesign(batch.x, batch.y, "logistic", gamma)

    return FLProblem(loss=loss, init=init, clients=clients,
                     linear_design=linear_design)


def logreg_accuracy(w: jax.Array, x: jax.Array, y: jax.Array) -> float:
    pred = jnp.sign(x @ w)
    return float(jnp.mean(pred == y))


def logreg_condition_number(
    clients: StackedClients, w: jax.Array, gamma: float
) -> float:
    """Condition number of the global Hessian at w (for §3.2 κ discussion).
    Only viable for small d (dense Hessian)."""
    X = clients.x.reshape(-1, clients.x.shape[-1])
    Y = clients.y.reshape(-1)
    M = clients.mask.reshape(-1)
    z = X @ w * Y
    s = jax.nn.sigmoid(-z)
    weights = s * (1 - s) * M
    H = (X.T * weights) @ X / jnp.maximum(jnp.sum(M), 1.0) + gamma * jnp.eye(X.shape[1])
    evals = jnp.linalg.eigvalsh(H)
    return float(evals[-1] / evals[0])
