"""Fully-connected ReLU MLP for the paper's NN experiments (Appendix D.5).

MLP1 = one hidden layer of 256; MLP3 = three hidden layers of 256 — exactly
the paper's configurations, with softmax cross-entropy loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import ClientBatch, FLProblem, StackedClients


def make_mlp_problem(
    clients: StackedClients,
    hidden_layers: int = 1,
    hidden_dim: int = 256,
    num_classes: int = 10,
    weight_decay: float = 0.0,
) -> FLProblem:
    in_dim = clients.x.shape[-1]
    dims = [in_dim] + [hidden_dim] * hidden_layers + [num_classes]

    def init(rng: jax.Array):
        params = {}
        keys = jax.random.split(rng, len(dims) - 1)
        for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
            # He init for ReLU nets
            params[f"w{i}"] = jax.random.normal(keys[i], (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
            params[f"b{i}"] = jnp.zeros((dout,), jnp.float32)
        return params

    n_layers = len(dims) - 1

    def forward(params, x):
        h = x
        for i in range(n_layers - 1):
            h = jax.nn.relu(h @ params[f"w{i}"] + params[f"b{i}"])
        return h @ params[f"w{n_layers-1}"] + params[f"b{n_layers-1}"]

    def loss(params, batch: ClientBatch) -> jax.Array:
        logits = forward(params, batch.x)
        labels = batch.y.astype(jnp.int32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        n = jnp.maximum(jnp.sum(batch.mask), 1.0)
        l = jnp.sum(nll * batch.mask) / n
        if weight_decay:
            l = l + 0.5 * weight_decay * sum(
                jnp.sum(p * p) for p in jax.tree.leaves(params)
            )
        return l

    problem = FLProblem(loss=loss, init=init, clients=clients)
    problem.__dict__["forward"] = forward   # expose for accuracy eval
    return problem


def mlp_accuracy(problem: FLProblem, params, x, y) -> float:
    logits = problem.__dict__["forward"](params, x)
    return float(jnp.mean(jnp.argmax(logits, -1) == y.astype(jnp.int32)))
