"""Round telemetry for the device-resident engine (ROADMAP: engine
observability).

Three layers, all fed from the ONE host sync per engine chunk — attaching
telemetry never adds a device→host transfer to the hot loop (pinned in
tests/test_obs.py):

  * ``sinks``     — MetricsSink protocol + in-memory / stdout / JSONL file
                    sinks with a versioned row schema, drained at chunk
                    boundaries by ``core/engine.run_rounds`` and per round by
                    the legacy loop in ``core/server.run_federated``; plus the
                    OFF-by-default ``LiveTap`` (a ``jax.debug.callback`` tap
                    inside the compiled scan for sub-chunk visibility; the
                    inserted callback perturbs XLA fusion at ulp level, so
                    tapped runs match tapless ones at rtol 1e-6 rather than
                    bit-exactly — see sinks.LiveTap).
  * ``profiling`` — on-demand ``jax.profiler.trace`` windows around chunk
                    execution ("trace rounds T..T+N", armed by flag or a
                    trigger file), attributing time to the ``jax.named_scope``
                    round phases annotated in core/algorithms.py /
                    core/sharded.py.
  * ``alarms``    — declarative health rules over the streamed rows
                    (non-finite loss, AA Gram conditioning, column-filtering
                    collapse, rel-error plateau) that log structured warnings
                    and can request early stop at the next chunk boundary.
"""
from repro.obs.alarms import (  # noqa: F401
    DEFAULT_RULES,
    AlarmMonitor,
    AlarmRule,
)
from repro.obs.profiling import (  # noqa: F401
    TraceCapture,
    TraceConfig,
    find_trace_files,
    trace_contains,
)
from repro.obs.sinks import (  # noqa: F401
    ROW_FIELDS,
    SCHEMA_VERSION,
    JsonlSink,
    LiveTap,
    MemorySink,
    MetricsSink,
    StdoutSink,
    build_round_row,
    make_sink,
)
