"""On-demand profiler trace windows around engine chunk execution.

``TraceCapture`` wraps the drivers' chunk (or round) boundaries in
``jax.profiler.start_trace``/``stop_trace``. Because the engine executes
whole chunks inside one jit, the window is aligned OUTWARD to chunk
boundaries: asking for rounds [T, T+N) starts the trace before the first
chunk that overlaps the window and stops it after the first chunk boundary
at or past T+N. Time inside the trace is attributed to round phases by the
``jax.named_scope`` annotations in core/algorithms.py / core/sharded.py /
core/anderson.py ("fl.cohort_plan", "fl.cohort_gather",
"fl.local_trajectory", "fl.aa_step", "fl.uplink", "fl.psum", "fl.scatter").

Two arming modes:

  * static window — ``TraceConfig(start_round=T, num_rounds=N)`` (the
    ``fl_train --trace-rounds N --trace-start T`` path);
  * trigger file — touch ``TraceConfig.trigger_file`` while a long run is in
    flight and the next chunk gets traced (the file is consumed/unlinked so
    each touch yields one window).

On this jax version the profiler writes
``<dir>/plugins/profile/<ts>/<host>.xplane.pb`` (plus a perfetto
``.trace.json.gz``); named-scope strings land in the xplane proto only, so
``trace_contains`` greps the ``.pb`` bytes — that is also what the trace
acceptance test pins.
"""
from __future__ import annotations

import glob
import logging
import os
from dataclasses import dataclass

import jax

logger = logging.getLogger("repro.obs.profiling")


@dataclass(frozen=True)
class TraceConfig:
    """Trace-window request. ``num_rounds=0`` with no trigger file disables
    capture entirely (the drivers skip constructing a TraceCapture)."""

    trace_dir: str
    start_round: int = 0
    num_rounds: int = 0
    trigger_file: str | None = None

    @property
    def enabled(self) -> bool:
        return self.num_rounds > 0 or self.trigger_file is not None


class TraceCapture:
    """Chunk-boundary state machine driving jax.profiler.trace windows.

    Drivers call ``on_chunk_start(first_round, n_live)`` before launching a
    chunk and ``on_chunk_end(next_round)`` after its host sync; the per-round
    loop uses the same hooks with ``n_live=1``. ``close()`` is a safety stop
    for early exits so a run never leaks an open profiler session.
    """

    def __init__(self, config: TraceConfig):
        self.config = config
        self.active = False
        self.windows: list[tuple[int, int]] = []
        self._started_at: int | None = None
        # remaining static window; trigger file arms one extra chunk window
        self._pending_start = config.start_round
        self._pending_rounds = config.num_rounds

    def _trigger_pulled(self) -> bool:
        path = self.config.trigger_file
        if not path or not os.path.exists(path):
            return False
        try:
            os.unlink(path)
        except OSError:
            pass
        return True

    def on_chunk_start(self, first_round: int, n_live: int) -> None:
        if self.active:
            return
        window_hit = (
            self._pending_rounds > 0
            and first_round + n_live > self._pending_start
            and first_round < self._pending_start + self._pending_rounds
        )
        if window_hit:
            stop_after = self._pending_start + self._pending_rounds
        elif self._trigger_pulled():
            stop_after = first_round + n_live
        else:
            return
        os.makedirs(self.config.trace_dir, exist_ok=True)
        jax.profiler.start_trace(self.config.trace_dir)
        self.active = True
        self._started_at = first_round
        self._stop_after = stop_after
        logger.info("trace started at round %d (stop after round %d) -> %s",
                    first_round, stop_after - 1, self.config.trace_dir)

    def on_chunk_end(self, next_round: int) -> None:
        if not self.active or next_round < self._stop_after:
            return
        jax.profiler.stop_trace()
        self.active = False
        self.windows.append((self._started_at, next_round))
        if self._pending_rounds > 0 and next_round >= (
                self._pending_start + self._pending_rounds):
            self._pending_rounds = 0  # static window fully covered
        logger.info("trace stopped before round %d", next_round)

    def close(self) -> None:
        if self.active:
            jax.profiler.stop_trace()
            self.active = False
            self.windows.append((self._started_at, -1))


def find_trace_files(trace_dir: str, suffix: str = ".xplane.pb") -> list:
    """Profiler output files under ``trace_dir`` (any capture session)."""
    pattern = os.path.join(trace_dir, "plugins", "profile", "*", f"*{suffix}")
    return sorted(glob.glob(pattern))


def trace_contains(trace_dir: str, name: str) -> bool:
    """True if any captured xplane proto mentions ``name`` (e.g. a
    ``jax.named_scope`` label). String-level grep of the .pb bytes — scope
    names are stored verbatim in the xplane string table, so this needs no
    proto parser."""
    needle = name.encode()
    for path in find_trace_files(trace_dir):
        with open(path, "rb") as f:
            if needle in f.read():
                return True
    return False


__all__ = ["TraceCapture", "TraceConfig", "find_trace_files", "trace_contains"]
