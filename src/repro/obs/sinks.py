"""Metric streaming: the MetricsSink protocol and its implementations.

The engine (core/engine.py) syncs the host exactly once per chunk; sinks are
fed from THAT sync — ``emit`` receives plain-python row dicts built from data
the driver already fetched, so attaching any number of sinks adds zero
device→host transfers (tests/test_obs.py counts them). The legacy per-round
loop (core/server.py) feeds the same rows at round granularity.

Row schema (versioned — bump SCHEMA_VERSION on any incompatible change;
v2 added aa_clipped_max, the robustness layer's clip-screen activity; v3
added arrivals/staleness_mean/staleness_max, the deadline gate's per-round
activity — null whenever AsyncConfig is off; v4 added the checkpoint
telemetry triple to the footer — always present, zeros when checkpointing
is off):

  header row  {"v": 4, "kind": "header", "fields": [...], ...run metadata:
               algo / runtime / channel / num_clients / cohort_size / chunk /
               num_rounds / uplink_bytes (per-UplinkSpec byte breakdown from
               the comm schema) / backend}
  round row   {"v": 4, "kind": "round", "round": t, <ROW_FIELDS>}
  footer row  {"v": 4, "kind": "footer", "rounds": T, "stopped": bool,
               "alarms": [...],
               "checkpoint_save_ms": cumulative wall spent in saves
               (snapshot + serialize + commit, async or not),
               "checkpoint_bytes": cumulative committed bytes,
               "checkpoint_failures": saves that exhausted their I/O
               retries (the run continued; each also appears in "alarms"
               as a checkpoint_failed event, and a save overrunning its
               chunk's compute appears as checkpoint_stalled)}

Round-row fields (ROW_FIELDS):

  loss, grad_norm      — global objective / gradient norm at w^t
  rel_error            — ‖w−w*‖/‖w*‖ (null without a reference solve)
  theta_mean           — mean AA optimization gain across clients
  gram_cond_max/_mean  — AA Gram conditioning aggregates across clients (the
                         diagnostic that predicts FedOSAA divergence)
  aa_used_min          — fewest Gram eigen-directions surviving filtering on
                         any client (0 = column-filtering collapse)
  aa_clipped_max       — most history columns the clip_rtol byzantine screen
                         dropped on any client (0 = screen off or inactive;
                         persistent non-zero trips the aa_clipping_active
                         alarm)
  cohort_ess           — effective sample size 1/Σw² of the round's
                         aggregation weights (cohort draw concentration)
  comm_bytes           — this round's wire bytes (codec-exact)
  arrivals             — deadline-gated rounds: clients whose update landed
                         this round, fresh or buffered (null when async off)
  staleness_mean/_max  — mean / oldest buffer age over the round's landed
                         contributions (null when async off or nothing
                         landed; a climbing staleness_max trips the
                         staleness_runaway alarm)
  comm_bytes_total     — cumulative wire bytes
  round_wall_s         — wall-clock attributed to this round (the engine
                         divides each chunk's measured time equally over its
                         executed rounds; the loop measures per round)
  wall_time_s          — cumulative wall-clock seconds

JSONL files hold strict JSON: non-finite floats are serialized as null
(``scripts/check_metrics_jsonl.py`` validates emitted files).
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Protocol, runtime_checkable

import numpy as np

SCHEMA_VERSION = 4

#: canonical per-round row fields, in emission order (after "round")
ROW_FIELDS = (
    "loss",
    "grad_norm",
    "rel_error",
    "theta_mean",
    "gram_cond_max",
    "gram_cond_mean",
    "aa_used_min",
    "aa_clipped_max",
    "cohort_ess",
    "comm_bytes",
    "arrivals",
    "staleness_mean",
    "staleness_max",
    "comm_bytes_total",
    "round_wall_s",
    "wall_time_s",
)


def build_round_row(round_idx: int, metrics: "dict[str, float]", rel: float,
                    comm_total: float, round_wall_s: float,
                    wall_total_s: float) -> dict:
    """One versioned round row from a round's scalar metrics.

    ``metrics`` is the RoundMetrics fields as python floats (the engine and
    the loop both have them host-side after their metric sync); driver-side
    quantities (rel-error, cumulative comm/wall) ride alongside.
    """
    return {
        "v": SCHEMA_VERSION,
        "kind": "round",
        "round": int(round_idx),
        "loss": metrics["loss"],
        "grad_norm": metrics["grad_norm"],
        "rel_error": rel,
        "theta_mean": metrics["theta_mean"],
        "gram_cond_max": metrics["gram_cond_max"],
        "gram_cond_mean": metrics["gram_cond_mean"],
        "aa_used_min": metrics["aa_used_min"],
        "aa_clipped_max": metrics["aa_clipped_max"],
        "cohort_ess": metrics["cohort_ess"],
        "comm_bytes": metrics["comm_bytes"],
        "arrivals": metrics["arrivals"],
        "staleness_mean": metrics["staleness_mean"],
        "staleness_max": metrics["staleness_max"],
        "comm_bytes_total": comm_total,
        "round_wall_s": round_wall_s,
        "wall_time_s": wall_total_s,
    }


def build_footer(rounds: int, stopped: bool, alarms: "list[dict]",
                 checkpoint: dict | None = None) -> dict:
    """The versioned run footer. ``checkpoint`` is a CheckpointManager's
    ``telemetry()`` dict; the three fields are always emitted (zeros when no
    checkpointing ran) so v4 consumers never branch on presence."""
    ckpt = checkpoint or {}
    return {
        "v": SCHEMA_VERSION,
        "kind": "footer",
        "rounds": int(rounds),
        "stopped": bool(stopped),
        "alarms": alarms,
        "checkpoint_save_ms": float(ckpt.get("checkpoint_save_ms", 0.0)),
        "checkpoint_bytes": int(ckpt.get("checkpoint_bytes", 0)),
        "checkpoint_failures": int(ckpt.get("checkpoint_failures", 0)),
    }


@runtime_checkable
class MetricsSink(Protocol):
    """Where streamed rows go. ``open`` is called once with the run header,
    ``emit`` with each drained batch of round rows (one chunk's executed
    rounds on the engine path, one row on the loop path), ``close`` once with
    the footer. Implementations may expose ``stop_requested`` (checked after
    every emit) to request early stop at the next chunk boundary — the
    host-side twin of the engine's in-graph stop criteria."""

    def open(self, header: dict) -> None: ...
    def emit(self, rows: "list[dict]") -> None: ...
    def close(self, footer: dict) -> None: ...


class MemorySink:
    """Collects header/rows/footer in python lists (tests, notebooks)."""

    def __init__(self):
        self.header: dict | None = None
        self.rows: list[dict] = []
        self.footer: dict | None = None

    def open(self, header: dict) -> None:
        self.header = header

    def emit(self, rows: "list[dict]") -> None:
        self.rows.extend(rows)

    def close(self, footer: dict) -> None:
        self.footer = footer


class StdoutSink:
    """Prints one compact line per round (every ``every``-th row)."""

    def __init__(self, every: int = 1):
        self.every = max(1, int(every))

    def open(self, header: dict) -> None:
        print(f"[obs] run {header.get('algo', '?')} "
              f"runtime={header.get('runtime', '?')} "
              f"channel={header.get('channel', '?')} "
              f"chunk={header.get('chunk')}")

    def emit(self, rows: "list[dict]") -> None:
        for row in rows:
            if row["round"] % self.every:
                continue
            print(f"[obs] round={row['round']:4d} loss={row['loss']:.6e} "
                  f"|g|={row['grad_norm']:.3e} relerr={row['rel_error']:.3e} "
                  f"gcond={row['gram_cond_max']:.2e} "
                  f"comm={row['comm_bytes_total']:.3e}B "
                  f"wall={row['wall_time_s']:.2f}s")

    def close(self, footer: dict) -> None:
        print(f"[obs] done rounds={footer.get('rounds')} "
              f"stopped={footer.get('stopped')}")


def _jsonable(value):
    """Strict-JSON scalar: non-finite floats become null."""
    if isinstance(value, float) and not math.isfinite(value):
        return None
    return value


class JsonlSink:
    """Streams rows to a JSON-lines file: header, round rows, footer — one
    strict-JSON object per line (non-finite floats → null). The file handle
    stays open across emits so a crashed run still holds every drained chunk.
    """

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def _write(self, obj: dict) -> None:
        line = json.dumps(
            {k: _jsonable(v) for k, v in obj.items()}, allow_nan=False)
        self._f.write(line + "\n")

    def open(self, header: dict) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        self._f = open(self.path, "w")
        self._write(header)
        self._f.flush()

    def emit(self, rows: "list[dict]") -> None:
        for row in rows:
            self._write(row)
        self._f.flush()

    def close(self, footer: dict) -> None:
        if self._f is None:
            return
        self._write(footer)
        self._f.close()
        self._f = None


class LiveTap:
    """Sub-chunk visibility: a host callback invoked from INSIDE the compiled
    chunk via ``jax.debug.callback`` as each scan slot executes.

    OFF by default — the engine only inserts the callback when a tap is
    passed (``make_chunk_runner(..., tap=...)``), because a host callback in
    the scan body re-enters the host mid-chunk (exactly what the
    one-sync-per-chunk contract avoids). The tap observes the compiled
    math's own values, but inserting the callback can shift XLA's fusion
    choices by an ulp — tapped chunks match tapless ones at the engine's
    documented rtol 1e-6, not bit-exactly (tests/test_obs.py); leave the tap
    off for bit-reproducible runs. Rows carry the chunk-LOCAL slot index;
    non-live slots (past a stop / past n_live) are dropped.
    """

    def __init__(self, print_rows: bool = False):
        self.print_rows = print_rows
        self.rows: list[dict] = []

    def __call__(self, slot, metrics, rel, live) -> None:
        if not bool(np.asarray(live)):
            return
        row = {f: float(np.asarray(getattr(metrics, f)))
               for f in metrics._fields}
        row["slot"] = int(np.asarray(slot))
        row["rel_error"] = float(np.asarray(rel))
        self.rows.append(row)
        if self.print_rows:
            print(f"[obs:tap] slot={row['slot']} loss={row['loss']:.6e} "
                  f"relerr={row['rel_error']:.3e}")


def make_sink(spec: str) -> MetricsSink:
    """Parse a CLI sink spec: ``jsonl:<path>``, ``stdout[:every]``, ``memory``."""
    kind, _, arg = spec.partition(":")
    if kind == "jsonl":
        if not arg:
            raise ValueError("jsonl sink needs a path: jsonl:<path>")
        return JsonlSink(arg)
    if kind == "stdout":
        return StdoutSink(every=int(arg) if arg else 1)
    if kind == "memory":
        return MemorySink()
    raise ValueError(f"unknown sink spec {spec!r}; "
                     "choose jsonl:<path> | stdout[:every] | memory")


__all__ = [
    "ROW_FIELDS",
    "SCHEMA_VERSION",
    "JsonlSink",
    "LiveTap",
    "MemorySink",
    "MetricsSink",
    "StdoutSink",
    "build_footer",
    "build_round_row",
    "make_sink",
]
