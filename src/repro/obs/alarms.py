"""Health monitors: declarative alarm rules over streamed metric rows.

``AlarmMonitor`` IS a ``MetricsSink`` — attach it alongside the file/stdout
sinks and it evaluates every drained round row against its rules. A firing
rule logs a structured warning (one ``logging`` record with the rule name,
round, field, and observed value); a rule with ``action="stop"`` additionally
sets ``stop_requested``, which the drivers check at the next chunk/round
boundary and fold into the existing early-stop path — health alarms never
reach into the compiled graph.

Rule operators:

  gt / lt      — field compared against ``threshold`` (non-finite values
                 never satisfy gt/lt; use ``nonfinite`` for those)
  nonfinite    — field is nan/inf (divergence tripwire)
  no_improve   — field's best value has not improved by ``min_improve``
                 (relative) within the last ``window`` rounds (plateau
                 detector; needs ``window``+1 rows before it can fire)

``DEFAULT_RULES`` encode the failure modes PRs 4-6 actually hit: non-finite
loss (stop — the run is already garbage), AA Gram conditioning blowing past
1e12 (the divergence predictor), AA column filtering collapsing to zero used
directions (the extrapolation silently became vanilla FedAvg), and a
rel-error plateau (the run stopped making progress toward w*). PR 8 adds
aa_clipping_active: the clip_rtol byzantine screen (core/anderson.py) dropped
history columns this round — the monitor's per-rule cooldown turns a
persistently-active screen into a periodic warning (a one-off clip stays a
single log line) telling the operator some client's history is being
rejected as poisoned. staleness_runaway watches the deadline gate
(repro.robust.async_agg): a landed contribution older than 10 rounds means
the buffer is draining slower than it fills — the discounted fold is about
to stop paying for itself (the field is null/NaN when async is off, which
never fires a threshold op).
"""
from __future__ import annotations

import logging
import math
from dataclasses import dataclass, field

logger = logging.getLogger("repro.obs.alarms")

_OPS = ("gt", "lt", "nonfinite", "no_improve")
_ACTIONS = ("warn", "stop")


@dataclass(frozen=True)
class AlarmRule:
    """One declarative health check over a round-row field."""

    name: str
    field: str
    op: str
    threshold: float | None = None
    window: int = 20
    min_improve: float = 1e-3
    action: str = "warn"

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"rule {self.name!r}: op must be one of {_OPS}")
        if self.action not in _ACTIONS:
            raise ValueError(
                f"rule {self.name!r}: action must be one of {_ACTIONS}")
        if self.op in ("gt", "lt") and self.threshold is None:
            raise ValueError(f"rule {self.name!r}: {self.op} needs threshold")


DEFAULT_RULES = (
    AlarmRule("loss_nonfinite", "loss", "nonfinite", action="stop"),
    AlarmRule("gram_cond_blowup", "gram_cond_max", "gt", threshold=1e12),
    AlarmRule("aa_columns_collapsed", "aa_used_min", "lt", threshold=1.0),
    AlarmRule("rel_error_plateau", "rel_error", "no_improve",
              window=50, min_improve=1e-3),
    AlarmRule("aa_clipping_active", "aa_clipped_max", "gt", threshold=0.0),
    AlarmRule("staleness_runaway", "staleness_max", "gt", threshold=10.0),
)


def _is_finite(v) -> bool:
    return isinstance(v, (int, float)) and math.isfinite(v)


class AlarmMonitor:
    """MetricsSink that evaluates rules on every round row.

    ``events`` accumulates structured fire records; ``stop_requested`` turns
    True when a ``stop`` rule fires. Each rule fires at most once per
    ``cooldown`` rounds so a persistently-bad metric doesn't flood the log.
    """

    def __init__(self, rules=DEFAULT_RULES, cooldown: int = 25):
        self.rules = tuple(rules)
        self.cooldown = int(cooldown)
        self.events: list[dict] = []
        self.stop_requested = False
        self._last_fired: dict[str, int] = {}
        # per-rule rolling state for no_improve: (best_value, round_of_best)
        self._best: dict[str, tuple[float, int]] = {}

    # -- MetricsSink protocol -------------------------------------------
    def open(self, header: dict) -> None:
        pass

    def close(self, footer: dict) -> None:
        pass

    def emit(self, rows) -> None:
        for row in rows:
            if row.get("kind") != "round":
                continue
            for rule in self.rules:
                self._check(rule, row)

    # -- rule evaluation ------------------------------------------------
    def _check(self, rule: AlarmRule, row: dict) -> None:
        value = row.get(rule.field)
        t = row["round"]
        fired = False
        if rule.op == "nonfinite":
            fired = value is None or (
                isinstance(value, float) and not math.isfinite(value))
        elif rule.op == "gt":
            fired = _is_finite(value) and value > rule.threshold
        elif rule.op == "lt":
            fired = _is_finite(value) and value < rule.threshold
        elif rule.op == "no_improve":
            fired = self._check_plateau(rule, value, t)
        if not fired:
            return
        last = self._last_fired.get(rule.name)
        if last is not None and t - last < self.cooldown:
            return
        self._last_fired[rule.name] = t
        self._fire(rule, row, value)

    def _check_plateau(self, rule: AlarmRule, value, t: int) -> bool:
        if not _is_finite(value):
            return False
        best = self._best.get(rule.name)
        if best is None:
            self._best[rule.name] = (value, t)
            return False
        best_v, best_t = best
        if value < best_v * (1.0 - rule.min_improve):
            self._best[rule.name] = (value, t)
            return False
        return t - best_t >= rule.window

    def _fire(self, rule: AlarmRule, row: dict, value) -> None:
        event = {
            "rule": rule.name,
            "field": rule.field,
            "op": rule.op,
            "threshold": rule.threshold,
            "round": row["round"],
            "value": value,
            "action": rule.action,
        }
        self.events.append(event)
        logger.warning(
            "alarm %s: %s %s (threshold=%s) at round %d value=%s action=%s",
            rule.name, rule.field, rule.op, rule.threshold,
            row["round"], value, rule.action,
        )
        if rule.action == "stop":
            self.stop_requested = True


__all__ = ["DEFAULT_RULES", "AlarmMonitor", "AlarmRule"]
