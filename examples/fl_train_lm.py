"""End-to-end driver (deliverable b): federated training of a transformer LM
with FedOSAA — a few hundred aggregate steps of a ~5M-param smollm-family
model on CPU, comparing FedOSAA-SVRG against FedSVRG.

  PYTHONPATH=src python examples/fl_train_lm.py              # ~15 min CPU
  PYTHONPATH=src python examples/fl_train_lm.py --rounds 5   # quick check

Each round performs L=5 local steps + 1 AA step per client, so
--rounds 40 = 240 local gradient steps per client — 'a few hundred steps'.
On TPU the same driver scales to the full smollm-135m via --no-reduced
(see repro/launch/fl_train.py for the mesh-sharded path).
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + [
    "--arch", "smollm-135m", "--reduced",
    "--algo", "fedosaa_svrg", "--baseline", "fedsvrg",
] + sys.argv[1:]

from repro.launch.fl_train import main  # noqa: E402

if __name__ == "__main__":
    main()
