"""Full algorithm shoot-out (paper Figure 2): FedOSAA vs first- and
second-order FL methods under IID / imbalance / label-skew partitions.

  PYTHONPATH=src python examples/fl_logreg_comparison.py [--scheme label_skew]
"""
import argparse

from repro.core import AlgoHParams, run_federated, solve_reference
from repro.data import heterogeneity_score, make_binary_classification, partition
from repro.models.logreg import make_logreg_problem

ALGOS = ["fedavg", "fedsvrg", "scaffold", "lbfgs", "giant",
         "newton_gmres", "fedosaa_svrg", "fedosaa_scaffold"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheme", default="iid",
                    choices=["iid", "imbalance", "label_skew"])
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients active per round (<1.0 samples "
                         "a ⌈pK⌉-client cohort each round)")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="explicit per-round cohort size C (overrides "
                         "--participation; non-sampled clients' state stays "
                         "frozen); 0 = derive from --participation")
    ap.add_argument("--comm-codec", default="identity",
                    help="wire-compression channel (repro/comm): identity | "
                         "bf16 | int8 | topk[:ratio] ...")
    ap.add_argument("--round-chunk", type=int, default=0,
                    help="run this many rounds per donated lax.scan jit "
                         "(core/engine.py); 0 = per-round loop")
    args = ap.parse_args()

    X, y = make_binary_classification("covtype", n=10_000, seed=0)
    clients = partition(X, y, num_clients=10, scheme=args.scheme)
    print(f"scheme={args.scheme}  heterogeneity={heterogeneity_score(clients):.3f}")
    problem = make_logreg_problem(clients, gamma=1e-3)
    w_star = solve_reference(problem)

    eta = 0.5 if args.scheme == "label_skew" else 1.0
    hp = AlgoHParams(eta=eta, local_epochs=10,
                     participation=args.participation,
                     cohort_size=args.cohort_size or None)
    for algo in ALGOS:
        h = run_federated(problem, algo, hp, args.rounds, w_star=w_star,
                          channel=args.comm_codec,
                          chunk=args.round_chunk or None)
        print(h.summary())


if __name__ == "__main__":
    main()
