"""Quickstart: FedOSAA vs FedSVRG on federated logistic regression.

  PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline result in ~1 minute on CPU: one Anderson-
acceleration step after the SVRG local epochs turns a first-order method into
a Newton-GMRES-class method, at identical communication cost.
"""
import jax

from repro.core import AlgoHParams, run_federated, solve_reference
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem


def main():
    # federated setup: 10 clients, IID split of a covtype-like dataset
    X, y = make_binary_classification("covtype", n=10_000, seed=0)
    clients = partition(X, y, num_clients=10, scheme="iid")
    problem = make_logreg_problem(clients, gamma=1e-3)
    w_star = solve_reference(problem)          # reference minimizer

    hp = AlgoHParams(eta=1.0, local_epochs=10)  # paper defaults
    print(f"{'round':>5} | {'FedSVRG':>12} | {'FedOSAA-SVRG':>12}   (relative error)")
    h_svrg = run_federated(problem, "fedsvrg", hp, 15, w_star=w_star)
    h_osaa = run_federated(problem, "fedosaa_svrg", hp, 15, w_star=w_star)
    for t in range(len(h_svrg.rounds)):
        print(f"{t:5d} | {h_svrg.rel_error[t]:12.3e} | {h_osaa.rel_error[t]:12.3e}")
    print(f"\nSame communication (2d floats/round), same local gradient count "
          f"(L+1={hp.local_epochs + 1}):")
    print(f"  FedSVRG      final rel-err: {h_svrg.rel_error[-1]:.3e}")
    print(f"  FedOSAA-SVRG final rel-err: {h_osaa.rel_error[-1]:.3e}")


if __name__ == "__main__":
    main()
