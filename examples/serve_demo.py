"""Serving demo: batched prefill + decode with KV caches / SSM states for any
assigned architecture (reduced variant on CPU).

  PYTHONPATH=src python examples/serve_demo.py --arch mamba2-2.7b --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.decoder import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))

    B, P, N = args.batch, args.prompt_len, args.new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    last_logits, caches = jax.jit(
        lambda p, t: model.prefill(p, t, None, cache_len=P + N)
    )(params, prompts)
    print(f"prefill[{B}x{P}] in {time.time()-t0:.2f}s")

    dec = jax.jit(model.decode_step)
    tok = jnp.argmax(last_logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(N - 1):
        pos = jnp.full((B, 1), P + i, jnp.int32)
        logits, caches = dec(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {N-1} tokens/seq in {dt:.2f}s "
          f"({B*(N-1)/max(dt,1e-9):.1f} tok/s batch throughput)")
    print("sample token ids:", out[0].tolist())


if __name__ == "__main__":
    main()
