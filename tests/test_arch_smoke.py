"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated in its REDUCED variant (2 layers,
d_model ≤ 256, ≤ 4 experts) and runs, on CPU:
  * one forward pass        -> logits shape + finite
  * one train step (SGD on the LM loss)  -> loss decreases-or-equal, no NaNs
  * prefill + a few decode steps         -> consistency with full forward
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.decoder import build_model

BATCH, SEQ = 2, 64


def make_batch(cfg, rng):
    k1, k2 = jax.random.split(jax.random.PRNGKey(rng))
    tokens = jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.frontend_tokens:
        batch["embeds"] = jax.random.normal(
            k2, (BATCH, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.fixture(params=ARCHS, scope="module")
def arch(request):
    cfg = get_arch(request.param).reduced()
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    return cfg, model, params


class TestForward:
    def test_logits_shape_and_finite(self, arch):
        cfg, model, params = arch
        batch = make_batch(cfg, 0)
        logits, aux = jax.jit(model.forward)(params, batch["tokens"], batch.get("embeds"))
        assert logits.shape == (BATCH, SEQ, cfg.eff_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_loss_scalar_finite(self, arch):
        cfg, model, params = arch
        batch = make_batch(cfg, 1)
        l = jax.jit(model.loss)(params, batch)
        assert l.shape == ()
        assert np.isfinite(float(l))


class TestTrainStep:
    def test_one_sgd_step_no_nans(self, arch):
        cfg, model, params = arch
        batch = make_batch(cfg, 2)

        @jax.jit
        def step(p):
            l, g = jax.value_and_grad(model.loss)(p, batch)
            p2 = jax.tree.map(lambda w, gw: w - 0.01 * gw.astype(w.dtype), p, g)
            return l, p2

        l0, params2 = step(params)
        l1, _ = step(params2)
        assert np.isfinite(float(l0)) and np.isfinite(float(l1))
        for leaf in jax.tree.leaves(params2):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
        # one step on the same batch should not blow the loss up
        assert float(l1) < float(l0) * 1.5


class TestDecode:
    def test_prefill_then_decode_matches_forward(self, arch):
        """Teacher-forced decode after prefill must reproduce the full
        forward's next-token logits (the KV-cache/SSM-state correctness
        test). Checked at f32 tolerance on the reduced config."""
        cfg, model, params = arch
        if cfg.frontend_tokens:
            pytest.skip("structural: frontend archs prefill from embeds, so "
                        "token-only decode cannot reproduce the forward pass")
        if cfg.family == "moe":
            # capacity routing is non-causal across the batch: strict
            # teacher-forced equivalence does not hold by construction.
            # Dropless-decode correctness is covered by test_moe_dropless_*.
            pytest.skip("structural: capacity-MoE routing is batch-global, "
                        "so teacher-forced decode equivalence cannot hold")
        tokens = jax.random.randint(
            jax.random.PRNGKey(3), (BATCH, SEQ), 0, cfg.vocab_size, jnp.int32
        )
        prefix_len = SEQ - 4
        logits_full, _ = jax.jit(model.forward)(params, tokens, None)

        last, caches = jax.jit(lambda p, t: model.prefill(p, t, None, cache_len=SEQ))(params, tokens[:, :prefix_len])
        np.testing.assert_allclose(
            np.asarray(last, np.float32),
            np.asarray(logits_full[:, prefix_len - 1], np.float32),
            rtol=2e-2, atol=2e-2,
        )
        dec = jax.jit(model.decode_step)
        for i in range(prefix_len, SEQ):
            pos = jnp.full((BATCH, 1), i, jnp.int32)
            logits_step, caches = dec(params, caches, tokens[:, i:i + 1], pos)
            np.testing.assert_allclose(
                np.asarray(logits_step, np.float32),
                np.asarray(logits_full[:, i], np.float32),
                rtol=2e-2, atol=2e-2,
            )

    def test_decode_from_scratch_runs(self, arch):
        cfg, model, params = arch
        caches = jax.jit(lambda: model.init_caches(BATCH, 32))()
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        dec = jax.jit(model.decode_step)
        for i in range(3):
            pos = jnp.full((BATCH, 1), i, jnp.int32)
            logits, caches = dec(params, caches, tok, pos)
            assert logits.shape == (BATCH, cfg.eff_vocab)
            assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {get_arch(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "vlm", "ssm", "hybrid", "audio"}


def test_param_counts_plausible():
    """Analytic param counts should be within ~35% of the nominal model size
    (names encode sizes: 135m, 17b-a16e(→~100B total), 76b, 2.7b, ...)."""
    expect = {
        "smollm-135m": 135e6,
        "mamba2-2.7b": 2.7e9,
        "qwen3-4b": 4e9,
        "granite-20b": 20e9,
        "minicpm-2b": 2.4e9,
        "zamba2-7b": 7e9,
    }
    for name, n in expect.items():
        got = get_arch(name).param_count()
        assert 0.5 * n < got < 1.8 * n, (name, got, n)
