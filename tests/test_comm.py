"""repro/comm: codec correctness, byte accounting, channel parsing, and the
end-to-end compression behaviors (error feedback, difference coding) on the
FL round API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    Bf16Codec,
    CommChannel,
    IdentityCodec,
    Int8SRCodec,
    TopKCodec,
    make_channel,
    parse_codec,
)
from repro.core import (
    AlgoHParams,
    comm_bytes_per_round,
    comm_floats_per_round,
    init_state,
    make_round_fn,
    run_federated,
    solve_reference,
)
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem


@pytest.fixture(scope="module")
def logreg():
    X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    wstar = solve_reference(prob, iters=50)
    return prob, wstar


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

class TestCodecs:
    def test_identity_roundtrip_lossless(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(137), jnp.float32)
        np.testing.assert_array_equal(np.asarray(IdentityCodec().roundtrip(x)),
                                      np.asarray(x))

    def test_bf16_roundtrip_error_bound(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal(512), jnp.float32)
        out = Bf16Codec().roundtrip(x)
        # bf16 has 8 mantissa bits: relative error < 2^-8
        np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                                   rtol=2.0 ** -8, atol=1e-30)

    @pytest.mark.parametrize("n", [31, 256, 1000])
    def test_int8_roundtrip_error_bounded_by_chunk_scale(self, n):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        codec = Int8SRCodec(chunk=64)
        out = codec.roundtrip(x, jax.random.PRNGKey(0))
        err = np.abs(np.asarray(out) - np.asarray(x))
        x_np = np.asarray(x)
        for c0 in range(0, n, 64):
            chunk = x_np[c0:c0 + 64]
            scale = np.abs(chunk).max() / 127.0
            assert err[c0:c0 + 64].max() <= scale + 1e-7

    def test_int8_sr_unbiased(self):
        """E[roundtrip(x)] = x: the mean over many independent draws converges
        at the Monte-Carlo rate to x (this is what lets quantized SVRG keep
        its unbiased gradient estimates)."""
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal(256), jnp.float32)
        codec = Int8SRCodec()
        draws = 400
        outs = jax.vmap(lambda k: codec.roundtrip(x, k))(
            jax.random.split(jax.random.PRNGKey(0), draws))
        mean = np.asarray(jnp.mean(outs, axis=0))
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        # per-element MC std is < scale; 5 sigma of the mean estimator
        assert np.max(np.abs(mean - np.asarray(x))) < 5 * scale / np.sqrt(draws)

    def test_topk_keeps_largest_by_magnitude(self):
        x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3], jnp.float32)
        out = np.asarray(TopKCodec(ratio=0.25).roundtrip(x))     # k = 2
        np.testing.assert_array_equal(out, [0, -5.0, 0, 3.0, 0, 0, 0, 0])

    def test_topk_ratio_validation(self):
        with pytest.raises(ValueError, match="ratio"):
            TopKCodec(ratio=0.0)

    def test_wire_bytes(self):
        shape = (1000,)
        assert IdentityCodec().wire_bytes(shape) == 4000
        assert Bf16Codec().wire_bytes(shape) == 2000
        # 1000 values @1B + 4 chunks(256) @4B
        assert Int8SRCodec().wire_bytes(shape) == 1000 + 4 * 4
        # k = ceil(0.01*1000) = 10 pairs of (f32, int32)
        assert TopKCodec(ratio=0.01).wire_bytes(shape) == 80

    def test_tree_roundtrip_distinct_draws_per_leaf(self):
        """Two identical leaves must not receive identical quantization noise
        (the leaf index is folded into the rng)."""
        x = jnp.asarray(np.random.default_rng(3).standard_normal(300), jnp.float32)
        tree = {"a": x, "b": x}
        out = Int8SRCodec().tree_roundtrip(tree, jax.random.PRNGKey(0))
        assert not np.array_equal(np.asarray(out["a"]), np.asarray(out["b"]))


# ---------------------------------------------------------------------------
# channel construction + byte accounting
# ---------------------------------------------------------------------------

class TestChannel:
    def test_parse_specs(self):
        assert make_channel(None).is_identity
        assert make_channel("identity").is_identity
        ch = make_channel("int8")
        assert isinstance(ch.up, Int8SRCodec) and ch.error_feedback
        assert not make_channel("int8+noef").error_feedback
        assert make_channel("bf16").error_feedback is False
        ch = make_channel("topk:0.05/bf16")
        assert isinstance(ch.up, TopKCodec) and ch.up.ratio == 0.05
        assert isinstance(ch.down, Bf16Codec)
        assert isinstance(make_channel("int8:128").up, Int8SRCodec)
        assert make_channel("int8:128").up.chunk == 128

    def test_unknown_codec_raises(self):
        with pytest.raises(ValueError, match="unknown codec"):
            make_channel("fp8")
        with pytest.raises(ValueError, match="unknown codec"):
            parse_codec("zstd")

    def test_stochastic_downlink_rejected(self):
        with pytest.raises(ValueError, match="stochastic"):
            make_channel("bf16/int8")

    def test_delta_only_downlink_rejected(self):
        """The downlink carries absolute state (w^t, ∇f); sparsifying it
        floors convergence (measured rel-err 1.1 vs 2.7e-3) — reject it."""
        with pytest.raises(ValueError, match="delta-only"):
            make_channel("bf16/topk:0.1")

    def test_channel_passthrough(self):
        ch = make_channel("int8")
        assert make_channel(ch) is ch

    def test_delta_only_routing(self):
        """topk applies to delta uplinks only; absolute-state (aux) uploads
        fall back to fp32 — and the byte accounting charges them fp32."""
        ch = make_channel("topk:0.1")
        assert isinstance(ch.up_codec("delta"), TopKCodec)
        assert isinstance(ch.up_codec("aux"), IdentityCodec)
        tree = jnp.zeros(100)
        assert ch.uplink_bytes(tree, kind="aux") == 400
        assert ch.uplink_bytes(tree, kind="delta") == 80

    def test_bytes_per_round_identity_matches_floats(self):
        d = 54
        params = jnp.zeros(d)
        for algo in ("fedavg", "fedsvrg", "scaffold", "fedosaa_svrg", "giant"):
            assert comm_bytes_per_round(algo, params) == pytest.approx(
                4 * comm_floats_per_round(algo, d))
        assert comm_bytes_per_round("giant", params, line_search=True) == \
            pytest.approx(4 * comm_floats_per_round("giant", d, line_search=True))

    def test_bytes_per_round_codec_exact(self):
        d = 54
        params = jnp.zeros(d)
        # fedsvrg = 2 uplink units: delta + gradient
        assert comm_bytes_per_round("fedsvrg", params, "bf16") == 2 * 2 * d
        assert comm_bytes_per_round("fedsvrg", params, "int8") == 2 * (d + 4)
        # topk: delta unit sparsified (k=3 pairs), gradient unit fp32
        k = TopKCodec(ratio=0.05).k_for(d)
        assert comm_bytes_per_round("fedsvrg", params, "topk:0.05") == \
            8 * k + 4 * d
        # fedavg = 1 delta unit only
        assert comm_bytes_per_round("fedavg", params, "topk:0.05") == 8 * k
        # line-search extra broadcast pays the DOWNLINK codec
        assert comm_bytes_per_round("giant", params, "int8/bf16",
                                    line_search=True) == 2 * (d + 4) + 2 * d


# ---------------------------------------------------------------------------
# end-to-end: channels on the FL round API
# ---------------------------------------------------------------------------

class TestChannelRounds:
    def test_identity_channel_bit_identical(self, logreg):
        """channel=None and channel='identity' add nothing to the graph."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=5)
        h0 = run_federated(prob, "fedosaa_svrg", hp, 5, w_star=wstar)
        h1 = run_federated(prob, "fedosaa_svrg", hp, 5, w_star=wstar,
                           channel="identity")
        np.testing.assert_array_equal(h0.loss, h1.loss)
        np.testing.assert_array_equal(h0.comm_bytes, h1.comm_bytes)

    @pytest.mark.parametrize("spec", ["bf16", "int8", "topk:0.25"])
    def test_fedosaa_converges_under_compression(self, logreg, spec):
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h = run_federated(prob, "fedosaa_svrg", hp, 20, w_star=wstar,
                          channel=spec)
        assert h.rel_error[-1] < 1e-2, spec
        # compressed channels must actually ship fewer bytes than fp32
        h0 = run_federated(prob, "fedosaa_svrg", hp, 1)
        assert h.comm_bytes[-1] / 20 < h0.comm_bytes[-1]

    def test_int8_diff_coding_removes_gradient_noise_floor(self, logreg):
        """Without the difference-coded aux uplink, SR noise on the O(1)
        local gradients leaves a floor; with it, int8 tracks fp32. Guard the
        mechanism by asserting int8 keeps converging well past the floor a
        naive quantizer stalls at (measured ~1e-3 on this problem)."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h = run_federated(prob, "fedosaa_svrg", hp, 30, w_star=wstar,
                          channel="int8")
        assert h.rel_error[-1] < 2e-4

    def test_error_feedback_state_carried_and_nonzero(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=3)
        ch = make_channel("topk:0.1")
        state = init_state(prob, jax.random.PRNGKey(0), hp, ch)
        assert state.comm is not None
        assert "ef" in state.comm["delta"]
        fn = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, ch))
        state, _ = fn(state)
        ef = np.asarray(jax.tree.leaves(state.comm["delta"]["ef"])[0])
        assert ef.shape[0] == prob.clients.num_clients
        assert np.abs(ef).max() > 0          # topk drops mass -> residual
        # aux leg of a delta-only codec is fp32: no aux state
        assert state.comm["aux"] == {}

    def test_algo_aware_state_allocation(self, logreg):
        """init_state(algo=...) skips buffers the round function never reads:
        Newton-type rounds are comm-stateless, the AVG family has no aux
        uplink — at LM scale each skipped buffer is a K×d array."""
        prob, _ = logreg
        ch = make_channel("int8")
        for algo in ("giant", "newton_gmres", "dane"):
            s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch, algo)
            assert s.comm is None, algo
        s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch, "fedavg")
        assert "ef" in s.comm["delta"] and s.comm["aux"] == {}
        s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch,
                       "fedosaa_svrg")
        assert "ref" in s.comm["aux"]
        # a stateless-algo state still runs its round end-to-end
        hp = AlgoHParams(local_epochs=2)
        s = init_state(prob, jax.random.PRNGKey(0), hp, ch, "giant")
        _, m = jax.jit(make_round_fn("giant", prob, hp, ch))(s)
        assert np.isfinite(float(m.loss))

    def test_noef_channel_carries_no_ef_state(self, logreg):
        prob, _ = logreg
        state = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(),
                           make_channel("topk:0.1+noef"))
        assert state.comm is None
        # int8+noef still needs the aux diff-coding reference
        state = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(),
                           make_channel("int8+noef"))
        assert state.comm is not None
        assert "ef" not in state.comm["delta"] and state.comm["delta"] == {}
        assert "ref" in state.comm["aux"]

    def test_comm_bytes_metric_matches_static_accounting(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=3)
        p0 = prob.init(jax.random.PRNGKey(0))
        for spec in (None, "bf16", "int8", "topk:0.1"):
            for algo in ("fedavg", "fedsvrg", "scaffold"):
                ch = make_channel(spec)
                fn = jax.jit(make_round_fn(algo, prob, hp, ch))
                _, m = fn(init_state(prob, jax.random.PRNGKey(0), hp, ch))
                assert float(m.comm_bytes) == pytest.approx(
                    comm_bytes_per_round(algo, p0, ch)), (spec, algo)

    def test_history_floats_compat_column(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=3)
        h = run_federated(prob, "fedsvrg", hp, 3)
        np.testing.assert_allclose(h.comm_floats, h.comm_bytes / 4.0)
        assert h.channel == "identity"
