"""repro/comm: codec correctness, byte accounting, channel parsing, the
declarative uplink schemas, and the end-to-end compression behaviors (error
feedback, difference coding — incl. the stateful Newton-family wire) on the
FL round API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degraded single-example mode; see tests/_hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.comm import (
    Bf16Codec,
    CommChannel,
    IdentityCodec,
    Int8SRCodec,
    TopKCodec,
    UplinkSpec,
    make_channel,
    parse_codec,
    validate_schema,
)
from repro.comm.schema import DELTA_UPLINK, DIR_UPLINK, GRAD_UPLINK
from repro.core import (
    COMM_TABLE,
    UPLINK_SCHEMAS,
    AlgoHParams,
    comm_bytes_per_round,
    comm_floats_per_round,
    init_state,
    make_round_fn,
    run_federated,
    solve_reference,
)
from repro.core.algorithms import ALGORITHMS, CrossClientReduce
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem


@pytest.fixture(scope="module")
def logreg():
    X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    wstar = solve_reference(prob, iters=50)
    return prob, wstar


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

class TestCodecs:
    def test_identity_roundtrip_lossless(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(137), jnp.float32)
        np.testing.assert_array_equal(np.asarray(IdentityCodec().roundtrip(x)),
                                      np.asarray(x))

    def test_bf16_roundtrip_error_bound(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal(512), jnp.float32)
        out = Bf16Codec().roundtrip(x)
        # bf16 has 8 mantissa bits: relative error < 2^-8
        np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                                   rtol=2.0 ** -8, atol=1e-30)

    @pytest.mark.parametrize("n", [31, 256, 1000])
    def test_int8_roundtrip_error_bounded_by_chunk_scale(self, n):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        codec = Int8SRCodec(chunk=64)
        out = codec.roundtrip(x, jax.random.PRNGKey(0))
        err = np.abs(np.asarray(out) - np.asarray(x))
        x_np = np.asarray(x)
        for c0 in range(0, n, 64):
            chunk = x_np[c0:c0 + 64]
            scale = np.abs(chunk).max() / 127.0
            assert err[c0:c0 + 64].max() <= scale + 1e-7

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 2000), chunk=st.sampled_from([64, 128, 256]),
           scale_exp=st.integers(-6, 6), seed=st.integers(0, 999))
    def test_property_int8_sr_unbiased(self, n, chunk, scale_exp, seed):
        """E[roundtrip(x)] = x for random shapes, chunk sizes and magnitude
        scales: the mean over many independent draws converges at the
        Monte-Carlo rate to x (this is what lets quantized SVRG keep its
        unbiased gradient estimates)."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n) * 10.0 ** scale_exp,
                        jnp.float32)
        codec = Int8SRCodec(chunk=chunk)
        draws = 400
        outs = jax.vmap(lambda k: codec.roundtrip(x, k))(
            jax.random.split(jax.random.PRNGKey(seed), draws))
        mean = np.asarray(jnp.mean(outs, axis=0))
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        # per-element MC std is < scale; 5 sigma of the mean estimator
        assert np.max(np.abs(mean - np.asarray(x))) < 5 * scale / np.sqrt(draws)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 4096), ratio=st.floats(0.01, 0.9),
           scale_exp=st.integers(-4, 4), seed=st.integers(0, 999))
    def test_property_topk_error_feedback_residual_contracts(
            self, n, ratio, scale_exp, seed):
        """The EF residual of one top-k uplink step contracts: dropping
        everything but the k largest-magnitude entries leaves
        ‖e‖² ≤ (1 − k/n)·‖u‖² (Stich et al.'s δ-contraction — the property
        that makes EF-topk converge to the exact optimum)."""
        rng = np.random.default_rng(seed)
        u = jnp.asarray(rng.standard_normal(n) * 10.0 ** scale_exp,
                        jnp.float32)
        codec = TopKCodec(ratio=ratio)
        e = np.asarray(u - codec.roundtrip(u), np.float64)
        u64 = np.asarray(u, np.float64)
        k = codec.k_for(n)
        assert np.sum(e ** 2) <= (1 - k / n) * np.sum(u64 ** 2) + 1e-6

    def test_topk_keeps_largest_by_magnitude(self):
        x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3], jnp.float32)
        out = np.asarray(TopKCodec(ratio=0.25).roundtrip(x))     # k = 2
        np.testing.assert_array_equal(out, [0, -5.0, 0, 3.0, 0, 0, 0, 0])

    def test_topk_ratio_validation(self):
        with pytest.raises(ValueError, match="ratio"):
            TopKCodec(ratio=0.0)

    def test_wire_bytes(self):
        shape = (1000,)
        assert IdentityCodec().wire_bytes(shape) == 4000
        assert Bf16Codec().wire_bytes(shape) == 2000
        # 1000 values @1B + 4 chunks(256) @4B
        assert Int8SRCodec().wire_bytes(shape) == 1000 + 4 * 4
        # k = ceil(0.01*1000) = 10 pairs of (f32, int32)
        assert TopKCodec(ratio=0.01).wire_bytes(shape) == 80

    def test_tree_roundtrip_distinct_draws_per_leaf(self):
        """Two identical leaves must not receive identical quantization noise
        (the leaf index is folded into the rng)."""
        x = jnp.asarray(np.random.default_rng(3).standard_normal(300), jnp.float32)
        tree = {"a": x, "b": x}
        out = Int8SRCodec().tree_roundtrip(tree, jax.random.PRNGKey(0))
        assert not np.array_equal(np.asarray(out["a"]), np.asarray(out["b"]))


# ---------------------------------------------------------------------------
# channel construction + byte accounting
# ---------------------------------------------------------------------------

class TestChannel:
    def test_parse_specs(self):
        assert make_channel(None).is_identity
        assert make_channel("identity").is_identity
        ch = make_channel("int8")
        assert isinstance(ch.up, Int8SRCodec) and ch.error_feedback
        assert not make_channel("int8+noef").error_feedback
        assert make_channel("bf16").error_feedback is False
        ch = make_channel("topk:0.05/bf16")
        assert isinstance(ch.up, TopKCodec) and ch.up.ratio == 0.05
        assert isinstance(ch.down, Bf16Codec)
        assert isinstance(make_channel("int8:128").up, Int8SRCodec)
        assert make_channel("int8:128").up.chunk == 128

    def test_unknown_codec_raises(self):
        with pytest.raises(ValueError, match="unknown codec"):
            make_channel("fp8")
        with pytest.raises(ValueError, match="unknown codec"):
            parse_codec("zstd")

    def test_stochastic_downlink_rejected(self):
        with pytest.raises(ValueError, match="stochastic"):
            make_channel("bf16/int8")

    def test_delta_only_downlink_rejected(self):
        """The downlink carries absolute state (w^t, ∇f); sparsifying it
        floors convergence (measured rel-err 1.1 vs 2.7e-3) — reject it."""
        with pytest.raises(ValueError, match="delta-only"):
            make_channel("bf16/topk:0.1")

    def test_channel_passthrough(self):
        ch = make_channel("int8")
        assert make_channel(ch) is ch

    def test_delta_only_routing(self):
        """topk applies to delta uplinks only; absolute-state (aux) uploads
        fall back to fp32 — and the byte accounting charges them fp32."""
        ch = make_channel("topk:0.1")
        assert isinstance(ch.up_codec("delta"), TopKCodec)
        assert isinstance(ch.up_codec("aux"), IdentityCodec)
        tree = jnp.zeros(100)
        assert ch.uplink_bytes(tree, kind="aux") == 400
        assert ch.uplink_bytes(tree, kind="delta") == 80

    def test_bytes_per_round_identity_matches_floats(self):
        d = 54
        params = jnp.zeros(d)
        for algo in ("fedavg", "fedsvrg", "scaffold", "fedosaa_svrg", "giant"):
            assert comm_bytes_per_round(algo, params) == pytest.approx(
                4 * comm_floats_per_round(algo, d))
        assert comm_bytes_per_round("giant", params, line_search=True) == \
            pytest.approx(4 * comm_floats_per_round("giant", d, line_search=True))

    def test_bytes_per_round_codec_exact(self):
        d = 54
        params = jnp.zeros(d)
        # fedsvrg = 2 uplink units: delta + gradient
        assert comm_bytes_per_round("fedsvrg", params, "bf16") == 2 * 2 * d
        assert comm_bytes_per_round("fedsvrg", params, "int8") == 2 * (d + 4)
        # topk: delta unit sparsified (k=3 pairs), gradient unit fp32
        k = TopKCodec(ratio=0.05).k_for(d)
        assert comm_bytes_per_round("fedsvrg", params, "topk:0.05") == \
            8 * k + 4 * d
        # fedavg = 1 delta unit only
        assert comm_bytes_per_round("fedavg", params, "topk:0.05") == 8 * k
        # giant's direction uplink is kind="delta": sparsifiable, while its
        # gradient leg pays fp32 under a delta-only codec
        assert comm_bytes_per_round("giant", params, "topk:0.05") == \
            8 * k + 4 * d
        # line-search extra broadcast pays the DOWNLINK codec
        assert comm_bytes_per_round("giant", params, "int8/bf16",
                                    line_search=True) == 2 * (d + 4) + 2 * d


# ---------------------------------------------------------------------------
# declarative uplink schemas
# ---------------------------------------------------------------------------

class TestUplinkSchemas:
    def test_every_algorithm_declares_a_schema(self):
        assert set(UPLINK_SCHEMAS) == set(ALGORITHMS)

    def test_schema_lengths_match_table1_float_units(self):
        """The schema IS the byte accounting: one model-sized uplink record
        per Table 1 float unit, so the identity channel reproduces the
        historical counters exactly."""
        for algo, schema in UPLINK_SCHEMAS.items():
            assert len(schema) == COMM_TABLE[algo].float_units, algo

    def test_schemas_are_statically_valid(self):
        for algo, schema in UPLINK_SCHEMAS.items():
            assert validate_schema(schema) == schema
            # every record is stateful: no algorithm opts out of the
            # carried-state wire (the regression this PR exists to prevent)
            assert all(s.stateful for s in schema), algo

    def test_validate_schema_rejects_collisions(self):
        dup_tag = UplinkSpec("grad", "aux", False, True, 999)
        with pytest.raises(ValueError, match="duplicate uplink tags"):
            validate_schema((GRAD_UPLINK, dup_tag))
        dup_fold = UplinkSpec("other", "aux", False, True, GRAD_UPLINK.fold)
        with pytest.raises(ValueError, match="duplicate rng folds"):
            validate_schema((GRAD_UPLINK, dup_fold))
        with pytest.raises(ValueError, match="unknown kind"):
            validate_schema((UplinkSpec("x", "sketch", False, True, 7),))

    def test_state_buffers_policy(self):
        """The channel decides which buffers each declared uplink carries:
        EF for lossy codecs with error feedback on, a diff-coding reference
        for absolute-state (aux) uploads, nothing for identity wires."""
        int8 = make_channel("int8")
        assert int8.state_buffers(GRAD_UPLINK) == ("ef", "ref")
        assert int8.state_buffers(DELTA_UPLINK) == ("ef",)
        assert int8.state_buffers(DIR_UPLINK) == ("ef",)
        noef = make_channel("int8+noef")
        assert noef.state_buffers(GRAD_UPLINK) == ("ref",)
        assert noef.state_buffers(DIR_UPLINK) == ()
        topk = make_channel("topk:0.1")        # delta-only: aux rides fp32
        assert topk.state_buffers(GRAD_UPLINK) == ()
        assert topk.state_buffers(DIR_UPLINK) == ("ef",)
        assert make_channel(None).state_buffers(DELTA_UPLINK) == ()
        stateless = UplinkSpec("scalar", "delta", False, False, 105)
        assert int8.state_buffers(stateless) == ()

    def test_uplink_anchor_must_match_declaration(self):
        R = CrossClientReduce(make_channel("bf16"))
        stacked = jnp.zeros((4, 8))
        rngs = jax.random.split(jax.random.PRNGKey(0), 4)
        with pytest.raises(ValueError, match="anchored"):
            R.uplink(stacked, rngs, DELTA_UPLINK, anchor=None)
        with pytest.raises(ValueError, match="anchored"):
            R.uplink(stacked, rngs, GRAD_UPLINK, anchor=jnp.zeros(8))

    def test_uplink_leaves_undeclared_tags_untouched(self):
        """A round that never uplinks a tag must pass its buffers through
        unchanged (the DEFAULT_SCHEMA union allocates tags some algorithms
        never touch)."""
        R = CrossClientReduce(make_channel("int8"))
        stacked = jnp.ones((4, 8))
        rngs = jax.random.split(jax.random.PRNGKey(0), 4)
        state = {"dir": {"ef": jnp.full((4, 8), 7.0)},
                 "grad": {"ef": jnp.zeros((4, 8)), "ref": jnp.zeros((4, 8))}}
        _, new_state = R.uplink(stacked, rngs, GRAD_UPLINK, state=state)
        np.testing.assert_array_equal(np.asarray(new_state["dir"]["ef"]),
                                      np.asarray(state["dir"]["ef"]))
        assert np.abs(np.asarray(new_state["grad"]["ref"])).max() > 0


# ---------------------------------------------------------------------------
# end-to-end: channels on the FL round API
# ---------------------------------------------------------------------------

class TestChannelRounds:
    def test_identity_channel_bit_identical(self, logreg):
        """channel=None and channel='identity' add nothing to the graph."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=5)
        h0 = run_federated(prob, "fedosaa_svrg", hp, 5, w_star=wstar)
        h1 = run_federated(prob, "fedosaa_svrg", hp, 5, w_star=wstar,
                           channel="identity")
        np.testing.assert_array_equal(h0.loss, h1.loss)
        np.testing.assert_array_equal(h0.comm_bytes, h1.comm_bytes)

    @pytest.mark.parametrize("spec", ["bf16", "int8", "topk:0.25"])
    def test_fedosaa_converges_under_compression(self, logreg, spec):
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h = run_federated(prob, "fedosaa_svrg", hp, 20, w_star=wstar,
                          channel=spec)
        assert h.rel_error[-1] < 1e-2, spec
        # compressed channels must actually ship fewer bytes than fp32
        h0 = run_federated(prob, "fedosaa_svrg", hp, 1)
        assert h.comm_bytes[-1] / 20 < h0.comm_bytes[-1]

    def test_int8_diff_coding_removes_gradient_noise_floor(self, logreg):
        """Without the difference-coded aux uplink, SR noise on the O(1)
        local gradients leaves a floor; with it, int8 tracks fp32. Guard the
        mechanism by asserting int8 keeps converging well past the floor a
        naive quantizer stalls at (measured ~1e-3 on this problem)."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h = run_federated(prob, "fedosaa_svrg", hp, 30, w_star=wstar,
                          channel="int8")
        assert h.rel_error[-1] < 2e-4

    @pytest.mark.parametrize("algo", ["giant", "newton_gmres"])
    def test_newton_family_tracks_fp32_under_int8(self, logreg, algo):
        """The schema'd stateful wire un-floors the Newton family: with the
        diff-coded gradient and EF'd direction uplinks, int8 GIANT/Newton-
        GMRES must keep tracking the fp32 trajectory instead of flooring an
        order of magnitude above it (the pre-schema behavior recorded in
        benchmarks/results/ext_compression.json)."""
        prob, wstar = logreg
        hp = AlgoHParams(local_epochs=10)
        h32 = run_federated(prob, algo, hp, 12, w_star=wstar)
        h8 = run_federated(prob, algo, hp, 12, w_star=wstar, channel="int8")
        # 1e-6 floor: both runs bottom out at f32 machine precision, where
        # the ratio is last-ulp noise; the pre-schema int8 floor was ~6.7e-4
        assert h8.rel_error[-1] < max(3 * h32.rel_error[-1], 1e-6), algo

    def test_error_feedback_state_carried_and_nonzero(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=3)
        ch = make_channel("topk:0.1")
        state = init_state(prob, jax.random.PRNGKey(0), hp, ch,
                           "fedosaa_svrg")
        assert state.comm is not None
        assert "ef" in state.comm["delta"]
        fn = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, ch))
        state, _ = fn(state)
        ef = np.asarray(jax.tree.leaves(state.comm["delta"]["ef"])[0])
        assert ef.shape[0] == prob.clients.num_clients
        assert np.abs(ef).max() > 0          # topk drops mass -> residual
        # aux leg of a delta-only codec is fp32: the "grad" tag carries
        # nothing, so the schema allocator omits it
        assert "grad" not in state.comm

    def test_algo_aware_state_allocation(self, logreg):
        """init_state(algo=...) allocates exactly the buffers the algorithm's
        uplink schema declares — the AVG family has no aux uplink, the Newton
        family carries "grad"/"dir" instead of "grad"/"delta"; at LM scale
        each skipped buffer is a K×d array."""
        prob, _ = logreg
        ch = make_channel("int8")
        for algo in ("giant", "newton_gmres"):
            s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch, algo)
            assert set(s.comm) == {"grad", "dir"}, algo
            assert set(s.comm["grad"]) == {"ef", "ref"}
            assert set(s.comm["dir"]) == {"ef"}
        s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch, "dane")
        assert set(s.comm) == {"grad", "delta"}
        s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch, "fedavg")
        assert set(s.comm) == {"delta"} and "ef" in s.comm["delta"]
        s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch,
                       "fedosaa_svrg")
        assert "ref" in s.comm["grad"]
        # algo=None allocates the union DEFAULT_SCHEMA for agnostic callers
        s = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(), ch)
        assert set(s.comm) == {"grad", "delta", "ctrl", "dir"}

    def test_newton_family_round_advances_comm_state(self, logreg):
        """The tentpole behavior: GIANT's gradient uplink is difference-coded
        and its direction uplink carries an EF residual — one round must
        advance both buffers (a stateless wire would leave them zero)."""
        prob, _ = logreg
        ch = make_channel("int8")
        hp = AlgoHParams(local_epochs=2)
        s = init_state(prob, jax.random.PRNGKey(0), hp, ch, "giant")
        s, m = jax.jit(make_round_fn("giant", prob, hp, ch))(s)
        assert np.isfinite(float(m.loss))
        ref = np.asarray(jax.tree.leaves(s.comm["grad"]["ref"])[0])
        ef = np.asarray(jax.tree.leaves(s.comm["dir"]["ef"])[0])
        assert ref.shape[0] == prob.clients.num_clients
        assert np.abs(ref).max() > 0   # tracks the reconstructed gradients
        assert np.abs(ef).max() > 0    # int8-SR residual on the direction

    def test_noef_channel_carries_no_ef_state(self, logreg):
        prob, _ = logreg
        state = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(),
                           make_channel("topk:0.1+noef"), "fedosaa_svrg")
        assert state.comm is None
        # int8+noef still needs the aux diff-coding reference
        state = init_state(prob, jax.random.PRNGKey(0), AlgoHParams(),
                           make_channel("int8+noef"), "fedosaa_svrg")
        assert state.comm is not None
        assert "delta" not in state.comm
        assert set(state.comm["grad"]) == {"ref"}

    def test_comm_bytes_metric_matches_static_accounting(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=3)
        p0 = prob.init(jax.random.PRNGKey(0))
        for spec in (None, "bf16", "int8", "topk:0.1"):
            for algo in ("fedavg", "fedsvrg", "scaffold", "giant"):
                ch = make_channel(spec)
                fn = jax.jit(make_round_fn(algo, prob, hp, ch))
                _, m = fn(init_state(prob, jax.random.PRNGKey(0), hp, ch,
                                     algo))
                assert float(m.comm_bytes) == pytest.approx(
                    comm_bytes_per_round(algo, p0, ch)), (spec, algo)

    def test_history_floats_compat_column(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=3)
        h = run_federated(prob, "fedsvrg", hp, 3)
        np.testing.assert_allclose(h.comm_floats, h.comm_bytes / 4.0)
        assert h.channel == "identity"
