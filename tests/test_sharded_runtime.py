"""vmap-vs-shard_map runtime equivalence (core/sharded.py).

The contract: from any given ServerState, one round of the sharded runtime on
a 1-device host mesh produces the same params / control variates / carried AA
history / metrics as the vmap runtime, to float precision (rtol 1e-6). The
comparison is per-round from a shared state — across MANY rounds the two
runtimes drift apart, because the shard_map boundary changes XLA fusion by an
ulp and the ill-conditioned AA gram solve amplifies it (that is a property of
AA, not a runtime bug; see core/sharded.py docstring).

gram_cond_max is asserted loosely for the same reason: the condition number
of a near-singular Gram matrix is itself ill-conditioned.
"""
import jax
import numpy as np
import pytest

from repro.core import AlgoHParams, init_state, make_round_fn, run_federated
from repro.core.algorithms import ALGORITHMS
from repro.core.anderson import AAConfig
from repro.core.sharded import (
    client_mesh_axes,
    make_sharded_round_fn,
    num_client_shards,
)
from repro.data import make_binary_classification, partition
from repro.launch.mesh import make_host_mesh
from repro.models.logreg import make_logreg_problem


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=400, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    return prob, make_host_mesh()


def assert_tree_allclose(a, b, rtol=1e-6, atol=1e-7, what=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol, err_msg=what
        )


def assert_round_equal(sv, mv, ss, ms, what="", rtol=1e-6, atol=1e-7):
    assert_tree_allclose(sv.params, ss.params, rtol, atol, what=f"{what} params")
    assert_tree_allclose(sv.c, ss.c, rtol, atol, what=f"{what} server control variate")
    assert_tree_allclose(sv.c_k, ss.c_k, rtol, atol, what=f"{what} client control variates")
    if sv.hist_s is not None:
        assert_tree_allclose(sv.hist_s, ss.hist_s, rtol, atol, what=f"{what} hist_s")
        assert_tree_allclose(sv.hist_y, ss.hist_y, rtol, atol, what=f"{what} hist_y")
    assert (sv.comm is None) == (ss.comm is None), what
    if sv.comm is not None:
        assert_tree_allclose(sv.comm, ss.comm, rtol, atol,
                             what=f"{what} comm state")
    for field in ("loss", "grad_norm", "comm_bytes"):
        np.testing.assert_allclose(
            float(getattr(mv, field)), float(getattr(ms, field)),
            rtol=1e-6, err_msg=f"{what} {field}",
        )
    tv, ts = float(mv.theta_mean), float(ms.theta_mean)
    assert np.isnan(tv) == np.isnan(ts), what
    if not np.isnan(tv):
        np.testing.assert_allclose(tv, ts, rtol=1e-4, err_msg=f"{what} theta")
    gv, gs = float(mv.gram_cond_max), float(ms.gram_cond_max)
    assert np.isnan(gv) == np.isnan(gs), what
    if not np.isnan(gv):
        np.testing.assert_allclose(gv, gs, rtol=0.05, err_msg=f"{what} gram_cond")


def roundwise_compare(prob, mesh, algo, hp, rounds=3, channel=None,
                      rtol=1e-6, atol=1e-7):
    """Advance the vmap state; at every round apply BOTH runtimes to the same
    state and compare the full outputs (incl. the carried comm state the
    algorithm's uplink schema allocates)."""
    fv = jax.jit(make_round_fn(algo, prob, hp, channel))
    fs = jax.jit(make_sharded_round_fn(algo, prob, hp, mesh, channel=channel))
    state = init_state(prob, jax.random.PRNGKey(0), hp, channel, algo)
    for t in range(rounds):
        sv, mv = fv(state)
        ss, ms = fs(state)
        assert_round_equal(sv, mv, ss, ms, what=f"{algo} round {t}",
                           rtol=rtol, atol=atol)
        state = sv


class TestRoundEquivalence:
    @pytest.mark.parametrize("algo", ["fedosaa_svrg", "fedosaa_scaffold",
                                      "fedavg"])
    def test_headline_algos_match_vmap(self, setup, algo):
        prob, mesh = setup
        roundwise_compare(prob, mesh, algo,
                          AlgoHParams(eta=0.5, local_epochs=3), rounds=3)

    @pytest.mark.parametrize("algo", [a for a in ALGORITHMS
                                      if a not in ("fedosaa_svrg",
                                                   "fedosaa_scaffold",
                                                   "fedavg")])
    def test_remaining_algos_match_vmap(self, setup, algo):
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, dane_newton_iters=2,
                         dane_cg_iters=5)
        roundwise_compare(prob, mesh, algo, hp, rounds=2)

    def test_carry_history_branch(self, setup):
        """The carry_history > 0 branch of _client_svrg: carried (s,y)
        columns must round-trip through the sharded runtime identically."""
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, carry_history=2,
                         aa=AAConfig(tikhonov=1e-6, damping=0.7))
        fv = jax.jit(make_round_fn("fedosaa_svrg", prob, hp))
        fs = jax.jit(make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh))
        state = init_state(prob, jax.random.PRNGKey(0), hp)
        assert state.hist_s is not None
        for t in range(3):
            sv, mv = fv(state)
            ss, ms = fs(state)
            assert_round_equal(sv, mv, ss, ms, what=f"carry round {t}")
            state = sv
        # after a round the carried history must actually hold secant pairs
        assert max(float(np.max(np.abs(l))) for l in jax.tree.leaves(state.hist_s)) > 0

    def test_line_search_giant(self, setup):
        prob, mesh = setup
        hp = AlgoHParams(local_epochs=5, line_search=True)
        roundwise_compare(prob, mesh, "giant", hp, rounds=2)

    def test_partial_participation(self, setup):
        """Participation draws happen in the (shared) prologue: identical rng
        => identical active set in both runtimes."""
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, participation=0.5)
        roundwise_compare(prob, mesh, "fedosaa_svrg", hp, rounds=3)

    def test_minibatch_rngs(self, setup):
        """Per-client rng keys are split in the prologue and sharded: the
        minibatch draws must match the vmap runtime exactly."""
        prob, mesh = setup
        hp = AlgoHParams(eta=0.3, local_epochs=3, batch_size=16)
        roundwise_compare(prob, mesh, "fedosaa_svrg", hp, rounds=2)


class TestCompressedRoundEquivalence:
    """Every repro/comm codec must produce identical rounds under the vmap
    and shard_map runtimes (rtol 1e-5 on the host mesh): the per-client
    encode/decode — including the stochastic int8 draws, which depend only on
    the prologue-split client rngs — happens before the psum, so sharding
    cannot change what crosses the wire. The carried comm state (error
    feedback, diff-coding references) is compared too."""

    @pytest.mark.parametrize("spec", ["bf16", "int8", "topk:0.1"])
    @pytest.mark.parametrize("algo", ["fedosaa_svrg", "fedosaa_scaffold",
                                      "fedavg"])
    def test_codecs_match_vmap(self, setup, algo, spec):
        prob, mesh = setup
        roundwise_compare(prob, mesh, algo,
                          AlgoHParams(eta=0.5, local_epochs=3), rounds=3,
                          channel=spec, rtol=1e-5)

    def test_codec_with_carry_history(self, setup):
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, carry_history=2,
                         aa=AAConfig(tikhonov=1e-6, damping=0.7))
        roundwise_compare(prob, mesh, "fedosaa_svrg", hp, rounds=3,
                          channel="int8", rtol=1e-5)

    @pytest.mark.parametrize("spec", ["bf16", "int8"])
    @pytest.mark.parametrize("algo", ["giant", "newton_gmres", "dane"])
    def test_stateful_newton_family_matches_vmap(self, setup, algo, spec):
        """The newly stateful Newton family: carried comm state (diff-coded
        gradient references, EF'd direction/delta residuals) must round-trip
        through shard_map identically to the vmap runtime — rtol 1e-6 on the
        host mesh, comm buffers compared round-by-round."""
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, dane_newton_iters=2,
                         dane_cg_iters=5)
        roundwise_compare(prob, mesh, algo, hp, rounds=3, channel=spec,
                          rtol=1e-6, atol=1e-7)

    def test_codec_newton_and_line_search(self, setup):
        prob, mesh = setup
        hp = AlgoHParams(local_epochs=5, line_search=True)
        roundwise_compare(prob, mesh, "giant", hp, rounds=2,
                          channel="int8", rtol=1e-5)

    def test_downlink_codec(self, setup):
        prob, mesh = setup
        roundwise_compare(prob, mesh, "fedosaa_svrg",
                          AlgoHParams(eta=0.5, local_epochs=3), rounds=2,
                          channel="bf16/bf16", rtol=1e-5)

    def test_compressed_sharded_round_has_collectives(self, setup):
        """The dequantized representation is what the psum reduces: the
        compressed round still lowers to one XLA computation with the
        client-axis all-reduce."""
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        fn = jax.jit(make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh,
                                           channel="int8"))
        state = init_state(prob, jax.random.PRNGKey(0), hp, "int8")
        compiled = fn.lower(state).compile()
        assert "all-reduce" in compiled.as_text()


class TestShardedMechanics:
    def test_single_xla_computation(self, setup):
        """The whole sharded round lowers and compiles as ONE jitted XLA
        computation (no per-client Python loop): one executable whose HLO
        contains the client-axis psum collectives."""
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        fn = jax.jit(make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh))
        compiled = fn.lower(init_state(prob, jax.random.PRNGKey(0), hp)).compile()
        assert "all-reduce" in compiled.as_text()

    def test_run_federated_runtime_knob(self, setup):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        hv = run_federated(prob, "fedavg", hp, 5, rng=0)
        hs = run_federated(prob, "fedavg", hp, 5, rng=0, runtime="sharded")
        np.testing.assert_allclose(hs.loss, hv.loss, rtol=1e-5)
        np.testing.assert_allclose(hs.comm_bytes, hv.comm_bytes, rtol=1e-6)
        with pytest.raises(ValueError, match="runtime"):
            run_federated(prob, "fedavg", hp, 1, runtime="pmap")

    def test_indivisible_clients_raise(self, setup):
        """K must divide over the client shards — a clear error, not silent
        wrong math."""
        prob, _ = setup

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 3, "model": 1}

        with pytest.raises(ValueError, match="divide"):
            make_sharded_round_fn("fedavg", prob, AlgoHParams(), FakeMesh())

    def test_mesh_without_client_axes_raises(self, setup):
        prob, _ = setup

        class FakeMesh:
            axis_names = ("model",)
            shape = {"model": 1}

        with pytest.raises(ValueError, match="mesh axes"):
            make_sharded_round_fn("fedavg", prob, AlgoHParams(), FakeMesh())

    def test_unknown_algorithm_raises(self, setup):
        prob, mesh = setup
        with pytest.raises(ValueError, match="unknown algorithm"):
            make_sharded_round_fn("sgd", prob, AlgoHParams(), mesh)

    def test_client_axis_helpers(self, setup):
        _, mesh = setup
        assert client_mesh_axes(mesh) == ("data",)
        assert num_client_shards(mesh) == 1

        class FakeMesh:
            axis_names = ("pod", "data", "model")
            shape = {"pod": 2, "data": 16, "model": 16}

        assert client_mesh_axes(FakeMesh()) == ("pod", "data")
        assert num_client_shards(FakeMesh()) == 32
