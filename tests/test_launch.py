"""Launch-layer unit tests: HLO collective parsing, input-spec construction,
effective-config policy (sliding window for long_500k), mesh factory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, get_shape
from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.launch.specs_io import (
    batch_specs_for, cache_len_for, effective_cfg, params_shape,
)
from repro.launch.steps import make_aa_step, make_train_step
from repro.models.decoder import build_model


class TestCollectiveParser:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[128,4096]") == 128 * 4096 * 2
        assert _shape_bytes("f32[16]") == 64
        assert _shape_bytes("(bf16[8,8], f32[4])") == 128 + 16
        assert _shape_bytes("pred[10]") == 10

    def test_collective_bytes_parses_ops(self):
        hlo = """
  %all-reduce.1 = bf16[256,512]{1,0} all-reduce(%x), replica_groups={}
  %ag = f32[1024]{0} all-gather(%y), dimensions={0}
  %aa = (bf16[64,64]{1,0}, bf16[64,64]{1,0}) all-to-all(%a, %b)
  %rs.2 = f32[128]{0} reduce-scatter(%z), dimensions={0}
  %cp = u32[16]{0} collective-permute(%w)
"""
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 256 * 512 * 2
        assert out["all-gather"] == 4096
        assert out["all-to-all"] == 2 * 64 * 64 * 2
        assert out["reduce-scatter"] == 512
        assert out["collective-permute"] == 64
        assert out["all-reduce_count"] == 1

    def test_ignores_non_collectives(self):
        assert collective_bytes("%d = bf16[8] dot(%a, %b)") == {}


class TestEffectiveCfg:
    def test_long_context_forces_sliding_window(self):
        shape = get_shape("long_500k")
        for arch in ARCHS:
            cfg = effective_cfg(get_arch(arch), shape)
            if cfg.num_heads:
                assert cfg.sliding_window > 0, arch
                assert cache_len_for(cfg, shape) == cfg.sliding_window
            else:  # pure SSM: O(1) state, no window needed
                assert cfg.sliding_window == 0

    def test_other_shapes_untouched(self):
        for sname in ("train_4k", "prefill_32k", "decode_32k"):
            cfg = effective_cfg(get_arch("qwen3-4b"), get_shape(sname))
            assert cfg.sliding_window == 0

    def test_decode_cache_len_is_seq_len(self):
        cfg = effective_cfg(get_arch("qwen3-4b"), get_shape("decode_32k"))
        assert cache_len_for(cfg, get_shape("decode_32k")) == 32_768


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ["smollm-135m", "internvl2-76b", "musicgen-medium"])
    def test_train_batch_specs(self, arch):
        cfg = get_arch(arch)
        io = batch_specs_for(cfg, get_shape("train_4k"))
        assert io["batch"]["tokens"].shape == (256, 4096)
        if cfg.frontend_tokens:
            assert io["batch"]["embeds"].shape == (256, cfg.frontend_tokens, cfg.d_model)

    def test_decode_specs(self):
        io = batch_specs_for(get_arch("mamba2-2.7b"), get_shape("decode_32k"))
        assert io["tokens"].shape == (128, 1)
        assert io["pos"].shape == (128, 1)

    def test_params_shape_no_allocation(self):
        cfg = get_arch("granite-20b")          # 20B params — must NOT allocate
        model = build_model(cfg)
        ps = params_shape(model)
        total = sum(np.prod(l.shape) for l in jax.tree.leaves(ps))
        assert total > 15e9
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in jax.tree.leaves(ps))


class TestSteps:
    def test_train_step_runs_reduced(self):
        cfg = get_arch("smollm-135m").reduced()
        model = build_model(cfg)
        params = jax.jit(model.init)(jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, eta=0.1))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                              0, cfg.vocab_size, jnp.int32)}
        correction = jax.tree.map(jnp.zeros_like, params)
        new_params, r, loss = step(params, batch, correction)
        assert np.isfinite(float(loss))
        # residual must equal the gradient when correction is zero
        g = jax.grad(model.loss)(params, batch)
        gmax = max(float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(g))
        for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6 * gmax)

    def test_aa_step_reduces_quadratic_residual(self):
        """make_aa_step on a toy quadratic trajectory behaves like AA."""
        rng = np.random.default_rng(0)
        d, m = 32, 3
        A = np.diag(np.linspace(1, 5, d)).astype(np.float32)
        b = rng.standard_normal(d).astype(np.float32)
        eta = 0.15
        w = rng.standard_normal(d).astype(np.float32)
        ws, rs = [w], [A @ w - b]
        for _ in range(m):
            w = w - eta * (A @ w - b)
            ws.append(w)
            rs.append(A @ w - b)
        s = jnp.asarray(np.diff(np.stack(ws), axis=0))
        y = jnp.asarray(np.diff(np.stack(rs), axis=0))
        aa = make_aa_step(eta=eta, history=m)
        w_new, theta = aa({"w": jnp.asarray(ws[0])}, {"w": jnp.asarray(rs[0])},
                          {"w": s}, {"w": y})
        r_new = A @ np.asarray(w_new["w"]) - b
        assert np.linalg.norm(r_new) < 0.5 * np.linalg.norm(rs[0])
        assert 0.0 <= float(theta) <= 1.0


def test_mesh_factory_shapes():
    """make_production_mesh axes/shape contract (can't build 512 devices in
    the test process — validate the spec via the documented contract)."""
    import inspect
    from repro.launch import mesh as mesh_mod
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
