"""Preemption-tolerant checkpointing (repro/checkpoint sharded format +
crash-injection recovery harness, repro/robust/fs_faults).

The property under test is the checkpoint subsystem's whole reason to exist:
a process killed at ANY byte of a save leaves the directory in a state from
which ``load_latest`` resumes BIT-identically from the newest complete
checkpoint — torn temp directories are invisible to discovery, corrupt or
partial checkpoints are skipped (never raised on), a full disk degrades the
run gracefully instead of crashing it, and the async save path adds no
device→host sync beyond the engine's one-per-chunk.

Fault realizations are deterministic (FSFaultPlan is keyed, not random), so
every scenario here replays bit-identically.
"""
import io
import json
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, strategies as st

from repro.checkpoint import (
    CheckpointConfigMismatch,
    CheckpointManager,
    CheckpointPolicy,
    ckpt_name,
    list_checkpoints,
    load_latest,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
    snapshot_shards,
    write_bytes_atomic,
    write_checkpoint,
)
from repro.checkpoint.policy import MODES
from repro.core import AAConfig, AlgoHParams, init_state, make_round_fn, run_rounds
from repro.core.server import run_federated
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem
from repro.obs import AlarmMonitor, MemorySink
from repro.robust import AsyncConfig, FaultPlan
from repro.robust.fs_faults import FaultyFs, FSFaultPlan, SimulatedKill

K = 8


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=400, seed=0)
    clients = partition(X, y, num_clients=K, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    return prob


def _tiny_state():
    """A cheap pytree standing in for ServerState where the protocol, not
    the algorithm, is under test."""
    return {
        "w": np.arange(24.0, dtype=np.float32).reshape(4, 6),
        "t": np.int32(3),
        "comm": {"int8/ef": np.ones((8,), np.float32)},
    }


def _save(directory, round_idx, state=None, fs=None, config=None):
    snap = snapshot_shards(state if state is not None else _tiny_state())
    kw = {} if fs is None else {"fs": fs}
    return write_checkpoint(directory, snap, round_idx,
                            config=config or {}, **kw)


# ---------------------------------------------------------------------------
# corruption helpers: turn a COMMITTED checkpoint into each defect class the
# recovery property quantifies over
# ---------------------------------------------------------------------------
def _corrupt(path: str, kind: str) -> None:
    manifest = os.path.join(path, "manifest.json")
    if kind == "none":
        return
    if kind == "torn_manifest":
        data = open(manifest, "rb").read()
        with open(manifest, "wb") as f:
            f.write(data[: len(data) // 2])
    elif kind == "bad_digest":
        m = json.load(open(manifest))
        first = next(iter(m["leaves"].values()))
        first["shards"][0]["sha256"] = "0" * 64
        with open(manifest, "w") as f:
            json.dump(m, f)
    elif kind == "missing_shard":
        for name in os.listdir(path):
            if name.startswith("shards_"):
                os.remove(os.path.join(path, name))
    elif kind == "empty":
        for name in os.listdir(path):
            os.remove(os.path.join(path, name))
    else:  # pragma: no cover
        raise ValueError(kind)


class TestAtomicCommit:
    def test_kill_mid_save_leaves_torn_tmp_invisible(self, tmp_path):
        """Death between save-start and commit: the staging dir stays on
        disk, but discovery and load_latest never see it."""
        d = str(tmp_path)
        fs = FaultyFs(FSFaultPlan(kill_at_save=1, kill_after_writes=1))
        fs.on_save_start()
        with pytest.raises(SimulatedKill):
            _save(d, 5, fs=fs)
        remnants = [n for n in os.listdir(d) if n.startswith(".tmp-")]
        assert remnants, "the kill must leave the torn staging dir behind"
        assert list_checkpoints(d) == []
        assert load_latest(d, _tiny_state()) is None

    def test_kill_before_commit_rename(self, tmp_path):
        """Even with every shard and the manifest staged, death before the
        directory rename means the checkpoint never existed."""
        d = str(tmp_path)
        # writes per save: shards npz (1), manifest (2) — die at the rename
        fs = FaultyFs(FSFaultPlan(kill_at_save=1, kill_after_writes=2))
        fs.on_save_start()
        with pytest.raises(SimulatedKill):
            _save(d, 5, fs=fs)
        assert list_checkpoints(d) == []

    def test_torn_write_never_under_final_name(self, tmp_path):
        """A torn write (power cut mid-write) persists only under the temp
        name; the final name either doesn't exist or holds complete bytes."""
        path = str(tmp_path / "blob.bin")
        fs = FaultyFs(FSFaultPlan(torn_write_rate=1.0))
        with pytest.raises(OSError):
            write_bytes_atomic(path, b"x" * 4096, fs=fs, retries=1,
                               backoff_s=0.0, sleep=lambda _: None)
        assert not os.path.exists(path)

    def test_transient_error_retried(self, tmp_path):
        """A once-flaky write (EIO then fine) succeeds via the exponential
        backoff — no failure surfaces to the caller."""
        d = str(tmp_path)
        fs = FaultyFs(FSFaultPlan(flaky_writes=(0,)))
        path, nbytes = write_checkpoint(
            d, snapshot_shards(_tiny_state()), 7, config={}, fs=fs,
            backoff_s=0.0, sleep=lambda _: None)
        assert list_checkpoints(d) == [(7, path)]
        assert nbytes > 0

    def test_retention_gc_and_tmp_sweep(self, tmp_path):
        d = str(tmp_path)
        for r in (2, 4, 6, 8):
            _save(d, r)
        os.makedirs(os.path.join(d, ".tmp-ckpt_00000010-999"))
        removed = prune_checkpoints(d, keep=2)
        assert [r for r, _ in list_checkpoints(d)] == [8, 6]
        assert any(".tmp-" in p for p in removed)
        assert not any(n.startswith(".tmp-") for n in os.listdir(d))

    def test_keep_zero_keeps_everything(self, tmp_path):
        d = str(tmp_path)
        for r in (1, 2, 3):
            _save(d, r)
        prune_checkpoints(d, keep=0)
        assert [r for r, _ in list_checkpoints(d)] == [3, 2, 1]

    def test_resave_same_round_overwrites(self, tmp_path):
        """A rerun into the same directory supersedes an existing committed
        round instead of failing the rename (ENOTEMPTY)."""
        d = str(tmp_path)
        _save(d, 4)
        state = _tiny_state()
        state["w"] = state["w"] + 1.0
        _save(d, 4, state=state)
        tree, _ = load_latest(d, _tiny_state())
        np.testing.assert_array_equal(np.asarray(tree["w"]), state["w"])


class TestRecoveryProperty:
    """load_latest over ANY subset of {complete, torn-manifest, bad-digest,
    missing-shard, empty}: never raises, never selects an incomplete
    checkpoint, always lands on the newest complete one (or None)."""

    @settings(max_examples=30, deadline=None)
    @given(newest_kind=st.sampled_from(
               ["none", "torn_manifest", "bad_digest", "missing_shard",
                "empty"]),
           middle_kind=st.sampled_from(["none", "torn_manifest", "empty"]),
           oldest_ok=st.booleans())
    def test_skips_defective_selects_newest_complete(
            self, tmp_path_factory, newest_kind, middle_kind, oldest_ok):
        d = str(tmp_path_factory.mktemp("prop"))
        by_round = {}
        for r in (2, 4, 6):
            state = _tiny_state()
            state["w"] = state["w"] + float(r)
            path, _ = _save(d, r, state=state)
            by_round[r] = (path, state)
        _corrupt(by_round[6][0], newest_kind)
        _corrupt(by_round[4][0], middle_kind)
        if not oldest_ok:
            _corrupt(by_round[2][0], "missing_shard")

        complete = [r for r, kind in ((6, newest_kind), (4, middle_kind),
                                      (2, "none" if oldest_ok else "empty"))
                    if kind == "none"]
        found = load_latest(d, _tiny_state())
        if not complete:
            assert found is None
        else:
            tree, manifest = found
            assert manifest["round"] == max(complete)
            np.testing.assert_array_equal(
                np.asarray(tree["w"]), by_round[max(complete)][1]["w"])

    def test_garbage_directory_never_raises(self, tmp_path):
        """Stray files, misnamed dirs, and empty ckpt dirs are all ignored."""
        d = str(tmp_path)
        open(os.path.join(d, "notes.txt"), "w").write("hi")
        os.makedirs(os.path.join(d, "ckpt_not_a_number"))
        os.makedirs(os.path.join(d, ckpt_name(3)))  # committed name, empty
        assert load_latest(d, _tiny_state()) is None
        assert list_checkpoints(d) == [(3, os.path.join(d, ckpt_name(3)))]

    def test_missing_directory_is_fresh_start(self, tmp_path):
        assert load_latest(str(tmp_path / "never_created"),
                           _tiny_state()) is None

    def test_config_mismatch_refuses(self, tmp_path):
        d = str(tmp_path)
        _save(d, 3, config={"algo": "fedosaa_svrg", "channel": "int8"})
        with pytest.raises(CheckpointConfigMismatch):
            load_latest(d, _tiny_state(),
                        expect_config={"algo": "fedosaa_svrg",
                                       "channel": "identity"})
        # matching config restores fine
        assert load_latest(
            d, _tiny_state(),
            expect_config={"algo": "fedosaa_svrg",
                           "channel": "int8"}) is not None


class TestEnospcGracefulDegrade:
    def test_run_continues_failure_counted_next_save_clean(self, setup):
        """A full disk during save N: the run keeps training, the failure is
        counted and alarmed in the v4 footer, and save N+1 (disk freed)
        commits normally."""
        prob = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        import tempfile

        d = tempfile.mkdtemp()
        # first save = write steps 0..3 (1 write + 3 retries), all ENOSPC;
        # the second save starts at step 4 and succeeds
        fs = FaultyFs(FSFaultPlan(enospc_writes=(0, 1, 2, 3)))
        mgr = CheckpointManager(
            CheckpointPolicy(directory=d, every=2, mode="sync",
                             backoff_s=0.0),
            fs=fs)
        sink = MemorySink()
        _, trace = run_rounds(rf, state, 4, chunk=2, sinks=[sink],
                              checkpoint=mgr)
        assert trace.num_rounds == 4, "the run must survive the full disk"
        tel = mgr.telemetry()
        assert tel["checkpoint_failures"] == 1
        assert [e["rule"] for e in mgr.events] == ["checkpoint_failed"]
        assert sink.footer["checkpoint_failures"] == 1
        assert any(a["rule"] == "checkpoint_failed"
                   for a in sink.footer["alarms"])
        # the round-4 save committed despite round-2's full disk
        assert [r for r, _ in list_checkpoints(d, fs=fs)] == [4]
        assert not any(n.startswith(".tmp-") for n in os.listdir(d)), \
            "the failed save must sweep its staging dir"


#: the adversarial carried state: int8 EF residuals + diff refs, two AA
#: history columns, per-client async buffers fed by heavy-tailed latency
#: faults — every buffer class a checkpoint can silently drop
RICH_HP = dict(eta=0.5, local_epochs=3, carry_history=2,
               aa=AAConfig(tikhonov=1e-6, damping=0.7))
LATENCY_PLAN = FaultPlan(seed=5, latency_scale=1.0, latency_shape=1.5)
GATE = AsyncConfig(deadline=2.0, min_arrivals=2, staleness_alpha=0.5)


class TestKillRecoveryBitExact:
    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_kill_during_save_then_resume_auto(self, setup, tmp_path,
                                               runtime):
        """The acceptance scenario end-to-end on BOTH runtimes: a run killed
        DURING a checkpoint save resumes from the newest complete checkpoint
        and finishes bit-identical to the never-killed run — params, int8
        comm state, carried AA history, and async buffers all included."""
        prob = setup
        hp = AlgoHParams(**RICH_HP)
        d = str(tmp_path / runtime)
        pol = CheckpointPolicy(directory=d, every=2, keep=0, mode="sync")
        kw = dict(problem=prob, algo="fedosaa_svrg", hp=hp, rng=0,
                  channel="int8", chunk=2, runtime=runtime,
                  faults=LATENCY_PLAN, async_cfg=GATE)

        straight = run_federated(num_rounds=6, **kw)

        # the save at round 4 (save #2) dies mid-write: only round 2 commits
        fs = FaultyFs(FSFaultPlan(kill_at_save=2, kill_after_writes=1))
        run_federated(num_rounds=6, checkpoint=pol, checkpoint_fs=fs, **kw)
        assert [r for r, _ in list_checkpoints(d)] == [2]
        assert any(n.startswith(".tmp-") for n in os.listdir(d))

        sink = MemorySink()
        resumed = run_federated(num_rounds=6, checkpoint=pol, resume="auto",
                                sinks=[sink], **kw)
        assert sink.header["start_round"] == 2
        assert [r["round"] for r in sink.rows] == [2, 3, 4, 5]
        np.testing.assert_array_equal(resumed.rounds, [2, 3, 4, 5])
        np.testing.assert_array_equal(resumed.loss, straight.loss[2:])
        np.testing.assert_array_equal(resumed.grad_norm,
                                      straight.grad_norm[2:])
        for a, b in zip(jax.tree.leaves(straight.final_params),
                        jax.tree.leaves(resumed.final_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the resumed run's own saves land alongside the survivor
        # (keep=0 retains everything: rounds 4 and 6 joined round 2)
        assert [r for r, _ in list_checkpoints(d)] == [6, 4, 2]

    def test_resume_refuses_mismatched_run_config(self, setup, tmp_path):
        """A checkpoint written under one fault plan must not resume under
        another — the carried anchors/buffers would be meaningless."""
        prob = setup
        hp = AlgoHParams(**RICH_HP)
        d = str(tmp_path)
        pol = CheckpointPolicy(directory=d, every=2, mode="sync")
        kw = dict(problem=prob, algo="fedosaa_svrg", hp=hp, rng=0,
                  channel="int8", chunk=2)
        run_federated(num_rounds=2, checkpoint=pol, faults=LATENCY_PLAN,
                      async_cfg=GATE, **kw)
        with pytest.raises(CheckpointConfigMismatch):
            run_federated(num_rounds=4, checkpoint=pol, resume="auto",
                          faults=None, async_cfg=None, **kw)


class TestNoExtraDeviceSync:
    @pytest.mark.parametrize("mode", ["async", "sync"])
    def test_checkpointing_adds_no_device_get(self, setup, tmp_path,
                                              monkeypatch, mode):
        """The save path copies addressable shards through the arrays' own
        host buffers: with checkpointing attached the engine still performs
        EXACTLY one jax.device_get per chunk (the acceptance criterion the
        sinks already pin in tests/test_obs.py)."""
        prob = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        calls = []
        orig = jax.device_get

        def counting(x):
            calls.append(1)
            return orig(x)

        monkeypatch.setattr(jax, "device_get", counting)
        mgr = CheckpointManager(CheckpointPolicy(
            directory=str(tmp_path), every=4, mode=mode))
        sink = MemorySink()
        _, trace = run_rounds(rf, state, 8, chunk=4,
                              sinks=[sink, AlarmMonitor()], checkpoint=mgr)
        assert trace.num_rounds == 8
        assert mgr.saves_completed == 2
        assert len(calls) == 2  # 8 rounds / chunk 4 = 2 chunks = 2 syncs
        assert sink.footer["checkpoint_bytes"] > 0

    def test_sync_gather_baseline_does_device_get(self, tmp_path):
        """The benchmark's sync_gather baseline is the stall the async path
        removes — it DOES full-state device_get (sanity check that the
        comparison in benchmarks/ext_checkpoint.py measures what it says)."""
        assert "sync_gather" in MODES
        mgr = CheckpointManager(CheckpointPolicy(
            directory=str(tmp_path), every=1, mode="sync_gather"))
        calls = []
        orig = jax.device_get
        state = {"w": jax.numpy.ones((4,))}
        try:
            jax.device_get = lambda x: (calls.append(1), orig(x))[1]
            mgr.maybe_save(state, 1, 0.01)
            mgr.finalize()
        finally:
            jax.device_get = orig
        assert len(calls) >= 1


class TestBackpressure:
    def test_one_in_flight_wait_and_warn(self, tmp_path):
        """A save still in flight when the next comes due: the manager waits
        (never two writers) and records a checkpoint_stalled event."""
        import threading

        gate = threading.Event()

        class SlowFs(FaultyFs):
            def write_bytes(self, path, data):
                gate.wait(timeout=5.0)
                super().write_bytes(path, data)

        fs = SlowFs(FSFaultPlan())
        mgr = CheckpointManager(CheckpointPolicy(
            directory=str(tmp_path), every=1, mode="async"), fs=fs)
        state = _tiny_state()
        assert mgr.maybe_save(state, 1, 0.001)

        def release():
            gate.set()

        threading.Timer(0.05, release).start()
        assert mgr.maybe_save(state, 2, 0.001)   # must wait, then dispatch
        mgr.finalize()
        rules = [e["rule"] for e in mgr.events]
        assert "checkpoint_stalled" in rules
        assert mgr.saves_completed == 2
        assert [r for r, _ in list_checkpoints(str(tmp_path), fs=fs)] \
            == [2, 1]


class TestLegacyNpzAtomic:
    def test_interrupted_save_never_corrupts_existing(self, tmp_path):
        """Regression for the silent-overwrite hazard: the legacy npz save
        used to np.savez straight onto the final path, so a crash mid-write
        destroyed the previous checkpoint. Now a failed save leaves the
        original bytes untouched and restorable."""
        path = str(tmp_path / "legacy_state")
        tree = {"w": np.arange(6.0, dtype=np.float32)}
        save_checkpoint(path, tree, step=1)
        before = open(path + ".npz", "rb").read()

        fs = FaultyFs(FSFaultPlan(torn_write_rate=1.0))
        with pytest.raises(OSError):
            save_checkpoint(path, {"w": np.zeros(6, np.float32)}, step=2,
                            fs=fs)
        assert open(path + ".npz", "rb").read() == before
        restored = restore_checkpoint(
            path, like={"w": np.zeros(6, np.float32)})
        np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])

    def test_no_tmp_litter_on_success(self, tmp_path):
        path = str(tmp_path / "clean")
        save_checkpoint(path, {"w": np.ones(3, np.float32)}, step=0)
        litter = [n for n in os.listdir(tmp_path) if ".tmp-" in n]
        assert litter == []


class TestManifestInventory:
    def test_manifest_names_every_subsystem_buffer(self, setup, tmp_path):
        """The manifest's inventory must account for the state's comm tags,
        AA history, and async buffers — the human-auditable record that
        nothing was silently dropped."""
        prob = setup
        hp = AlgoHParams(**RICH_HP)
        from repro.comm import make_channel
        from repro.robust import init_async_comm

        channel = make_channel("int8")
        state = init_state(prob, jax.random.PRNGKey(0), hp, channel,
                           "fedosaa_svrg")
        state = state._replace(comm=init_async_comm(
            state.comm, state.params, prob.clients.num_clients))
        d = str(tmp_path)
        _save(d, 1, state=state)
        manifest = json.load(
            open(os.path.join(d, ckpt_name(1), "manifest.json")))
        inv = manifest["inventory"]
        assert inv["aa_history"] is True
        assert inv["async_buffers"] is True
        assert inv["rng"] is True and inv["round_counter"] is True
        assert inv["num_leaves"] == len(jax.tree.leaves(state))
        # every npz entry digest in the manifest is 64 hex chars
        for leaf in manifest["leaves"].values():
            for sh in leaf["shards"]:
                assert len(sh["sha256"]) == 64
