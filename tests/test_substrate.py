"""Substrate tests: optimizers, schedules, checkpointing, data pipeline,
FL-LM bridge, centralized trainer step."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the module runs
    from _hypothesis_stub import given, settings, strategies as st

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import make_binary_classification, make_lm_tokens, make_mnist_like, partition
from repro.optim import adamw, clip_by_global_norm, constant, cosine, sgd, wsd


def rosenbrock_params():
    return {"a": jnp.array([1.5, -0.5]), "b": {"c": jnp.array([0.3])}}


def quad_loss(p):
    flat = jnp.concatenate([p["a"], p["b"]["c"]])
    return jnp.sum((flat - jnp.array([1.0, 2.0, 3.0])) ** 2)


class TestOptim:
    @pytest.mark.parametrize("make", [lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
                                      lambda: adamw(0.1)])
    def test_converges_on_quadratic(self, make):
        opt = make()
        p = rosenbrock_params()
        state = opt.init(p)
        for _ in range(200):
            g = jax.grad(quad_loss)(p)
            p, state = opt.update(g, state, p)
        assert float(quad_loss(p)) < 1e-3

    def test_adamw_weight_decay_shrinks(self):
        opt = adamw(0.1, weight_decay=0.5)
        p = {"w": jnp.ones((4,)) * 10}
        state = opt.init(p)
        zero_g = {"w": jnp.zeros((4,))}
        for _ in range(20):
            p, state = opt.update(zero_g, state, p)
        assert float(jnp.abs(p["w"]).max()) < 10.0

    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones((100,)) * 10}
        clipped = clip_by_global_norm(g, 1.0)
        n = float(jnp.linalg.norm(clipped["a"]))
        assert abs(n - 1.0) < 1e-5

    def test_schedules_shapes(self):
        for fn in (constant(1.0), cosine(1.0, 100, warmup=10), wsd(1.0, 100)):
            vals = [float(fn(jnp.asarray(s))) for s in range(0, 100, 7)]
            assert all(0 <= v <= 1.0 + 1e-6 for v in vals)

    def test_wsd_phases(self):
        fn = wsd(1.0, 1000, warmup_frac=0.01, decay_frac=0.1)
        assert float(fn(jnp.asarray(0))) < 0.2          # warmup start
        assert float(fn(jnp.asarray(500))) == pytest.approx(1.0)   # stable
        assert float(fn(jnp.asarray(999))) < 0.05       # decayed


class TestCheckpoint:
    def test_roundtrip(self):
        p = {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
             "head": jnp.zeros((2, 2), jnp.int32)}
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ckpt")
            save_checkpoint(path, p, step=7)
            like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), p)
            restored = restore_checkpoint(path, like)
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a, np.float32),
                                              np.asarray(b, np.float32))

    def test_shape_mismatch_raises(self):
        p = {"w": jnp.ones((3,))}
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ckpt")
            save_checkpoint(path, p)
            bad = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
            with pytest.raises(AssertionError):
                restore_checkpoint(path, bad)


class TestData:
    def test_binary_datasets_match_fingerprint(self):
        for name, d in (("covtype", 54), ("w8a", 300)):
            X, y = make_binary_classification(name, n=500)
            assert X.shape == (500, d)
            assert set(np.unique(y)) <= {-1.0, 1.0}

    def test_w8a_class_imbalance(self):
        _, y = make_binary_classification("w8a", n=5000)
        pos = float((y > 0).mean())
        assert pos < 0.15          # w8a is ~3% positive

    def test_lm_tokens_in_range(self):
        toks = make_lm_tokens(4, 64, vocab=1000)
        assert toks.shape == (4, 64)
        assert toks.min() >= 0 and toks.max() < 1000

    def test_mnist_like_labels(self):
        X, y = make_mnist_like(n=200)
        assert X.shape == (200, 784)
        assert set(np.unique(y)) <= set(range(10))

    @settings(max_examples=10, deadline=None)
    @given(k=st.integers(2, 20), scheme=st.sampled_from(["iid", "imbalance", "label_skew"]))
    def test_property_partition_conserves_weight(self, k, scheme):
        X, y = make_binary_classification("synthetic_small", n=600, seed=1)
        clients = partition(X, y, num_clients=k, scheme=scheme)
        assert clients.num_clients == k
        np.testing.assert_allclose(float(clients.weight.sum()), 1.0, rtol=1e-5)
        # masked counts == weights * total
        counts = np.asarray(clients.mask.sum(axis=1))
        np.testing.assert_allclose(
            counts / counts.sum(), np.asarray(clients.weight), rtol=1e-4
        )

    def test_imbalance_is_imbalanced(self):
        X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
        clients = partition(X, y, num_clients=10, scheme="imbalance")
        w = np.asarray(clients.weight)
        assert w.max() / w.min() > 20

    def test_imbalance_adversarial_counts(self):
        """Regression: the geometric tail used to round trailing clients to
        EMPTY slices at adversarial n/num_clients (the 2-sample floor then
        overdrew the total and the last clients got nothing). Every client
        must keep >= 2 samples and the counts must exactly cover n."""
        for n, k in ((60, 20), (101, 17), (2000, 30)):
            X, y = make_binary_classification("synthetic_small", n=n, seed=0)
            clients = partition(X, y, num_clients=k, scheme="imbalance")
            counts = np.asarray(clients.mask.sum(axis=1)).astype(int)
            assert counts.min() >= 2, (n, k, counts)
            assert counts.sum() == n, (n, k, counts)
        # below the documented floor the partitioner must refuse, not emit
        # empty clients
        X, y = make_binary_classification("synthetic_small", n=30, seed=0)
        with pytest.raises(ValueError, match="2 samples per client"):
            partition(X, y, num_clients=16, scheme="imbalance")


class TestLMBridge:
    def test_fl_lm_round_decreases_loss(self):
        from repro.configs import get_arch
        from repro.core import AlgoHParams, run_federated
        from repro.core.lm import make_lm_clients, make_lm_problem
        from repro.models.decoder import build_model

        cfg = get_arch("smollm-135m").reduced()
        model = build_model(cfg)
        toks = make_lm_tokens(8, 64, cfg.vocab_size)
        clients = make_lm_clients(toks, 2)
        problem = make_lm_problem(model, clients)
        h = run_federated(problem, "fedosaa_svrg",
                          AlgoHParams(eta=0.3, local_epochs=3), 3)
        assert h.loss[-1] < h.loss[0]
        assert np.isfinite(h.loss).all()
