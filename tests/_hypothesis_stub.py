"""Stand-in for ``hypothesis`` when it is not installed.

Property-based tests are a dev-extra (requirements-dev.txt); the tier-1 suite
must collect and run without them. Modules that use hypothesis import it as

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, strategies as st

so that with hypothesis absent the ``@given`` tests SKIP (not error) while
every other test in the module still runs. The strategy stubs only need to
survive being *called* at module-collection time — the decorated test bodies
never execute.
"""
from __future__ import annotations

import pytest

_SKIP_REASON = "hypothesis not installed (pip install -r requirements-dev.txt)"


class _Strategy:
    """Inert placeholder returned by every strategy constructor."""

    def __getattr__(self, name):          # .map(...), .filter(...), ...
        return lambda *a, **k: self


class _Strategies:
    """st.integers(...), st.floats(...), st.sampled_from(...), ... -> inert."""

    def __getattr__(self, name):
        return lambda *a, **k: _Strategy()


strategies = _Strategies()


def given(*_args, **_kwargs):
    """Decorator: mark the test skipped instead of running the property."""
    def deco(fn):
        return pytest.mark.skip(reason=_SKIP_REASON)(fn)

    return deco


def settings(*_args, **_kwargs):
    """No-op decorator (accepts max_examples=, deadline=, ...)."""
    def deco(fn):
        return fn

    return deco


def assume(_condition) -> bool:
    """Never reached — @given bodies are skipped — but importable."""
    return True


class _HealthCheck:
    """Attribute sink so ``suppress_health_check=[HealthCheck.x]`` parses."""

    def __getattr__(self, name):
        return name


# exported as an instance (like ``strategies``) so the class-style access
# ``HealthCheck.too_slow`` hits __getattr__ instead of raising AttributeError
HealthCheck = _HealthCheck()
