"""Stand-in for ``hypothesis`` when it is not installed.

Property-based tests are a dev-extra (requirements-dev.txt); the tier-1 suite
must collect and RUN without them. Modules that use hypothesis import it as

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, strategies as st

With hypothesis absent the ``@given`` tests DEGRADE instead of skipping: each
strategy stub exposes a small deterministic example set (the corners of its
range), and the decorated test body runs once per corner tuple. That is far
weaker than real property search — no shrinking, no random exploration — but
it keeps the property's assertions exercised on minimal installs, where these
tests used to show up as 7 permanent skips in the tier-1 run.

Strategies without a meaningful corner set make ``given`` fall back to a
skip, so collection never errors on an unsupported strategy.
"""
from __future__ import annotations

import inspect

import pytest

_SKIP_REASON = ("hypothesis not installed and no stub corner examples for "
                "this strategy (pip install -r requirements-dev.txt)")


class _AssumeFailed(Exception):
    """Raised by ``assume(False)``: discards the current corner example."""


class _Strategy:
    """Deterministic corner-example set standing in for a search strategy."""

    def __init__(self, examples=None):
        self.examples = list(examples) if examples else None   # None: unknown

    def map(self, f):
        if self.examples is None:
            return _Strategy(None)
        return _Strategy([f(e) for e in self.examples])

    def filter(self, pred):
        if self.examples is None:
            return _Strategy(None)
        kept = [e for e in self.examples if pred(e)]
        return _Strategy(kept or None)

    def __getattr__(self, name):          # anything exotic -> unknown
        return lambda *a, **k: _Strategy(None)


def _bounds(args, kwargs, lo_key, hi_key, defaults):
    lo = kwargs.get(lo_key, args[0] if len(args) > 0 else defaults[0])
    hi = kwargs.get(hi_key, args[1] if len(args) > 1 else defaults[1])
    return lo, hi


class _Strategies:
    """st.integers(...), st.floats(...), st.sampled_from(...), ... — each
    returns a _Strategy whose examples are the corners of the search space."""

    def integers(self, *args, **kwargs):
        lo, hi = _bounds(args, kwargs, "min_value", "max_value", (0, 100))
        mid = (lo + hi) // 2
        return _Strategy(sorted({lo, mid, hi}))

    def floats(self, *args, **kwargs):
        lo, hi = _bounds(args, kwargs, "min_value", "max_value", (0.0, 1.0))
        return _Strategy(sorted({float(lo), (float(lo) + float(hi)) / 2.0,
                                 float(hi)}))

    def booleans(self):
        return _Strategy([False, True])

    def sampled_from(self, elements):
        elements = list(elements)
        return _Strategy(elements if elements else None)

    def just(self, value):
        return _Strategy([value])

    def __getattr__(self, name):          # unknown strategy kind -> skip
        return lambda *a, **k: _Strategy(None)


strategies = _Strategies()


def given(*args, **kwargs):
    """Decorator: run the test once per corner-example tuple.

    Example i of each kwarg's strategy is combined positionally (clamped to
    the strategy's last example), so N corners cost N runs, not a cartesian
    product. Positional strategies or strategies without examples fall back
    to a skip, exactly like the old stub.
    """
    if args or not kwargs or any(s.examples is None for s in kwargs.values()):
        def skip_deco(fn):
            return pytest.mark.skip(reason=_SKIP_REASON)(fn)
        return skip_deco

    rounds = max(len(s.examples) for s in kwargs.values())
    corner_sets = [
        {k: s.examples[min(i, len(s.examples) - 1)]
         for k, s in kwargs.items()}
        for i in range(rounds)
    ]

    def deco(fn):
        def run(*fargs, **fkwargs):
            ran = 0
            for corners in corner_sets:
                try:
                    fn(*fargs, **corners, **fkwargs)
                    ran += 1
                except _AssumeFailed:
                    continue
            if ran == 0:
                pytest.skip("all stub corner examples rejected by assume()")

        # pytest resolves fixtures from the signature: expose the original
        # minus the strategy-bound parameters (what hypothesis itself does)
        sig = inspect.signature(fn)
        run.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in kwargs])
        run.__name__ = fn.__name__
        run.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run._hypothesis_stub_corners = corner_sets   # introspectable in tests
        return run

    return deco


def settings(*_args, **_kwargs):
    """No-op decorator (accepts max_examples=, deadline=, ...)."""
    def deco(fn):
        return fn

    return deco


def assume(condition):
    """Discard the current corner example when its precondition fails."""
    if not condition:
        raise _AssumeFailed()
    return True


class _HealthCheck:
    """Attribute sink so ``suppress_health_check=[HealthCheck.x]`` parses."""

    def __getattr__(self, name):
        return name


# exported as an instance (like ``strategies``) so the class-style access
# ``HealthCheck.too_slow`` hits __getattr__ instead of raising AttributeError
HealthCheck = _HealthCheck()
