"""Convergence-contract regression tests (paper Theorem 1).

The paper's headline theory: FedOSAA converges locally linearly, with a
provably FASTER linear rate than the first-order method it accelerates
(FedSVRG ≡ FedLin). These tests pin that contract as a measured regression on
a small strongly convex quadratic — the setting of the theorem — by fitting
each method's per-round contraction factor ρ (the geometric mean of
e_{t+1}/e_t over the clean linear regime, above the f32 fixed-point floor)
and asserting, with seeded tolerances:

  1. both methods actually contract linearly (log-linear fit is tight);
  2. ρ(FedOSAA-SVRG) beats ρ(FedSVRG) by a wide measured margin;
  3. ρ(FedOSAA-SVRG) beats the FIRST-ORDER theoretical rate (1 − ημ)^L —
     the rate a perfectly-corrected L-step first-order method cannot beat
     on a quadratic — so the win is structural (the AA step), not tuning.

A quadratic is used because the theorem's constants are exact there: client
Hessians are constant, FedSVRG's correction makes every local step a
full-gradient step, and μ/L are computable from the data.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AlgoHParams, run_federated, solve_reference
from repro.core.problem import FLProblem, StackedClients

K, N_PER, D = 4, 256, 8
GAMMA = 1e-2
ETA = 0.2
LOCAL_EPOCHS = 5
SEED = 0
# Client-Hessian spread: A_k deviates from A by O(√(D/N_PER)) sample noise
# plus this deliberate scale skew. FedOSAA's quadratic rate is governed by
# that spread (its AA step is a per-client-curvature solve), so the skew is
# kept mild — the contract under test is the rate ORDERING, not AA under
# extreme curvature heterogeneity.
SCALE_HET = 0.2


def _make_quadratic_problem():
    """K heterogeneous least-squares clients: f_k(w) = ½·mean_i (x_i'w − y_i)²
    + ½γ‖w‖² — strongly convex, constant Hessian A_k = X_k'X_k/n + γI."""
    rng = np.random.default_rng(SEED)
    w_true = rng.standard_normal(D)
    xs, ys = [], []
    for k in range(K):
        X = rng.standard_normal((N_PER, D)) * (1.0 + SCALE_HET * k / K)
        # heterogeneity: each client regresses toward a shifted target
        y = X @ (w_true + 0.3 * rng.standard_normal(D)) + 0.1 * rng.standard_normal(N_PER)
        xs.append(X)
        ys.append(y)
    clients = StackedClients(
        x=jnp.asarray(np.stack(xs), jnp.float32),
        y=jnp.asarray(np.stack(ys), jnp.float32),
        mask=jnp.ones((K, N_PER), jnp.float32),
        weight=jnp.full((K,), 1.0 / K, jnp.float32),
    )

    def loss(w, batch):
        r = batch.x @ w - batch.y
        denom = jnp.maximum(jnp.sum(batch.mask), 1.0)
        return (0.5 * jnp.sum(batch.mask * r * r) / denom
                + 0.5 * GAMMA * jnp.sum(w * w))

    problem = FLProblem(
        loss=loss,
        init=lambda rng_: jnp.zeros((D,), jnp.float32),
        clients=clients,
    )
    # exact global Hessian spectrum (for the theoretical first-order rate)
    A = sum((np.stack(xs)[k].T @ np.stack(xs)[k] / N_PER) / K for k in range(K))
    A += GAMMA * np.eye(D)
    evals = np.linalg.eigvalsh(A)
    return problem, float(evals[0]), float(evals[-1])


@pytest.fixture(scope="module")
def quadratic():
    problem, mu, lip = _make_quadratic_problem()
    wstar = solve_reference(problem, iters=20)
    return problem, wstar, mu, lip


def _fitted_rate(rel_error, floor=3e-5):
    """Per-round linear contraction factor ρ and the log-linear fit residual,
    over the clean regime: rounds before the trace hits the f32 floor."""
    e = np.asarray(rel_error, np.float64)
    keep = e > floor
    # stop at the first floored round; need >= 3 points for a meaningful fit
    n = int(np.argmin(keep)) if not keep.all() else len(e)
    e = e[:n]
    assert len(e) >= 3, f"trace floored too fast to fit a rate: {rel_error}"
    t = np.arange(len(e))
    slope, intercept = np.polyfit(t, np.log(e), 1)
    resid = np.log(e) - (slope * t + intercept)
    return float(np.exp(slope)), float(np.max(np.abs(resid)))


class TestTheorem1Contract:
    def test_fedosaa_rate_beats_fedsvrg_rate(self, quadratic):
        problem, wstar, mu, lip = quadratic
        hp = AlgoHParams(eta=ETA, local_epochs=LOCAL_EPOCHS)
        h_svrg = run_federated(problem, "fedsvrg", hp, 25, rng=SEED,
                               w_star=wstar)
        h_osaa = run_federated(problem, "fedosaa_svrg", hp, 25, rng=SEED,
                               w_star=wstar)
        rho_svrg, fit_svrg = _fitted_rate(h_svrg.rel_error)
        rho_osaa, fit_osaa = _fitted_rate(h_osaa.rel_error)

        # 1. both contract linearly: ρ < 1 with a tight log-linear fit
        #    (a superlinear/stalling trace shows up as large fit residual)
        assert rho_svrg < 1.0 and rho_osaa < 1.0
        assert fit_svrg < 0.5, (rho_svrg, fit_svrg)

        # 2. the Theorem-1 ordering, pinned with a seeded margin: FedOSAA's
        #    measured rate is at most HALF FedSVRG's (measured ρ≈0.065 vs
        #    ρ≈0.29 on this problem — the margin has ~2x slack to rng drift)
        assert rho_osaa < 0.5 * rho_svrg, (rho_osaa, rho_svrg)

        # 3. and beats the first-order THEORETICAL per-round rate (1−ημ)^L:
        #    faster than any perfectly-corrected L-step first-order method
        first_order_rate = (1.0 - ETA * mu) ** LOCAL_EPOCHS
        assert rho_osaa < first_order_rate, (rho_osaa, first_order_rate)
        # sanity on the harness itself: FedSVRG cannot beat its own bound
        # by more than fit noise (it IS an L-step corrected method)
        assert rho_svrg > 0.5 * first_order_rate, (rho_svrg, first_order_rate)

    def test_contract_survives_int8_wire(self, quadratic):
        """The stateful compressed wire must preserve the Theorem-1 ordering.
        Stochastic-rounding noise makes a per-round rate fit fragile, so the
        pinned contract is rounds-to-target: FedOSAA under int8 must reach
        1e-4 at least two rounds before FedSVRG under int8 (measured 5 vs 8
        rounds on this seed)."""
        problem, wstar, mu, lip = quadratic
        hp = AlgoHParams(eta=ETA, local_epochs=LOCAL_EPOCHS)
        target = 1e-4

        def rounds_to(h):
            hit = np.nonzero(np.asarray(h.rel_error) < target)[0]
            assert hit.size, f"never reached {target}: {h.rel_error}"
            return int(hit[0]) + 1

        h_svrg = run_federated(problem, "fedsvrg", hp, 25, rng=SEED,
                               w_star=wstar, channel="int8",
                               stop_rel_error=0.1 * target)
        h_osaa = run_federated(problem, "fedosaa_svrg", hp, 25, rng=SEED,
                               w_star=wstar, channel="int8",
                               stop_rel_error=0.1 * target)
        assert rounds_to(h_osaa) <= rounds_to(h_svrg) - 2, (
            h_osaa.rel_error, h_svrg.rel_error)
