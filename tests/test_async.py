"""Deadline-gated buffered aggregation (repro/robust/async_agg).

Contracts, matching the subsystem's acceptance criteria:

  1. An inactive AsyncConfig (deadline=0, or async_cfg=None) compiles the
     BYTE-IDENTICAL synchronous round on both runtimes — the gate is
     python-gated out of the graph (TestInactiveGate).
  2. A zero-arrival round (every latency past the deadline, min_arrivals=0)
     is a bit-exact no-op on the global iterate: every late client's delta
     lands in the carried buffer instead (TestZeroArrivals).
  3. min_arrivals extends the effective deadline in-graph: at least that
     many latencies always beat it (TestPlanAsync).
  4. The buffer lifecycle: a late client's update is deferred with age 1,
     ages while it waits, and folds into the first round whose deadline it
     beats with weight discounted as (1+s)^-alpha (TestBufferLifecycle).
  5. Discounted weights are finite, non-negative, and renormalize to 1 —
     or the round contributes nothing at all (the hypothesis property,
     TestWeightsProperty; degrades to corner examples without hypothesis).
  6. Mixed latency+dropout gated rounds are bit-deterministic across
     repeats, and the vmap/sharded runtimes realize bit-identical
     arrival/staleness schedules (TestDeterminism).
  7. Stale folds never enter recorded AA residual history as fresh: with
     guard_history=True the folded/waiting clients' history rows keep their
     exact bits (TestHistoryGuard).
  8. The async triple reaches RoundMetrics and the staleness_runaway alarm
     watches it (TestTelemetry); Newton-family rounds (directions, not
     deltas) refuse an active gate loudly (TestNewtonRefusal).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degraded single-example mode; see tests/_hypothesis_stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import AlgoHParams, init_state, make_round_fn, run_federated
from repro.core.sharded import make_sharded_round_fn
from repro.data import make_binary_classification, partition
from repro.launch.mesh import make_host_mesh
from repro.models.logreg import make_logreg_problem
from repro.robust import (
    ASYNC_AGE_KEY,
    ASYNC_BUF_KEY,
    AsyncConfig,
    FaultPlan,
    discounted_weights,
    init_async_comm,
    plan_async,
)

K = 8

#: heavy-tailed latency plan + a gate that usually lands most clients
LATENCY_PLAN = FaultPlan(seed=5, latency_scale=1.0, latency_shape=1.5)
GATE = AsyncConfig(deadline=2.0, min_arrivals=2, staleness_alpha=0.5)


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=800, seed=0)
    clients = partition(X, y, num_clients=K, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    return prob, make_host_mesh()


@pytest.fixture
def setup64():
    """f64 for cross-runtime sweeps: the AA Gram solve amplifies the shard
    boundary ulp past f32's rtol headroom (see tests/test_robust.py)."""
    was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        X, y = make_binary_classification("synthetic_small", n=800, seed=0)
        clients = partition(X, y, num_clients=K, scheme="iid")
        prob = make_logreg_problem(clients, gamma=1e-3, dtype=jnp.float64)
        yield prob, make_host_mesh()
    finally:
        jax.config.update("jax_enable_x64", was)


def _init(prob, hp, algo="fedosaa_svrg", async_cfg=None):
    state = init_state(prob, jax.random.PRNGKey(0), hp, None, algo)
    if async_cfg is not None and async_cfg.active:
        state = state._replace(comm=init_async_comm(
            state.comm, state.params, prob.clients.num_clients))
    return state


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


class TestConfig:
    def test_validation(self):
        with pytest.raises(ValueError):
            AsyncConfig(deadline=-1.0)
        with pytest.raises(ValueError):
            AsyncConfig(deadline=1.0, min_arrivals=-1)
        with pytest.raises(ValueError):
            AsyncConfig(deadline=1.0, staleness_alpha=-0.5)

    def test_active(self):
        assert not AsyncConfig().active
        assert AsyncConfig(deadline=0.5).active


class TestInactiveGate:
    """async_cfg=None and AsyncConfig(deadline=0) compile the same round."""

    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_bit_identical(self, setup, runtime):
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        if runtime == "sharded":
            f0 = make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh)
            f1 = make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh,
                                       async_cfg=AsyncConfig())
        else:
            f0 = make_round_fn("fedosaa_svrg", prob, hp)
            f1 = make_round_fn("fedosaa_svrg", prob, hp,
                               async_cfg=AsyncConfig())
        state = _init(prob, hp)
        s0, m0 = jax.jit(f0)(state)
        s1, m1 = jax.jit(f1)(state)
        for field in s0._fields:
            assert _leaves_equal(getattr(s0, field), getattr(s1, field)), field
        np.testing.assert_array_equal(np.asarray(m0.loss), np.asarray(m1.loss))
        # inactive gate reports the null async triple
        assert not np.isfinite(float(m1.staleness_mean))
        assert not np.isfinite(float(m1.staleness_max))


class TestPlanAsync:
    def test_min_arrivals_extends_deadline(self):
        lat = jnp.asarray([5.0, 3.0, 9.0, 1.0])
        age = jnp.zeros(4, jnp.int32)
        pw = jnp.full((4,), 0.25)
        cfg = AsyncConfig(deadline=0.5, min_arrivals=2)
        ar = plan_async(cfg, lat, age, pw)
        assert float(ar.deadline) == 3.0  # 2nd order statistic
        assert int(jnp.sum(ar.fresh)) == 2
        np.testing.assert_allclose(float(jnp.sum(ar.fresh_weights)), 1.0,
                                   rtol=1e-6)

    def test_drop_blocks_landing_but_not_deferral(self):
        """A dropped on-time client contributes nothing this round, yet a
        dropped LATE client still buffers client-side (the dropout models
        the uplink, not the client's compute)."""
        lat = jnp.asarray([0.1, 0.1, 9.0, 9.0])
        age = jnp.zeros(4, jnp.int32)
        pw = jnp.full((4,), 0.25)
        drop = jnp.asarray([True, False, True, False])
        ar = plan_async(AsyncConfig(deadline=1.0), lat, age, pw, drop=drop)
        np.testing.assert_array_equal(np.asarray(ar.fresh),
                                      [False, True, False, False])
        np.testing.assert_array_equal(np.asarray(ar.defer),
                                      [False, False, True, True])

    def test_fold_staleness_discount(self):
        lat = jnp.asarray([0.1, 0.1])
        age = jnp.asarray([0, 3], jnp.int32)
        pw = jnp.full((2,), 0.5)
        ar = plan_async(AsyncConfig(deadline=1.0, staleness_alpha=1.0),
                        lat, age, pw)
        # fresh weight 0.5, fold weight 0.5*(1+3)^-1 — renormalized
        w = np.asarray(ar.weights)
        np.testing.assert_allclose(w[1] / w[0], 0.25, rtol=1e-6)
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(ar.staleness), [0.0, 3.0])


class TestZeroArrivals:
    def test_noop_round_buffers_everyone(self, setup):
        """Every client late: w^{t+1} == w^t bit-exactly, every delta lands
        in the carried buffer with age 1."""
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        # latencies ~ 5*lognormal(0.01): all ≈ 5, deadline far below
        plan = FaultPlan(seed=1, latency_scale=5.0, latency_shape=0.01)
        cfg = AsyncConfig(deadline=0.5)
        state = _init(prob, hp, async_cfg=cfg)
        rf = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, faults=plan,
                                   async_cfg=cfg))
        s, m = rf(state)
        assert _leaves_equal(state.params, s.params)
        assert float(m.arrivals) == 0.0
        assert not np.isfinite(float(m.staleness_mean))  # nothing landed
        ages = np.asarray(s.comm[ASYNC_AGE_KEY])
        np.testing.assert_array_equal(ages, np.ones(K, np.int32))
        buf_norm = sum(float(jnp.sum(jnp.abs(l)))
                       for l in jax.tree.leaves(s.comm[ASYNC_BUF_KEY]))
        assert buf_norm > 0.0  # the computed deltas were kept, not lost


class TestBufferLifecycle:
    def test_defer_then_fold(self, setup):
        """Round 0 buffers every client (tight deadline); round 1's loose
        deadline folds them back discounted: ages return to 0 and the
        iterate moves."""
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        plan = FaultPlan(seed=1, latency_scale=5.0, latency_shape=0.01)
        tight = AsyncConfig(deadline=0.5)
        loose = AsyncConfig(deadline=50.0)
        state = _init(prob, hp, async_cfg=tight)
        rf_tight = jax.jit(make_round_fn("fedosaa_svrg", prob, hp,
                                         faults=plan, async_cfg=tight))
        rf_loose = jax.jit(make_round_fn("fedosaa_svrg", prob, hp,
                                         faults=plan, async_cfg=loose))
        s1, _ = rf_tight(state)
        s2, m2 = rf_loose(s1)
        assert not _leaves_equal(s1.params, s2.params)
        assert float(m2.arrivals) == float(K)
        assert float(m2.staleness_max) == 1.0
        np.testing.assert_array_equal(np.asarray(s2.comm[ASYNC_AGE_KEY]),
                                      np.zeros(K, np.int32))

    def test_retained_buffer_ages(self, setup):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        plan = FaultPlan(seed=1, latency_scale=5.0, latency_shape=0.01)
        cfg = AsyncConfig(deadline=0.5)
        state = _init(prob, hp, async_cfg=cfg)
        rf = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, faults=plan,
                                   async_cfg=cfg))
        s, _ = rf(state)
        buf1 = s.comm[ASYNC_BUF_KEY]
        s, _ = rf(s)
        np.testing.assert_array_equal(np.asarray(s.comm[ASYNC_AGE_KEY]),
                                      np.full(K, 2, np.int32))
        # a waiting client's buffered delta keeps its exact bits
        assert _leaves_equal(buf1, s.comm[ASYNC_BUF_KEY])


class TestWeightsProperty:
    """For ANY arrival mask and staleness vector — all-late, all-on-time,
    and everything between — the discounted weights are finite,
    non-negative, and renormalize to 1, or the round contributes nothing."""

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n=st.integers(1, 16),
           alpha=st.floats(0.0, 4.0, allow_nan=False),
           mode=st.sampled_from(["random", "none", "all"]))
    def test_weights_partition_of_unity(self, seed, n, alpha, mode):
        rng = np.random.default_rng(seed)
        if mode == "none":
            contribute = np.zeros(n, bool)
        elif mode == "all":
            contribute = np.ones(n, bool)
        else:
            contribute = rng.random(n) < rng.random()
        staleness = rng.integers(0, 1000, n).astype(np.float32)
        base = rng.random(n).astype(np.float32) + 1e-3
        base /= base.sum()
        w = np.asarray(discounted_weights(
            jnp.asarray(base), jnp.asarray(contribute),
            jnp.asarray(staleness), alpha))
        assert np.all(np.isfinite(w))
        assert np.all(w >= 0.0)
        assert np.all(w[~contribute] == 0.0)
        total = float(w.sum())
        if contribute.any():
            np.testing.assert_allclose(total, 1.0, rtol=1e-5)
        else:
            assert total == 0.0


class TestDeterminism:
    PLAN = FaultPlan(seed=3, drop_rate=0.2,
                     latency_scale=1.0, latency_shape=1.5)

    def test_repeats_bit_identical(self, setup):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        runs = [run_federated(prob, "fedosaa_svrg", hp, 4, faults=self.PLAN,
                              async_cfg=GATE) for _ in range(2)]
        np.testing.assert_array_equal(np.asarray(runs[0].loss),
                                      np.asarray(runs[1].loss))
        np.testing.assert_array_equal(np.asarray(runs[0].arrivals),
                                      np.asarray(runs[1].arrivals))

    def test_runtime_schedules_bit_identical(self, setup64):
        """vmap and sharded realize the same arrivals/staleness schedule
        (the gate is keyed by (seed, round, global id), never layout)."""
        prob, mesh = setup64
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        fv = jax.jit(make_round_fn("fedosaa_svrg", prob, hp,
                                   faults=self.PLAN, async_cfg=GATE))
        fs = jax.jit(make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh,
                                           faults=self.PLAN, async_cfg=GATE))
        sv = ss = _init(prob, hp, async_cfg=GATE)
        for t in range(3):
            sv, mv = fv(sv)
            ss, ms = fs(ss)
            for f in ("arrivals", "staleness_mean", "staleness_max"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(mv, f)), np.asarray(getattr(ms, f)),
                    err_msg=f"round {t} {f}")
            np.testing.assert_array_equal(
                np.asarray(sv.comm[ASYNC_AGE_KEY]),
                np.asarray(ss.comm[ASYNC_AGE_KEY]), err_msg=f"round {t}")
            for a, b in zip(jax.tree.leaves(sv.params),
                            jax.tree.leaves(ss.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6, atol=1e-9,
                                           err_msg=f"round {t}")


class TestHistoryGuard:
    def _states(self, setup, guard):
        """Round 1: heavy-tailed latencies against a median deadline — the
        fast clients land fresh (the iterate moves), the stragglers buffer.
        Round 2: a loose deadline folds the stragglers back. Returns the
        pre/post states of round 2 and the straggler mask."""
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, carry_history=2)
        cfg = AsyncConfig(deadline=1.0, guard_history=guard)
        loose = AsyncConfig(deadline=1e6, guard_history=guard)
        state = _init(prob, hp, async_cfg=cfg)
        s1, _ = jax.jit(make_round_fn("fedosaa_svrg", prob, hp,
                                      faults=LATENCY_PLAN,
                                      async_cfg=cfg))(state)
        busy = np.asarray(s1.comm[ASYNC_AGE_KEY]) > 0
        assert busy.any() and (~busy).any()  # the scenario needs both kinds
        s2, _ = jax.jit(make_round_fn("fedosaa_svrg", prob, hp,
                                      faults=LATENCY_PLAN,
                                      async_cfg=loose))(s1)
        return s1, s2, busy

    @staticmethod
    def _rows(tree, rows):
        return [np.asarray(l)[rows] for l in jax.tree.leaves(tree)]

    def test_guard_freezes_fold_rows(self, setup):
        """A stale fold must not enter recorded AA residual history as
        fresh: with the guard on, the folded clients' history rows keep
        their exact pre-round bits while fresh clients' rows advance."""
        s1, s2, busy = self._states(setup, guard=True)
        for field in ("hist_s", "hist_y"):
            for a, b in zip(self._rows(getattr(s1, field), busy),
                            self._rows(getattr(s2, field), busy)):
                np.testing.assert_array_equal(a, b, err_msg=field)
        moved = any(
            not np.array_equal(a, b)
            for a, b in zip(self._rows(s1.hist_y, ~busy),
                            self._rows(s2.hist_y, ~busy)))
        assert moved

    def test_unguarded_fold_writes_history(self, setup):
        """guard_history=False is the measured alternative (clip_rtol
        age-screening): the fold's history write goes through."""
        s1, s2, busy = self._states(setup, guard=False)
        moved = any(
            not np.array_equal(a, b)
            for a, b in zip(self._rows(s1.hist_y, busy),
                            self._rows(s2.hist_y, busy)))
        assert moved


class TestTelemetry:
    def test_staleness_runaway_alarm(self):
        from repro.obs.alarms import AlarmMonitor

        mon = AlarmMonitor()
        row = {"kind": "round", "round": 1, "loss": 1.0, "staleness_max": 12.0}
        mon.emit([row])
        assert any(e["rule"] == "staleness_runaway" for e in mon.events)
        # async-off rows carry null — the alarm must never fire on them
        mon2 = AlarmMonitor()
        mon2.emit([{"kind": "round", "round": 1, "loss": 1.0,
                    "staleness_max": None}])
        assert not any(e["rule"] == "staleness_runaway" for e in mon2.events)

    def test_history_carries_async_columns(self, setup):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        plan = FaultPlan(seed=5, latency_scale=1.0, latency_shape=1.5)
        h = run_federated(prob, "fedosaa_svrg", hp, 4, faults=plan,
                          async_cfg=GATE, chunk=2)
        assert h.arrivals is not None and len(h.arrivals) == 4
        assert np.all(h.arrivals >= 0)
        assert h.staleness_max is not None


class TestNewtonRefusal:
    def test_newton_family_raises(self, setup):
        prob, mesh = setup
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        with pytest.raises(ValueError, match="delta-form"):
            make_round_fn("giant", prob, hp,
                          async_cfg=AsyncConfig(deadline=1.0))
        with pytest.raises(ValueError, match="delta-form"):
            make_sharded_round_fn("newton_gmres", prob, hp, mesh,
                                  async_cfg=AsyncConfig(deadline=1.0))
