"""Serving-runtime tests: slot server correctness vs. single-request decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.serve import Request, SlotServer
from repro.models.decoder import build_model


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("smollm-135m").reduced()
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    return cfg, model, params


def single_request_reference(cfg, model, params, prompt, n_new):
    """Greedy decode of one request via prefill+decode (the tested-good path)."""
    B = 1
    toks = jnp.asarray(prompt)[None, :]
    last, caches = jax.jit(
        lambda p, t: model.prefill(p, t, None, cache_len=len(prompt) + n_new + 1)
    )(params, toks)
    out = []
    tok = jnp.argmax(last[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out.append(int(tok[0, 0]))
    dec = jax.jit(model.decode_step)
    for i in range(n_new - 1):
        pos = jnp.full((B, 1), len(prompt) + i, jnp.int32)
        logits, caches = dec(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


class TestSlotServer:
    def test_matches_single_request_decode(self, small_model):
        """Batched slot serving must produce the same greedy tokens as the
        reference prefill+decode path for every request."""
        cfg, model, params = small_model
        rng = np.random.default_rng(0)
        P, N = 12, 6
        prompts = [rng.integers(0, cfg.vocab_size, P).astype(np.int32)
                   for _ in range(3)]
        refs = [single_request_reference(cfg, model, params, p, N)
                for p in prompts]
        reqs = [Request(i, p, N) for i, p in enumerate(prompts)]
        srv = SlotServer(model, params, batch_slots=4, cache_len=P + N + 2)
        srv.run(reqs)
        for req, ref in zip(reqs, refs):
            assert req.out == ref, (req.rid, req.out, ref)

    def test_more_requests_than_slots(self, small_model):
        cfg, model, params = small_model
        rng = np.random.default_rng(1)
        P, N = 8, 4
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, P).astype(np.int32), N)
                for i in range(5)]
        srv = SlotServer(model, params, batch_slots=2, cache_len=P + N + 2)
        stats = srv.run(reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.out) == N for r in reqs)
        assert stats["tokens"] == 5 * N


    def test_ssm_arch_slot_serving(self, small_model):
        """SSM (O(1)-state) archs serve through the same slot runtime."""
        cfg = get_arch("mamba2-2.7b").reduced()
        model = build_model(cfg)
        params = jax.jit(model.init)(jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        P, N = 8, 4
        prompt = rng.integers(0, cfg.vocab_size, P).astype(np.int32)
        ref = single_request_reference(cfg, model, params, prompt, N)
        req = Request(0, prompt, N)
        srv = SlotServer(model, params, batch_slots=2, cache_len=P + N + 2)
        srv.run([req])
        assert req.out == ref
