"""Integration tests: every FL algorithm on a small logistic regression,
asserting the paper's qualitative convergence ordering."""
import jax
import numpy as np
import pytest

from repro.core import AlgoHParams, init_state, make_round_fn, run_federated, solve_reference
from repro.core.algorithms import (
    ALGORITHMS,
    COMM_TABLE,
    _aggregate,
    _sample_cohort,
    comm_bytes_per_round,
    comm_floats_per_round,
    resolve_cohort_size,
)
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem
from repro.utils import tree_math as tm


@pytest.fixture(scope="module")
def logreg():
    X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    wstar = solve_reference(prob, iters=50)
    return prob, wstar


def rel_err(history, wstar):
    return history.rel_error[-1]


class TestConvergenceOrdering:
    """The paper's Figure 1/2 claims as assertions."""

    def test_fedosaa_beats_fedsvrg(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h_osaa = run_federated(prob, "fedosaa_svrg", hp, 10, w_star=wstar)
        h_svrg = run_federated(prob, "fedsvrg", hp, 10, w_star=wstar)
        assert rel_err(h_osaa, wstar) < 0.01 * rel_err(h_svrg, wstar)

    def test_fedosaa_tracks_newton_gmres(self, logreg):
        """FedOSAA ≈ Newton-GMRES (the paper's central approximation claim):
        same order of magnitude of error after the same rounds."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h_osaa = run_federated(prob, "fedosaa_svrg", hp, 8, w_star=wstar)
        h_ng = run_federated(prob, "newton_gmres", hp, 8, w_star=wstar)
        # both deep into linear convergence on an ill-conditioned synthetic
        assert rel_err(h_osaa, wstar) < 1e-2
        assert rel_err(h_ng, wstar) < 1e-3

    def test_fedosaa_scaffold_beats_scaffold(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h_a = run_federated(prob, "fedosaa_scaffold", hp, 12, w_star=wstar)
        h_b = run_federated(prob, "scaffold", hp, 12, w_star=wstar)
        assert rel_err(h_a, wstar) < 0.5 * rel_err(h_b, wstar)

    def test_fedosaa_beats_lbfgs(self, logreg):
        """Paper: 'constantly better than the one-step L-BFGS method'."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h_a = run_federated(prob, "fedosaa_svrg", hp, 10, w_star=wstar)
        h_l = run_federated(prob, "lbfgs", hp, 10, w_star=wstar)
        assert rel_err(h_a, wstar) < rel_err(h_l, wstar)

    def test_fedosaa_avg_fails(self, logreg):
        """Appendix D.4: AA cannot rescue FedAvg — no gradient correction
        means convergence to the wrong point."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=10)
        h = run_federated(prob, "fedosaa_avg", hp, 15, w_star=wstar)
        assert rel_err(h, wstar) > 1e-3   # stuck away from w*

    def test_giant_converges(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(local_epochs=10)
        h = run_federated(prob, "giant", hp, 8, w_star=wstar)
        assert rel_err(h, wstar) < 1e-4

    def test_dane_converges_fast(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(dane_newton_iters=8, dane_cg_iters=40)
        h = run_federated(prob, "dane", hp, 5, w_star=wstar)
        assert rel_err(h, wstar) < 1e-3

    def test_small_lr_still_accelerates(self, logreg):
        """Figure 1(a): FedOSAA improves across a wide η range, even η=0.01×
        optimal — because it approximates Newton-GMRES regardless of η."""
        prob, wstar = logreg
        hp = AlgoHParams(eta=0.05, local_epochs=10)
        h_osaa = run_federated(prob, "fedosaa_svrg", hp, 10, w_star=wstar)
        h_svrg = run_federated(prob, "fedsvrg", hp, 10, w_star=wstar)
        assert rel_err(h_osaa, wstar) < 0.1 * rel_err(h_svrg, wstar)

    def test_l3_matches_svrg_l30(self, logreg):
        """Figure 1(b): FedOSAA with L=3 ≈ FedSVRG with L=30."""
        prob, wstar = logreg
        h3 = run_federated(prob, "fedosaa_svrg", AlgoHParams(eta=1.0, local_epochs=3), 12, w_star=wstar)
        h30 = run_federated(prob, "fedsvrg", AlgoHParams(eta=1.0, local_epochs=30), 12, w_star=wstar)
        assert rel_err(h3, wstar) < 3 * rel_err(h30, wstar)


class TestMechanics:
    def test_all_algorithms_run_one_round(self, logreg):
        prob, _ = logreg
        hp = AlgoHParams(eta=0.5, local_epochs=3, dane_newton_iters=2, dane_cg_iters=5)
        for algo in ALGORITHMS:
            state = init_state(prob, jax.random.PRNGKey(0))
            fn = jax.jit(make_round_fn(algo, prob, hp))
            state2, m = fn(state)
            assert np.isfinite(float(m.loss)), algo
            assert int(state2.t) == 1, algo

    def test_minibatch_svrg_runs_and_converges(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(eta=0.5, local_epochs=5, batch_size=32)
        h = run_federated(prob, "fedosaa_svrg", hp, 12, w_star=wstar)
        # stochastic AA stagnates at the noise floor, but must beat init (=1.0)
        assert rel_err(h, wstar) < 0.5

    def test_carry_history_improves_convergence(self, logreg):
        """Beyond-paper (App. A option 1): carrying secant pairs across
        rounds enriches the Krylov space at zero gradient cost."""
        prob, wstar = logreg
        h_plain = run_federated(prob, "fedosaa_svrg",
                                AlgoHParams(eta=1.0, local_epochs=5), 10, w_star=wstar)
        h_carry = run_federated(prob, "fedosaa_svrg",
                                AlgoHParams(eta=1.0, local_epochs=5, carry_history=5),
                                10, w_star=wstar)
        assert rel_err(h_carry, wstar) < rel_err(h_plain, wstar)

    def test_partial_participation(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(eta=1.0, local_epochs=5, participation=0.5)
        h = run_federated(prob, "fedosaa_svrg", hp, 15, w_star=wstar)
        assert rel_err(h, wstar) < 0.5

    def test_comm_accounting_matches_table1(self, logreg):
        """On the default (fp32 identity) channel the byte counters are
        exactly 4 × the paper's Table 1 float units."""
        prob, _ = logreg
        d = 40
        hp = AlgoHParams(eta=1.0, local_epochs=2, dane_newton_iters=1, dane_cg_iters=3)
        for algo in ALGORITHMS:
            state = init_state(prob, jax.random.PRNGKey(0))
            fn = jax.jit(make_round_fn(algo, prob, hp))
            _, m = fn(state)
            _, units = COMM_TABLE[algo]
            assert float(m.comm_bytes) == pytest.approx(4 * units * d), algo
            assert float(m.comm_bytes) == pytest.approx(
                4 * comm_floats_per_round(algo, d)), algo
            assert float(m.comm_bytes) == pytest.approx(
                comm_bytes_per_round(algo, jax.numpy.zeros(d))), algo

    def test_comm_table_audit(self):
        """Paper Table 1 audit: both CommCost fields carry meaning and are
        mutually consistent — algorithms that need ∇f(w^t) before local work
        (SVRG family + every second-order method) pay 2 round trips AND ship
        2d uplink floats; SCAFFOLD piggybacks its 2d on a single exchange."""
        needs_global_grad = {"fedsvrg", "fedosaa_svrg", "lbfgs", "giant",
                             "newton_gmres", "dane"}
        for algo in ALGORITHMS:
            cost = COMM_TABLE[algo]
            assert cost.round_trips == (2 if algo in needs_global_grad else 1), algo
            expected_units = 1.0 if algo in ("fedavg", "fedosaa_avg") else 2.0
            assert cost.float_units == expected_units, algo

    @pytest.mark.parametrize("algo", ["giant", "newton_gmres"])
    def test_comm_accounting_line_search_extra(self, logreg, algo):
        """The GIANT backtracking path broadcasts the aggregated direction —
        exactly d extra floats on top of the Table 1 units."""
        prob, _ = logreg
        d = 40
        hp = AlgoHParams(local_epochs=2, line_search=True)
        state = init_state(prob, jax.random.PRNGKey(0))
        _, m = jax.jit(make_round_fn(algo, prob, hp))(state)
        _, units = COMM_TABLE[algo]
        assert float(m.comm_bytes) == pytest.approx(4 * (units + 1) * d)
        assert float(m.comm_bytes) == pytest.approx(
            4 * comm_floats_per_round(algo, d, line_search=True))
        # line_search on a non-Newton algorithm must NOT charge the extra d
        assert comm_floats_per_round("fedavg", d, line_search=True) == \
            pytest.approx(1.0 * d)

    def test_line_search_giant(self, logreg):
        prob, wstar = logreg
        hp = AlgoHParams(local_epochs=10, line_search=True)
        h = run_federated(prob, "giant", hp, 6, w_star=wstar)
        assert rel_err(h, wstar) < 1e-3

    def test_imbalance_weights_sum_to_one(self, logreg):
        X, y = make_binary_classification("synthetic_small", n=2000, seed=1)
        for scheme in ("iid", "imbalance", "label_skew"):
            clients = partition(X, y, num_clients=10, scheme=scheme)
            np.testing.assert_allclose(float(clients.weight.sum()), 1.0, rtol=1e-5)


class TestParticipation:
    """Dedicated coverage for the cohort sampler (resolve_cohort_size /
    _sample_cohort) and the partial-participation round behavior
    (AlgoHParams.participation < 1.0 / AlgoHParams.cohort_size)."""

    def _problem(self, K=10):
        X, y = make_binary_classification("synthetic_small", n=1000, seed=2)
        clients = partition(X, y, num_clients=K, scheme="imbalance")
        return make_logreg_problem(clients, gamma=1e-3)

    def test_resolve_cohort_size_routing(self):
        # full participation, no explicit cohort → dense path
        assert resolve_cohort_size(AlgoHParams(participation=1.0), 10) is None
        # participation < 1 derives C = max(1, round(p·K))
        assert resolve_cohort_size(AlgoHParams(participation=0.5), 10) == 5
        assert resolve_cohort_size(AlgoHParams(participation=1e-9), 10) == 1
        # explicit cohort_size wins, even at C == K
        hp = AlgoHParams(participation=0.5, cohort_size=10)
        assert resolve_cohort_size(hp, 10) == 10
        with pytest.raises(ValueError):
            resolve_cohort_size(AlgoHParams(cohort_size=11), 10)
        with pytest.raises(ValueError):
            resolve_cohort_size(AlgoHParams(cohort_size=0), 10)

    def test_sample_cohort_renormalizes(self):
        """The drawn indices are unique and the cohort weights sum to 1, so
        the delta-form aggregation stays exact under sampling."""
        prob = self._problem()
        for seed in range(5):
            idx, cw = _sample_cohort(prob.clients.weight, 5,
                                     jax.random.PRNGKey(seed))
            idx, cw = np.asarray(idx), np.asarray(cw)
            assert len(np.unique(idx)) == 5
            np.testing.assert_allclose(cw.sum(), 1.0, rtol=1e-6)

    def test_sample_cohort_identity_at_full_size(self):
        """C == K short-circuits to arange + the RAW data weights — the
        bit-identity anchor of the C=K parity tests (test_cohort.py)."""
        prob = self._problem()
        idx, cw = _sample_cohort(prob.clients.weight, 10, jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(idx), np.arange(10))
        np.testing.assert_array_equal(np.asarray(cw),
                                      np.asarray(prob.clients.weight))

    def test_sampling_prefers_large_clients(self):
        """The draw is data-size weighted: under the imbalance partition the
        largest client must appear in far more cohorts than the smallest."""
        prob = self._problem()
        w = np.asarray(prob.clients.weight)
        big, small = int(np.argmax(w)), int(np.argmin(w))
        hits = np.zeros(10)
        for seed in range(200):
            idx, _ = _sample_cohort(prob.clients.weight, 3,
                                    jax.random.PRNGKey(seed))
            hits[np.asarray(idx)] += 1
        assert hits[big] > 2 * hits[small]

    def test_aggregate_zero_weights_is_no_op(self):
        """The delta-form aggregation degrades to keeping the anchor — not a
        zeroed model — if every weight is zero."""
        anchor = jax.numpy.full((7,), 0.37)
        stacked = jax.random.normal(jax.random.PRNGKey(0), (4, 7))
        out = _aggregate(jax.numpy.zeros(4), stacked, anchor=anchor)
        np.testing.assert_allclose(np.asarray(out), np.asarray(anchor))

    @pytest.mark.parametrize("algo", ["fedosaa_svrg", "scaffold", "giant",
                                      "dane"])
    def test_singleton_cohort_round_is_finite(self, algo):
        """Vanishing participation now draws a 1-client cohort (never an
        empty round): the model still takes a finite, well-defined step."""
        prob = self._problem(K=8)
        hp = AlgoHParams(eta=0.5, local_epochs=2, participation=1e-9,
                         dane_newton_iters=1, dane_cg_iters=3)
        state = init_state(prob, jax.random.PRNGKey(0), hp)
        state = state._replace(params=state.params + 0.37)  # off-origin start
        new_state, m = jax.jit(make_round_fn(algo, prob, hp))(state)
        assert np.all(np.isfinite(np.asarray(new_state.params))), algo
        assert np.isfinite(float(m.loss))

    def test_vmap_and_sharded_draw_identical_cohorts(self):
        """The cohort draw happens in the shared prologue: with the same rng
        both runtimes pick the same clients, so full histories agree (non-AA
        algorithm — multi-round AA comparisons drift by amplified ulps, see
        test_sharded_runtime.py). Complements that module's per-round
        test_partial_participation."""
        prob = self._problem(K=8)
        hp = AlgoHParams(eta=0.5, local_epochs=3, participation=0.5)
        hv = run_federated(prob, "fedsvrg", hp, 4, rng=3)
        hs = run_federated(prob, "fedsvrg", hp, 4, rng=3, runtime="sharded")
        np.testing.assert_allclose(hv.loss, hs.loss, rtol=1e-5)

    def test_participation_converges_with_channel(self):
        """Partial participation composes with wire compression."""
        prob = self._problem(K=8)
        hp = AlgoHParams(eta=1.0, local_epochs=10, participation=0.75)
        wstar = solve_reference(prob, iters=50)
        h = run_federated(prob, "fedosaa_svrg", hp, 15, w_star=wstar,
                          channel="int8")
        assert h.rel_error[-1] < 0.3


class TestHeterogeneousDistributions:
    """Figure 2: FedOSAA keeps working under imbalance and label skew."""

    @pytest.mark.parametrize("scheme", ["imbalance", "label_skew"])
    def test_fedosaa_converges_under_heterogeneity(self, scheme):
        X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
        clients = partition(X, y, num_clients=10, scheme=scheme)
        prob = make_logreg_problem(clients, gamma=1e-3)
        wstar = solve_reference(prob, iters=50)
        eta = 1.0 if scheme == "imbalance" else 0.5   # paper: smaller η for skew
        h = run_federated(prob, "fedosaa_svrg", AlgoHParams(eta=eta, local_epochs=10), 15, w_star=wstar)
        assert h.rel_error[-1] < 1e-2
