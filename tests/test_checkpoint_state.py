"""Checkpointing the FULL round state (repro/checkpoint on ServerState).

The npz pytree checkpoint was written for params; these tests pin that it
round-trips the ENTIRE ServerState — params, per-client control variates,
the carried comm-channel state (int8 EF residuals + diff-coding refs), the
cross-round AA history columns, the PRNG key, and the round counter — and
that a run interrupted at round T, checkpointed, restored, and continued is
BIT-identical to the uninterrupted run. That is the property that makes
long engine runs resumable at all: any leaf silently dropped or cast would
show up here as a bit mismatch after resume.
"""
import jax
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import (
    AAConfig,
    AlgoHParams,
    init_state,
    make_round_fn,
    run_rounds,
    solve_reference,
)
from repro.data import make_binary_classification, partition
from repro.models.logreg import make_logreg_problem
from repro.obs import MemorySink


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=400, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    wstar = solve_reference(prob, iters=50)
    return prob, wstar


# the adversarial state shape: int8 wire (per-client EF residual buffers in
# ServerState.comm) AND cross-round AA history columns riding the carry
HP = dict(eta=0.5, local_epochs=3, carry_history=2,
          aa=AAConfig(tikhonov=1e-6, damping=0.7))


def _mk(prob, channel="int8"):
    hp = AlgoHParams(**HP)
    rf = make_round_fn("fedosaa_svrg", prob, hp, channel)
    mk_state = lambda: init_state(prob, jax.random.PRNGKey(0), hp, channel,
                                  "fedosaa_svrg")
    return rf, mk_state


def _assert_state_bitexact(a, b, what=""):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for (kp, x), y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{what}: leaf {jax.tree_util.keystr(kp)}")
        assert np.asarray(x).dtype == np.asarray(y).dtype, (
            f"{what}: dtype of {jax.tree_util.keystr(kp)}")


class TestFullStateRoundtrip:
    def test_server_state_roundtrips_bit_exact(self, setup, tmp_path):
        """Every ServerState leaf — comm buffers, AA history, rng, t —
        survives save→restore bit-exactly, with dtypes preserved."""
        prob, wstar = setup
        rf, mk_state = _mk(prob)
        state, _ = run_rounds(rf, mk_state(), 3, chunk=3, w_star=wstar)
        # the interesting leaves actually exist in this config
        assert state.comm is not None
        assert state.hist_s is not None
        path = str(tmp_path / "ckpt" / "state_3")
        save_checkpoint(path, state, step=3)
        restored = restore_checkpoint(path, like=mk_state())
        _assert_state_bitexact(state, restored, what="roundtrip")
        assert int(np.asarray(restored.t)) == int(np.asarray(state.t))
        np.testing.assert_array_equal(np.asarray(restored.rng),
                                      np.asarray(state.rng))

    def test_fresh_template_restore(self, setup, tmp_path):
        """Restore only needs a shape/dtype template, not the saved values:
        a freshly-initialized state works as ``like``."""
        prob, wstar = setup
        rf, mk_state = _mk(prob, channel=None)
        state, _ = run_rounds(rf, mk_state(), 2, chunk=2, w_star=wstar)
        path = str(tmp_path / "state_2")
        save_checkpoint(path, state, step=2)
        template = mk_state()
        restored = restore_checkpoint(path, like=template)
        _assert_state_bitexact(state, restored, what="fresh-template")
        # the template itself is untouched (t still 0)
        assert int(np.asarray(template.t)) == 0


class TestResumeMidRun:
    def test_resume_bit_identical_to_uninterrupted(self, setup, tmp_path):
        """Run 6 rounds straight vs run 3 → checkpoint → restore → run 3
        more: final state AND the continued metric rows are bit-identical.
        The restored rng/t make round 4 of the resumed run draw the exact
        minibatches/cohorts round 4 of the straight run drew."""
        prob, wstar = setup
        rf, mk_state = _mk(prob)

        straight, trace_straight = run_rounds(
            rf, mk_state(), 6, chunk=3, w_star=wstar)

        first, trace_first = run_rounds(rf, mk_state(), 3, chunk=3,
                                        w_star=wstar)
        np.testing.assert_array_equal(trace_first.loss,
                                      trace_straight.loss[:3])
        path = str(tmp_path / "mid_run")
        save_checkpoint(path, first, step=3)
        restored = restore_checkpoint(path, like=mk_state())
        resumed, trace_resumed = run_rounds(rf, restored, 3, chunk=3,
                                            w_star=wstar)

        _assert_state_bitexact(straight, resumed, what="resume")
        np.testing.assert_array_equal(trace_resumed.loss,
                                      trace_straight.loss[3:])
        np.testing.assert_array_equal(trace_resumed.grad_norm,
                                      trace_straight.grad_norm[3:])
        np.testing.assert_array_equal(trace_resumed.rel_error,
                                      trace_straight.rel_error[3:])
        np.testing.assert_array_equal(trace_resumed.gram_cond_max,
                                      trace_straight.gram_cond_max[3:])

    def test_resumed_telemetry_continues_round_numbering(self, setup,
                                                         tmp_path):
        """A resumed run's sink rows pick up the global round index via
        ``start_round`` — the JSONL streams of the two segments concatenate
        into one contiguous history."""
        prob, wstar = setup
        rf, mk_state = _mk(prob, channel=None)
        first, _ = run_rounds(rf, mk_state(), 3, chunk=3, w_star=wstar)
        path = str(tmp_path / "seg")
        save_checkpoint(path, first, step=3)
        restored = restore_checkpoint(path, like=mk_state())
        sink = MemorySink()
        run_rounds(rf, restored, 3, chunk=3, w_star=wstar, sinks=[sink],
                   start_round=3)
        assert [r["round"] for r in sink.rows] == [3, 4, 5]

class TestShardedFormatRoundtrip:
    def test_full_state_save_load_latest_bit_exact(self, setup, tmp_path):
        """The sharded manifest format (repro/checkpoint/sharded_ckpt) must
        carry the same full-ServerState contract as the legacy npz: run a
        few rounds so every buffer is non-trivial, write_checkpoint, and
        load_latest back bit-exact — dtypes included."""
        from repro.checkpoint import (
            load_latest,
            snapshot_shards,
            write_checkpoint,
        )

        prob, wstar = setup
        rf, mk_state = _mk(prob)
        state, _ = run_rounds(rf, mk_state(), 3, chunk=3, w_star=wstar)

        d = str(tmp_path)
        snap = snapshot_shards(state)
        path, nbytes = write_checkpoint(d, snap, 3, config={"algo": "x"})
        assert nbytes > 0

        tree, manifest = load_latest(d, mk_state())
        assert manifest["round"] == 3
        assert manifest["config"] == {"algo": "x"}
        _assert_state_bitexact(state, tree, what="sharded roundtrip")
        # the manifest inventory names what rode along
        inv = manifest["inventory"]
        assert inv["aa_history"] and inv["round_counter"] and inv["rng"]
        assert set(inv["comm_tags"]) == {"delta", "grad"}  # int8 EF + refs
