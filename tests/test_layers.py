"""Layer-level correctness tests against independent references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the module runs
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_arch
from repro.models import layers as Lyr
from repro.models.layers import Sharder

SH = Sharder()


# ---------------------------------------------------------------------------
# Mamba2 SSD: chunked dual form == naive sequential recurrence
# ---------------------------------------------------------------------------

def ssd_sequential_ref(xh, dt, A, Bm, Cm):
    """O(S·state) literal recurrence: h ← exp(dt·A)h + dt·B⊗x ; y = C·h."""
    B, S, nh, hd = xh.shape
    stt = Bm.shape[-1]
    h = np.zeros((B, nh, hd, stt), np.float64)
    ys = []
    xh, dt, Bm, Cm = (np.asarray(a, np.float64) for a in (xh, dt, Bm, Cm))
    A = np.asarray(A, np.float64)
    for s in range(S):
        dA = np.exp(dt[:, s] * A[None])                     # [B,nh]
        h = h * dA[..., None, None] + np.einsum(
            "bh,bhd,bs->bhds", dt[:, s], xh[:, s], Bm[:, s]
        )
        ys.append(np.einsum("bs,bhds->bhd", Cm[:, s], h))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
def test_ssd_chunked_matches_sequential(S, chunk):
    rng = np.random.default_rng(0)
    B, nh, hd, stt = 2, 3, 4, 8
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, stt)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, stt)), jnp.float32)
    y, final = Lyr._ssd_chunked_scan(xh, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = ssd_sequential_ref(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), h_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    S_chunks=st.sampled_from([(16, 4), (32, 8), (24, 8)]),
    seed=st.integers(0, 1000),
    a_scale=st.floats(0.1, 8.0),
)
def test_property_ssd_chunk_invariance(S_chunks, seed, a_scale):
    """SSD output must be invariant to the chunk size (pure reformulation)."""
    S, c1 = S_chunks
    rng = np.random.default_rng(seed)
    B, nh, hd, stt = 1, 2, 4, 4
    xh = jnp.asarray(rng.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (B, S, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.1, a_scale, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, stt)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, stt)), jnp.float32)
    y1, f1 = Lyr._ssd_chunked_scan(xh, dt, A, Bm, Cm, c1)
    y2, f2 = Lyr._ssd_chunked_scan(xh, dt, A, Bm, Cm, S)   # one big chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=3e-4, atol=3e-4)


def test_mamba_decode_matches_forward():
    """Token-by-token recurrent decode == chunked forward, full block level."""
    cfg = get_arch("mamba2-2.7b").reduced()
    p = Lyr.mamba_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32) * 0.3
    y_full, _ = Lyr.mamba_forward(p, x, cfg, SH)
    state = Lyr.init_ssm_state(cfg, B, jnp.float32)
    outs = []
    for s in range(S):
        y, state = Lyr.mamba_forward(p, x[:, s:s + 1], cfg, SH, state=state)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_step), np.asarray(y_full), rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_per_token_ref(p, x, cfg):
    """Literal per-token dropless reference: y = Σ_k w_k FFN_{e_k}(x)."""
    B, S, d = x.shape
    xt = np.asarray(x, np.float64).reshape(-1, d)
    router = np.asarray(p["router"], np.float64)
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.experts_per_token
    y = np.zeros_like(xt)
    wig = np.asarray(p["wi_gate"], np.float64)
    wiu = np.asarray(p["wi_up"], np.float64)
    wo = np.asarray(p["wo"], np.float64)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:k]
        w = probs[t][top]
        w = w / w.sum()
        for e, wi in zip(top, w):
            h = xt[t] @ wig[e]
            h = h / (1 + np.exp(-h)) * (xt[t] @ wiu[e])
            y[t] += wi * (h @ wo[e])
    return y.reshape(B, S, d)


def test_moe_dropless_matches_per_token_ref():
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    p = Lyr.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, _ = Lyr.moe(p, x, cfg, SH, dropless=True)
    y_ref = moe_per_token_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor c, at most T·k tokens-slots exist and the output
    must stay finite; dropped slots contribute exactly zero."""
    cfg = get_arch("llama4-scout-17b-a16e").reduced()
    p = Lyr.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    y, aux = Lyr.moe(p, x, cfg, SH, dropless=False)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.5   # load-balance loss ~1 at uniform routing


def test_moe_aux_loss_penalizes_imbalance():
    """Routing everything to one expert must raise the aux loss (≈E at full
    collapse vs ≈1 at uniform)."""
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    p = dict(Lyr.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    # positive inputs so a large positive router column forces expert 0
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))) + 0.1
    _, aux_uniform = Lyr.moe(p, x, cfg, SH)
    p_collapsed = dict(p)
    bias = jnp.zeros((cfg.d_model, cfg.num_experts)).at[:, 0].set(50.0)
    p_collapsed["router"] = p["router"] + bias
    _, aux_collapsed = Lyr.moe(p_collapsed, x, cfg, SH)
    assert float(aux_collapsed) > 2.0 * float(aux_uniform)


# ---------------------------------------------------------------------------
# attention variants
# ---------------------------------------------------------------------------

def test_sliding_window_matches_masked_full():
    """Sliding-window attention == full attention with an explicit band mask
    applied to the scores (independent einsum reference)."""
    cfg = get_arch("smollm-135m").reduced()
    p = Lyr.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, W = 2, 32, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y_win, _ = Lyr.attention(p, x, cfg, SH, pos, window=W)

    # reference: manual scores with band mask
    hd, H, KV = cfg.resolved_head_dim, cfg.eff_heads, cfg.eff_kv_heads
    q = Lyr.apply_rope((x @ p["wq"]).reshape(B, S, H, hd), pos, cfg.rope_theta)
    k = Lyr.apply_rope((x @ p["wk"]).reshape(B, S, KV, hd), pos, cfg.rope_theta)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    k = jnp.repeat(k, H // KV, 2)
    v = jnp.repeat(v, H // KV, 2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    i = jnp.arange(S)
    band = (i[None, :] <= i[:, None]) & (i[None, :] > i[:, None] - W)
    sc = jnp.where(band[None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", pr, v).reshape(B, S, H * hd) @ p["wo"]
    np.testing.assert_allclose(np.asarray(y_win), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_sliding_window_decode_ring_buffer():
    """Windowed decode with a ring buffer of size W must match windowed
    forward at every position past the window boundary."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("smollm-135m").reduced(), sliding_window=8)
    from repro.models.decoder import build_model
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    B, S, W = 2, 32, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size, jnp.int32)
    logits_full, _ = jax.jit(model.forward)(params, tokens, None)

    caches = model.init_caches(B, W)     # ring buffer = window size
    dec = jax.jit(model.decode_step)
    for i in range(S):
        pos = jnp.full((B, 1), i, jnp.int32)
        ls, caches = dec(params, caches, tokens[:, i:i + 1], pos)
        np.testing.assert_allclose(
            np.asarray(ls), np.asarray(logits_full[:, i]), rtol=2e-2, atol=2e-2
        )


def test_padded_heads_are_noops():
    """A config padded for 16-way TP must produce IDENTICAL outputs to the
    unpadded config at init (padded o_proj rows are zero)."""
    cfg = get_arch("smollm-135m").reduced()          # 4 heads, kv 2
    cfg_pad = cfg.padded(model_shards=8)             # pads q heads 4 -> 8
    assert cfg_pad.eff_heads == 8
    p = Lyr.attn_init(jax.random.PRNGKey(0), cfg_pad, jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y, _ = Lyr.attention(p, x, cfg_pad, SH, pos)
    # zero out padded-head inputs too: identical result (o_proj rows already 0)
    hd = cfg_pad.resolved_head_dim
    p2 = dict(p)
    mask_q = (jnp.arange(cfg_pad.eff_heads * hd) < cfg.num_heads * hd)
    p2["wq"] = p["wq"] * mask_q[None, :]
    y2, _ = Lyr.attention(p2, x, cfg_pad, SH, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-5, atol=1e-6)


def test_rms_norm_reference():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)
    y = Lyr.rms_norm(x, g)
    ref = np.asarray(x) / np.sqrt(np.mean(np.asarray(x) ** 2, -1, keepdims=True) + 1e-6) * np.asarray(g)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-6)


def test_rope_preserves_norm_and_relativity():
    B, S, H, hd = 1, 16, 2, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y = Lyr.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5,
    )
    # relativity: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd), jnp.float32)
    def dot_at(i, j):
        qi = Lyr.apply_rope(q, jnp.full((1, 1), i, jnp.int32), 10_000.0)
        kj = Lyr.apply_rope(k, jnp.full((1, 1), j, jnp.int32), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
