"""Fused dual-gradient local-trajectory kernels (kernels/local_update):
kernel↔oracle parity (bit-exact where shapes are granule-aligned), the
padded-row invariance property, fused↔autodiff round parity, and the
stack_client_arrays aggregation-weight regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests degrade to corner examples
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import (
    AlgoHParams,
    init_state,
    make_round_fn,
    resolve_local_impl,
    stack_client_arrays,
)
from repro.core.algorithms import _svrg_trajectory
from repro.core.sharded import make_sharded_round_fn
from repro.data import make_binary_classification, partition
from repro.kernels.local_update import fused_trajectory
from repro.launch.mesh import make_host_mesh
from repro.models.linreg import linreg_exact_solution, make_linreg_problem
from repro.models.logreg import make_logreg_problem
from repro.utils import tree_math as tm


@pytest.fixture(scope="module")
def logreg():
    X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    return make_logreg_problem(clients, gamma=1e-3)


@pytest.fixture
def x64():
    """Enable f64 for one test (the ext_compression pattern): the AA Gram
    solve amplifies last-ulp trajectory reorderings chaotically in f32 (the
    PR 4 lax.cond finding), so the ≤1e-6 fused↔tree ROUND contract is
    pinned where reordering noise is 1e-15, not 1e-7."""
    was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", was)


def _rand_case(rng, n, d, link, S=1):
    x = jnp.asarray(rng.standard_normal((S, n, d)), jnp.float32)
    if link == "logistic":
        y = jnp.asarray(rng.choice([-1.0, 1.0], (S, n)), jnp.float32)
    else:
        y = jnp.asarray(rng.standard_normal((S, n)), jnp.float32)
    mask = jnp.ones((S, n), jnp.float32)
    w0 = jnp.asarray(rng.standard_normal(d) * 0.1, jnp.float32)
    u = jnp.asarray(rng.standard_normal(d) * 0.01, jnp.float32)
    return x, y, mask, w0, u


# ---------------------------------------------------------------------------
# kernel (interpret mode) vs the jnp oracle
# ---------------------------------------------------------------------------

class TestFusedKernelParity:
    @pytest.mark.parametrize("link", ["logistic", "linear"])
    @pytest.mark.parametrize("anchor", [0.0, 1.0])
    def test_bit_exact_on_granule_shapes(self, link, anchor):
        """One granule-aligned row tile: the kernel IS the oracle, bitwise
        (same contractions, same cast points — ref.py docstring)."""
        rng = np.random.default_rng(hash((link, anchor)) % 2**31)
        x, y, mask, w0, u = _rand_case(rng, 384, 128, link)
        mask = mask.at[0, 350:].set(0.0)
        kw = dict(link=link, reg=1e-3, eta=0.5, anchor_scale=anchor, steps=11)
        wr, rr = fused_trajectory(x, y, mask, w0, u, impl="ref", **kw)
        wk, rk = fused_trajectory(x, y, mask, w0, u, impl="kernel",
                                  interpret=True, **kw)
        assert bool(jnp.all(wr == wk)), "w_traj not bit-exact vs ref"
        assert bool(jnp.all(rr == rk)), "r_traj not bit-exact vs ref"

    def test_bit_exact_minibatch_blocks(self):
        """S == steps per-step design blocks, granule-aligned: bit-exact."""
        rng = np.random.default_rng(3)
        x, y, mask, w0, u = _rand_case(rng, 128, 128, "logistic", S=5)
        kw = dict(link="logistic", reg=1e-3, eta=0.5, anchor_scale=1.0,
                  steps=5)
        wr, rr = fused_trajectory(x, y, mask, w0, u, impl="ref", **kw)
        wk, rk = fused_trajectory(x, y, mask, w0, u, impl="kernel",
                                  interpret=True, **kw)
        assert bool(jnp.all(wr == wk) & jnp.all(rr == rk))

    @pytest.mark.parametrize("n,d,row_tile", [
        (300, 54, None),      # ragged → padded, auto tile
        (1000, 54, 128),      # multi-tile: accumulator crosses 8 row tiles
        (384, 200, 128),      # ragged d, multi-tile
    ])
    def test_padded_and_tiled_allclose(self, n, d, row_tile):
        rng = np.random.default_rng(n + d)
        x, y, mask, w0, u = _rand_case(rng, n, d, "logistic")
        mask = mask.at[0, n - n // 8:].set(0.0)
        kw = dict(link="logistic", reg=1e-3, eta=0.5, anchor_scale=1.0,
                  steps=8)
        wr, rr = fused_trajectory(x, y, mask, w0, u, impl="ref", **kw)
        wk, rk = fused_trajectory(x, y, mask, w0, u, impl="kernel",
                                  interpret=True, row_tile=row_tile, **kw)
        np.testing.assert_allclose(np.asarray(wk), np.asarray(wr),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(rk), np.asarray(rr),
                                   rtol=1e-5, atol=1e-6)

    def test_vmapped_over_clients(self):
        """The round cores vmap the per-client call — kernel must match the
        oracle under batching (scratch re-initializes per client; vmap
        changes XLA fusion, so parity is to f32 reordering noise here)."""
        rng = np.random.default_rng(9)
        K, n, d = 3, 256, 128
        x = jnp.asarray(rng.standard_normal((K, 1, n, d)), jnp.float32)
        y = jnp.asarray(rng.choice([-1.0, 1.0], (K, 1, n)), jnp.float32)
        m = jnp.ones((K, 1, n), jnp.float32)
        w0 = jnp.asarray(rng.standard_normal((K, d)) * 0.1, jnp.float32)
        u = jnp.zeros((K, d), jnp.float32)

        def call(impl):
            return jax.vmap(lambda *a: fused_trajectory(
                *a, link="logistic", reg=1e-3, eta=0.5, anchor_scale=1.0,
                steps=4, impl=impl, interpret=True))(x, y, m, w0, u)

        (wr, rr), (wk, rk) = call("ref"), call("kernel")
        np.testing.assert_allclose(np.asarray(wk), np.asarray(wr),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(rk), np.asarray(rr),
                                   rtol=1e-5, atol=1e-6)

    def test_rejects_bad_link_and_impl(self):
        rng = np.random.default_rng(0)
        x, y, mask, w0, u = _rand_case(rng, 128, 128, "linear")
        with pytest.raises(ValueError, match="unknown link"):
            fused_trajectory(x, y, mask, w0, u, link="probit", reg=0.0,
                             eta=0.1, anchor_scale=0.0, steps=2)
        with pytest.raises(ValueError, match="unknown impl"):
            fused_trajectory(x, y, mask, w0, u, link="linear", reg=0.0,
                             eta=0.1, anchor_scale=0.0, steps=2, impl="cuda")


# ---------------------------------------------------------------------------
# property: padded rows never influence fused gradients or trajectories
# ---------------------------------------------------------------------------

class TestMaskedRowInvariance:
    @settings(max_examples=8, deadline=None)
    @given(n_valid=st.integers(5, 180), d=st.integers(3, 40),
           seed=st.integers(0, 99), minibatch=st.booleans())
    def test_padded_rows_never_influence(self, n_valid, d, seed, minibatch):
        """Randomize the padded region (mask == 0) of a ragged client: every
        fused output — both executors, both batch modes — must be unchanged
        down to the bit vs the zero-padded twin."""
        rng = np.random.default_rng(seed)
        n = n_valid + int(rng.integers(1, 64))
        steps = 4
        if minibatch:
            S, B = steps, 32
            x0 = rng.standard_normal((S, B, d))
            m = np.ones((S, B), np.float32)
            m[:, B - max(1, B // 4):] = 0.0   # padded tail per block
        else:
            S, B = 1, n
            x0 = rng.standard_normal((S, n, d))
            m = np.zeros((S, n), np.float32)
            m[:, :n_valid] = 1.0
        y0 = rng.choice([-1.0, 1.0], (S, B))
        w0 = jnp.asarray(rng.standard_normal(d) * 0.1, jnp.float32)
        u = jnp.asarray(rng.standard_normal(d) * 0.01, jnp.float32)
        garbage = rng.standard_normal(x0.shape) * 1e6
        x_dirty = np.where(m[..., None] > 0, x0, garbage)
        y_dirty = np.where(m > 0, y0, 7e9)
        kw = dict(link="logistic", reg=1e-3, eta=0.5, anchor_scale=1.0,
                  steps=steps)
        for impl in ("ref", "kernel"):
            clean = fused_trajectory(
                jnp.asarray(x0 * (m[..., None] > 0), jnp.float32),
                jnp.asarray(y0 * (m > 0), jnp.float32), jnp.asarray(m),
                w0, u, impl=impl, interpret=True, **kw)
            dirty = fused_trajectory(
                jnp.asarray(x_dirty, jnp.float32),
                jnp.asarray(y_dirty, jnp.float32), jnp.asarray(m),
                w0, u, impl=impl, interpret=True, **kw)
            for a, b in zip(clean, dirty):
                assert bool(jnp.all(a == b)), (
                    f"padded rows leaked into the {impl} trajectory")
                assert bool(jnp.all(jnp.isfinite(a)))


# ---------------------------------------------------------------------------
# fused vs autodiff: trajectory- and round-level
# ---------------------------------------------------------------------------

class TestFusedVsAutodiff:
    def test_trajectory_matches_autodiff(self, logreg):
        """Ops-level contract: the fused residuals equal the double-autodiff
        residuals to f32 reordering noise, step for step (L=10)."""
        hp_t = AlgoHParams(eta=1.0, local_epochs=10, local_impl="tree")
        hp_p = dataclasses.replace(hp_t, local_impl="pallas")
        w0 = logreg.init(jax.random.PRNGKey(0))
        g = logreg.global_grad(w0)
        batch = logreg.clients.client(0)
        rng = jax.random.PRNGKey(7)
        wt, rt = _svrg_trajectory(logreg, hp_t, w0, g, batch, rng)
        wp, rp = _svrg_trajectory(logreg, hp_p, w0, g, batch, rng)
        np.testing.assert_allclose(np.asarray(wp), np.asarray(wt), atol=5e-6)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rt), atol=5e-6)

    def test_trajectory_matches_autodiff_minibatch(self, logreg):
        """Minibatch mode draws the bit-identical rows the autodiff path
        samples (sample_minibatch_indices), live+anchor on the same ζ."""
        hp_t = AlgoHParams(eta=1.0, local_epochs=6, batch_size=32,
                           local_impl="tree")
        hp_p = dataclasses.replace(hp_t, local_impl="pallas")
        w0 = logreg.init(jax.random.PRNGKey(0))
        g = logreg.global_grad(w0)
        batch = logreg.clients.client(1)
        rng = jax.random.PRNGKey(3)
        wt, rt = _svrg_trajectory(logreg, hp_t, w0, g, batch, rng)
        wp, rp = _svrg_trajectory(logreg, hp_p, w0, g, batch, rng)
        np.testing.assert_allclose(np.asarray(wp), np.asarray(wt), atol=5e-6)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(rt), atol=5e-6)

    @pytest.mark.parametrize("algo", ["fedsvrg", "fedavg", "scaffold"])
    def test_round_parity_f32_non_aa(self, logreg, algo):
        """Without the AA amplifier the full f32 round agrees to ~1e-6."""
        hp_t = AlgoHParams(eta=1.0, local_epochs=10, local_impl="tree")
        hp_p = dataclasses.replace(hp_t, local_impl="pallas")
        outs = {}
        for tag, hp in (("tree", hp_t), ("pallas", hp_p)):
            rf = jax.jit(make_round_fn(algo, logreg, hp))
            st_ = init_state(logreg, jax.random.PRNGKey(0), hp, None, algo)
            for _ in range(3):
                st_, _m = rf(st_)
            outs[tag] = st_.params
        assert float(jnp.max(jnp.abs(outs["tree"] - outs["pallas"]))) <= 2e-6

    @pytest.mark.parametrize("case", ["plain", "carry", "minibatch",
                                      "scaffold"])
    def test_round_parity_f64_aa(self, x64, case):
        """The acceptance contract: fused↔tree round parity ≤ 1e-6 for the
        AA algorithms, incl. L>8 and carry-history — in f64, where float
        reordering noise (1e-16 at trajectory level, measured) stays below
        the Gram solve's amplification instead of being blown past 1e-6 as
        in f32 (see the x64 fixture). Observed on this container: 0.0 —
        bit-identical rounds — for all four cases."""
        X, y = make_binary_classification("synthetic_small", n=2000, seed=0)
        clients = partition(X, y, num_clients=8, scheme="iid")
        prob = make_logreg_problem(clients, gamma=1e-3, dtype=jnp.float64)
        algo = "fedosaa_scaffold" if case == "scaffold" else "fedosaa_svrg"
        hp = AlgoHParams(
            eta=1.0, local_epochs=10,   # L > 8: the m>8 AA granule path
            carry_history=3 if case == "carry" else 0,
            batch_size=32 if case == "minibatch" else None,
            local_impl="tree")
        outs = {}
        for impl in ("tree", "pallas"):
            h = dataclasses.replace(hp, local_impl=impl)
            rf = jax.jit(make_round_fn(algo, prob, h))
            st_ = init_state(prob, jax.random.PRNGKey(0), h, None, algo)
            for _ in range(4):
                st_, _m = rf(st_)
            outs[impl] = st_.params
        diff = float(jnp.max(jnp.abs(outs["tree"] - outs["pallas"])))
        assert diff <= 1e-6, f"{algo}/{case}: max|Δparams| {diff:.2e}"

    def test_round_through_interpret_kernel(self, logreg, monkeypatch):
        """Force the KERNEL executor (interpret mode) through a full round —
        the exact graph the TPU path compiles — and compare against the
        oracle executor the CPU path uses. fedsvrg: no AA step, so the
        comparison is not routed through the ulp-chaotic Gram solve."""
        import repro.kernels.local_update.ops as lu_ops

        hp = AlgoHParams(eta=1.0, local_epochs=4, local_impl="pallas")
        outs = {}
        for impl in ("ref", "kernel"):
            monkeypatch.setattr(lu_ops, "DEFAULT_IMPL", impl)
            rf = jax.jit(make_round_fn("fedsvrg", logreg, hp))
            st_ = init_state(logreg, jax.random.PRNGKey(0), hp, None,
                             "fedsvrg")
            st_, _m = rf(st_)
            outs[impl] = st_.params
        np.testing.assert_allclose(np.asarray(outs["kernel"]),
                                   np.asarray(outs["ref"]),
                                   rtol=1e-5, atol=1e-6)

    def test_linreg_fused_converges_to_exact_optimum(self):
        """The "linear" link end-to-end: FedOSAA-SVRG with the fused
        trajectory lands on the closed-form ridge optimum."""
        rng = np.random.default_rng(0)
        xs = [rng.standard_normal((120 + 30 * k, 12)) for k in range(4)]
        wtrue = rng.standard_normal(12)
        ys = [x @ wtrue + 0.05 * rng.standard_normal(x.shape[0]) for x in xs]
        clients = stack_client_arrays(xs, ys)
        prob = make_linreg_problem(clients, gamma=1e-2)
        wstar = linreg_exact_solution(clients, gamma=1e-2)
        hp = AlgoHParams(eta=0.3, local_epochs=8, local_impl="pallas")
        rf = jax.jit(make_round_fn("fedosaa_svrg", prob, hp))
        st_ = init_state(prob, jax.random.PRNGKey(0), hp, None,
                         "fedosaa_svrg")
        for _ in range(12):
            st_, _m = rf(st_)
        rel = float(tm.tree_norm(tm.tree_sub(st_.params, wstar))
                    / jnp.maximum(tm.tree_norm(wstar), 1e-30))
        assert rel < 1e-3, f"linreg fused rel-error {rel:.2e}"


# ---------------------------------------------------------------------------
# knob resolution / fallback
# ---------------------------------------------------------------------------

class TestLocalImplResolution:
    def test_sharded_always_tree(self):
        assert resolve_local_impl("pallas", "sharded") == "tree"
        assert resolve_local_impl("auto", "sharded") == "tree"

    def test_ineligible_falls_back(self, logreg):
        no_design = dataclasses.replace(logreg, linear_design=None)
        assert resolve_local_impl("pallas", "vmap", no_design) == "tree"
        # the Newton family has no trajectory to fuse
        assert resolve_local_impl("pallas", "vmap", logreg, "giant") == "tree"
        assert resolve_local_impl("pallas", "vmap", logreg,
                                  "fedosaa_svrg") == "pallas"
        # params must BE a flat array, not merely contain one flat leaf —
        # a container-wrapped [d] falls back instead of crashing at trace
        wrapped = dataclasses.replace(
            logreg, init=lambda rng: {"w": logreg.init(rng)})
        assert resolve_local_impl("pallas", "vmap", wrapped,
                                  "fedosaa_svrg") == "tree"

    def test_unknown_impl_raises(self):
        with pytest.raises(ValueError, match="unknown local_impl"):
            resolve_local_impl("cuda")

    def test_sharded_round_runs_with_pallas_requested(self, logreg):
        """An explicit local_impl="pallas" on the sharded runtime silently
        falls back to the autodiff path and matches the vmap tree round."""
        hp = AlgoHParams(eta=1.0, local_epochs=3, local_impl="pallas")
        mesh = make_host_mesh()
        rf_sh = jax.jit(make_sharded_round_fn("fedosaa_svrg", logreg, hp,
                                              mesh))
        rf_vm = jax.jit(make_round_fn(
            "fedosaa_svrg", logreg,
            dataclasses.replace(hp, local_impl="tree")))
        st0 = init_state(logreg, jax.random.PRNGKey(0), hp, None,
                         "fedosaa_svrg")
        st_sh, m_sh = rf_sh(st0)
        st_vm, m_vm = rf_vm(st0)
        np.testing.assert_allclose(np.asarray(st_sh.params),
                                   np.asarray(st_vm.params),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# stack_client_arrays aggregation weights (satellite regression)
# ---------------------------------------------------------------------------

class TestStackedWeights:
    def test_ragged_k100_weights_sum_to_one_ulp(self):
        """Weights normalized in f64 before the f32 cast: the f64 sum of
        the stored f32 weights stays within 1 ulp of 1.0 even for a ragged
        K=100 split (per-element drift would otherwise bias every
        delta-form aggregation by O(K·eps))."""
        rng = np.random.default_rng(0)
        sizes = rng.integers(3, 997, size=100)
        xs = [rng.standard_normal((int(s), 7)) for s in sizes]
        ys = [rng.choice([-1.0, 1.0], int(s)) for s in sizes]
        clients = stack_client_arrays(xs, ys)
        w = np.asarray(clients.weight)
        assert w.dtype == np.float32
        total = float(np.sum(w.astype(np.float64)))
        assert abs(total - 1.0) <= float(np.spacing(np.float32(1.0))), total
        # weights stay proportional to client sizes
        np.testing.assert_allclose(w, sizes / sizes.sum(), rtol=1e-6)

    def test_masks_match_sizes(self):
        xs = [np.ones((3, 2)), np.ones((5, 2))]
        ys = [np.ones(3), np.ones(5)]
        clients = stack_client_arrays(xs, ys)
        assert np.asarray(clients.mask).sum() == 8
        assert clients.x.shape == (2, 5, 2)
