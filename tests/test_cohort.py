"""Cohort-resident client state (core/client_store.py + the cohort plan in
core/algorithms.py).

Four contracts, matching the design's acceptance criteria:

  1. C = K with an explicit cohort_size is BIT-IDENTICAL to the dense path
     on both runtimes — the cohort machinery is a pure reorganization of the
     same arithmetic (TestIdentityCohortParity).
  2. A sampled cohort C < K computes exactly what the dense round would with
     the cohort's renormalized weights masked onto the full client axis
     (rtol 1e-6 in f64 — TestSampledCohortVsMaskedDense).
  3. Non-sampled clients are bit-frozen: their comm buffers (EF residuals,
     diff-coding references) and control variates keep their exact bits
     across rounds they sit out (TestFrozenClientState — the regression for
     the historical wart where inactive clients still advanced their
     buffers).
  4. The compiled cohort round touches O(C·d), not O(K·d): no equation in
     the jaxpr of a K=4096 / C=16 round — or of the engine's donated scan
     chunk — produces a float tensor with leading dimension K
     (TestNoDenseComputeInCohortRound).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import make_channel
from repro.core import (
    AlgoHParams,
    ClientStateStore,
    init_state,
    make_chunk_runner,
    make_round_fn,
    make_sharded_round_fn,
    run_rounds,
)
from repro.core.algorithms import (
    CrossClientReduce,
    _sample_cohort,
    _scaffold_round_core,
    _svrg_round_core,
)
from repro.core.anderson import AAConfig
from repro.core.client_store import gather_rows, scatter_rows
from repro.data import make_binary_classification, partition
from repro.launch.mesh import make_host_mesh
from repro.models.logreg import make_logreg_problem


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=800, seed=0)
    clients = partition(X, y, num_clients=8, scheme="imbalance")
    prob = make_logreg_problem(clients, gamma=1e-3)
    return prob, make_host_mesh()


@pytest.fixture
def x64():
    was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", was)


def leaves_bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if np.issubdtype(x.dtype, np.floating):
            np.testing.assert_array_equal(x, y)  # NaN-tolerant via ==-bits?
        else:
            np.testing.assert_array_equal(x, y)


def assert_state_bitwise(sa, sb, what=""):
    for field in sa._fields:
        a, b = getattr(sa, field), getattr(sb, field)
        assert (a is None) == (b is None), f"{what} {field}"
        if a is None:
            continue
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            x, y = np.asarray(x), np.asarray(y)
            if np.issubdtype(x.dtype, np.floating):
                assert np.array_equal(x, y, equal_nan=True), f"{what} {field}"
            else:
                assert np.array_equal(x, y), f"{what} {field}"


class TestClientStateStore:
    def _tree(self, K=6):
        k = jax.random.PRNGKey(0)
        return {
            "a": jax.random.normal(k, (K, 5)),
            "b": {"c": jax.random.normal(jax.random.fold_in(k, 1), (K, 2, 3))},
        }

    def test_gather_scatter_roundtrip(self):
        tree = self._tree()
        idx = jnp.asarray([4, 1, 3])
        rows = gather_rows(tree, idx)
        assert jax.tree.leaves(rows)[0].shape[0] == 3
        back = scatter_rows(tree, idx, rows)
        leaves_bitwise_equal(tree, back)

    def test_scatter_freezes_other_rows(self):
        tree = self._tree()
        idx = jnp.asarray([0, 5])
        rows = jax.tree.map(lambda r: r + 100.0, gather_rows(tree, idx))
        out = scatter_rows(tree, idx, rows)
        for orig, new in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(orig)[1:5],
                                          np.asarray(new)[1:5])
            np.testing.assert_array_equal(np.asarray(new)[np.asarray(idx)],
                                          np.asarray(orig)[np.asarray(idx)] + 100.0)

    def test_none_fields_pass_through(self):
        store = ClientStateStore(c_k=self._tree(), comm=None)
        idx = jnp.asarray([2, 0])
        cohort = store.gather(idx)
        assert cohort.comm is None and cohort.hist_s is None
        # a field None in the UPDATE is returned as the same object — no
        # scatter op for state the round never advanced
        out = store.scatter(idx, ClientStateStore(c_k=None, comm=None))
        assert out.c_k is store.c_k

    def test_num_clients(self):
        store = ClientStateStore(c_k=self._tree(K=7))
        assert store.num_clients == 7
        with pytest.raises(ValueError):
            _ = ClientStateStore().num_clients


class TestIdentityCohortParity:
    """cohort_size == K runs the full plan/commit machinery yet stays
    bit-identical to the dense path — state AND metrics, both runtimes,
    including carried AA history and int8 comm state."""

    CONFIGS = [
        ("fedosaa_svrg", None, {}),
        ("fedosaa_scaffold", "int8", {}),
        ("fedosaa_svrg", "int8", {"carry_history": 2}),
    ]

    @pytest.mark.parametrize("algo,chan,extra", CONFIGS)
    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_c_equals_k_bitwise(self, setup, algo, chan, extra, runtime):
        prob, mesh = setup
        K = prob.clients.num_clients
        hp = AlgoHParams(eta=0.5, local_epochs=3, **extra)
        hpk = dataclasses.replace(hp, cohort_size=K)
        if runtime == "vmap":
            fd = jax.jit(make_round_fn(algo, prob, hp, chan))
            fk = jax.jit(make_round_fn(algo, prob, hpk, chan))
        else:
            fd = jax.jit(make_sharded_round_fn(algo, prob, hp, mesh, channel=chan))
            fk = jax.jit(make_sharded_round_fn(algo, prob, hpk, mesh, channel=chan))
        sd = init_state(prob, jax.random.PRNGKey(0), hp, chan, algo)
        sk = init_state(prob, jax.random.PRNGKey(0), hpk, chan, algo)
        for t in range(3):
            sd, md = fd(sd)
            sk, mk = fk(sk)
            assert_state_bitwise(sd, sk, what=f"{algo} round {t}")
            for f, a, b in zip(md._fields, md, mk):
                a, b = np.asarray(a), np.asarray(b)
                assert np.array_equal(a, b, equal_nan=True), f"{algo} {f}"


class TestSampledCohortVsMaskedDense:
    """A C < K cohort round == the dense round core fed the cohort's
    renormalized weights masked onto the full client axis (zero weight for
    non-sampled clients), on the same drawn client set, at rtol 1e-6.

    Runs in f64: the ill-conditioned AA Gram solve amplifies the fusion-level
    ulp differences between the gathered [C,...] and the masked [K,...]
    graphs far past 1e-6 in f32."""

    C = 4

    def _setup64(self):
        X, y = make_binary_classification("synthetic_small", n=800, seed=0)
        clients = partition(X, y, num_clients=8, scheme="imbalance")
        return make_logreg_problem(clients, gamma=1e-3, dtype=jnp.float64)

    def _hp(self, **extra):
        return AlgoHParams(eta=0.5, local_epochs=3, aa_impl="tree",
                           local_impl="tree", aa=AAConfig(tikhonov=1e-8),
                           cohort_size=self.C, **extra)

    def _replay_prologue(self, prob, state):
        """The exact draw the cohort round makes, plus its masked-dense
        image: zero weights off-cohort, the renormalized weights at idx."""
        _, part_rng, cl_rng = jax.random.split(state.rng, 3)
        rngs_K = jax.random.split(cl_rng, prob.clients.num_clients)
        idx, cw = _sample_cohort(prob.clients.weight, self.C, part_rng)
        wm = jnp.zeros(prob.clients.num_clients,
                       cw.dtype).at[idx].set(cw)
        return np.asarray(idx), wm, rngs_K

    def test_svrg_matches_masked_dense(self, x64):
        prob = self._setup64()
        hp = self._hp()
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        new_state, m = jax.jit(make_round_fn("fedosaa_svrg", prob, hp))(state)

        idx, wm, rngs_K = self._replay_prologue(prob, state)
        R = CrossClientReduce(make_channel(None))
        Cl = prob.clients
        ref_params, ref_parts, _, _, _ = _svrg_round_core(
            prob, hp, True, R, state.params, Cl.x, Cl.y, Cl.mask,
            wm, wm, rngs_K)
        np.testing.assert_allclose(np.asarray(new_state.params),
                                   np.asarray(ref_params), rtol=1e-6)
        np.testing.assert_allclose(float(m.loss), float(ref_parts.loss),
                                   rtol=1e-6)

    def test_scaffold_matches_masked_dense(self, x64):
        prob = self._setup64()
        hp = self._hp()
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_scaffold")
        rf = jax.jit(make_round_fn("fedosaa_scaffold", prob, hp))
        new_state, m = rf(state)

        idx, wm, rngs_K = self._replay_prologue(prob, state)
        R = CrossClientReduce(make_channel(None))
        Cl = prob.clients
        ref_params, ref_c, ref_c_k, ref_parts, _ = _scaffold_round_core(
            prob, hp, True, R, state.params, state.c, Cl.x, Cl.y, Cl.mask,
            state.c_k, wm, wm, rngs_K)
        np.testing.assert_allclose(np.asarray(new_state.params),
                                   np.asarray(ref_params), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new_state.c),
                                   np.asarray(ref_c), rtol=1e-6, atol=1e-12)
        # the cohort's control-variate rows match the dense update at idx;
        # rows OFF the cohort differ by design (frozen vs wart-advanced)
        np.testing.assert_allclose(np.asarray(new_state.c_k)[idx],
                                   np.asarray(ref_c_k)[idx], rtol=1e-6,
                                   atol=1e-12)
        np.testing.assert_allclose(float(m.loss), float(ref_parts.loss),
                                   rtol=1e-6)


class TestFrozenClientState:
    """Non-sampled clients keep their state bit-frozen across rounds — the
    regression test for the historical partial-participation wart where
    every client advanced its EF/diff-coding comm buffers with zero weight."""

    def _run(self, algo, setup, rounds=2):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, participation=0.5)
        rf = jax.jit(make_round_fn(algo, prob, hp, "int8"))
        states = [init_state(prob, jax.random.PRNGKey(0), hp, "int8", algo)]
        cohorts = []
        for _ in range(rounds):
            _, part_rng, _ = jax.random.split(states[-1].rng, 3)
            idx, _ = _sample_cohort(prob.clients.weight, 4, part_rng)
            cohorts.append(np.asarray(idx))
            s, _ = rf(states[-1])
            states.append(s)
        return states, cohorts

    @staticmethod
    def _rows(tree, rows):
        return [np.asarray(l)[rows] for l in jax.tree.leaves(tree)]

    def test_comm_rows_frozen(self, setup):
        states, cohorts = self._run("fedosaa_svrg", setup)
        K = 8
        sampled_any = np.union1d(cohorts[0], cohorts[1])
        never = np.setdiff1d(np.arange(K), sampled_any)
        only_r1 = np.setdiff1d(cohorts[0], cohorts[1])
        assert len(never) > 0 or len(only_r1) > 0  # K=8, C=4: essentially sure
        # rows never sampled: still exactly the init bits after 2 rounds
        for a, b in zip(self._rows(states[0].comm, never),
                        self._rows(states[2].comm, never)):
            np.testing.assert_array_equal(a, b)
        # rows sampled only in round 1: untouched by round 2
        for a, b in zip(self._rows(states[1].comm, only_r1),
                        self._rows(states[2].comm, only_r1)):
            np.testing.assert_array_equal(a, b)
        # sanity: round 1's cohort rows DID advance from init
        moved = any(
            not np.array_equal(a, b)
            for a, b in zip(self._rows(states[0].comm, cohorts[0]),
                            self._rows(states[1].comm, cohorts[0]))
        )
        assert moved

    def test_control_variate_rows_frozen(self, setup):
        states, cohorts = self._run("fedosaa_scaffold", setup)
        never = np.setdiff1d(np.arange(8), np.union1d(cohorts[0], cohorts[1]))
        only_r1 = np.setdiff1d(cohorts[0], cohorts[1])
        for a, b in zip(self._rows(states[0].c_k, never),
                        self._rows(states[2].c_k, never)):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(self._rows(states[1].c_k, only_r1),
                        self._rows(states[2].c_k, only_r1)):
            np.testing.assert_array_equal(a, b)
        moved = any(
            not np.array_equal(a, b)
            for a, b in zip(self._rows(states[0].c_k, cohorts[0]),
                            self._rows(states[1].c_k, cohorts[0]))
        )
        assert moved


# ---------------------------------------------------------------------------
# O(C·d) compute: jaxpr shape assertion + a real K=4096 engine run
# ---------------------------------------------------------------------------

def _iter_subjaxprs(params):
    """Sub-jaxprs referenced by an equation's params (scan/pjit/cond/...)."""
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for w in vs:
            if hasattr(w, "jaxpr") and hasattr(w.jaxpr, "eqns"):  # ClosedJaxpr
                yield w.jaxpr
            elif hasattr(w, "eqns"):  # Jaxpr
                yield w


def _dense_float_eqns(jaxpr, K, found):
    """Collect leaf equations producing a float tensor with ndim >= 2 and
    leading dim K. Container equations (those carrying sub-jaxprs — scan,
    pjit, cond) are not themselves flagged: a [K, ...] scan carry that merely
    passes state through is not compute; their bodies are recursed into."""
    for eqn in jaxpr.eqns:
        subs = list(_iter_subjaxprs(eqn.params))
        if subs:
            for s in subs:
                _dense_float_eqns(s, K, found)
            continue
        for v in eqn.outvars:
            aval = v.aval
            shape = getattr(aval, "shape", ())
            if (len(shape) >= 2 and shape[0] == K
                    and jnp.issubdtype(aval.dtype, jnp.floating)):
                found.append((eqn.primitive.name, shape))


class TestNoDenseComputeInCohortRound:
    """K=4096, C=16: the compiled round body must not materialize any
    [K, d] float tensor — the acceptance criterion that the cohort refactor
    actually changed the compute scaling, not just the API."""

    K, C = 4096, 16

    def _problem(self):
        # 8 samples per client: enough for the client-local SVRG full-batch
        # gradient to be informative (2/client diverges at this cohort ratio)
        X, y = make_binary_classification("synthetic_small", n=32768, seed=0)
        clients = partition(X, y, num_clients=self.K, scheme="iid")
        return make_logreg_problem(clients, gamma=1e-3)

    def _hp(self):
        return AlgoHParams(eta=0.5, local_epochs=2, cohort_size=self.C)

    def test_round_jaxpr_has_no_dense_float_eqn(self):
        prob = self._problem()
        hp = self._hp()
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        jaxpr = jax.make_jaxpr(rf)(state)
        found = []
        _dense_float_eqns(jaxpr.jaxpr, self.K, found)
        assert not found, f"dense [K, ...] float equations in round: {found}"

    def test_engine_chunk_jaxpr_has_no_dense_float_eqn(self):
        """The donated scan chunk keeps the frozen store rows out of the
        graph too (tree_where passes untouched fields by object identity)."""
        prob = self._problem()
        hp = self._hp()
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        runner = make_chunk_runner(rf, 2, donate=False)
        jaxpr = jax.make_jaxpr(runner)(state, jnp.int32(2))
        found = []
        _dense_float_eqns(jaxpr.jaxpr, self.K, found)
        assert not found, f"dense [K, ...] float equations in chunk: {found}"

    def test_k4096_engine_run_converges(self):
        """The acceptance run: K=4096, C=16 FedOSAA-SVRG through the sharded
        runtime's engine path on the host mesh. Judged on the GLOBAL
        (all-K, data-weighted) loss — the per-round trace loss is the
        cohort-weighted loss of that round's 16-client draw and too noisy to
        order."""
        from repro.core.algorithms import _stack_losses

        prob = self._problem()
        hp = self._hp()
        mesh = make_host_mesh()
        rf = make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")

        def global_loss(w):
            Cl = prob.clients
            l = _stack_losses(prob, w, Cl.x, Cl.y, Cl.mask)
            return float(jnp.sum(Cl.weight * l))

        l0 = global_loss(state.params)
        state, trace = run_rounds(rf, state, 8, chunk=4)
        assert trace.num_rounds == 8
        assert np.all(np.isfinite(trace.loss))
        assert global_loss(state.params) < 0.7 * l0
