"""Robustness subsystem (repro/robust): fault injection + the clip defense.

Contracts, matching the subsystem's acceptance criteria:

  1. An inactive FaultPlan (or faults=None) is BYTE-IDENTICAL to the
     fault-free round — the fault machinery is python-gated out of the
     compiled graph (TestInactivePlan).
  2. Fault realization is deterministic and keyed by (seed, round, GLOBAL
     client id) — never by cohort position or shard layout — so injected
     rounds are bit-identical across repeated runs and across runtimes
     (TestRealize, TestDeterminism).
  3. Mid-round dropout: the dropped client computed but its uplink never
     landed — aggregation weights renormalize over the survivors and every
     per-client state row of a dropped client keeps its exact bits
     (TestDropout — distinct from never-sampled cohort rows, which
     tests/test_cohort.py pins).
  4. Every fault kind produces the same faulted round on the vmap and
     sharded runtimes at the runtimes' documented rtol 1e-6, per-round from
     a shared state (TestRuntimeEquivalence — the roundwise mold of
     tests/test_sharded_runtime.py; across many rounds the runtimes drift
     for fault-free reasons, see core/sharded.py).
  5. The clip_rtol screen survives the history-poison attack the undefended
     step dies on, and its activity reaches the telemetry sinks and alarms
     (TestDefenseEndToEnd).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import make_channel
from repro.core import AlgoHParams, init_state, make_round_fn, run_federated
from repro.core.anderson import AAConfig
from repro.core.sharded import make_sharded_round_fn
from repro.data import make_binary_classification, partition
from repro.launch.mesh import make_host_mesh
from repro.models.logreg import make_logreg_problem
from repro.robust import (
    FAULT_ANCHOR_KEY,
    FaultPlan,
    init_fault_comm,
    realize,
)


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=800, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    return prob, make_host_mesh()


@pytest.fixture
def setup64():
    """f64 problem for the cross-runtime sweep: byzantine perturbations
    amplify the shard-boundary ulp past f32's rtol-1e-6 headroom; in f64
    the same graphs agree with orders of magnitude to spare."""
    was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        X, y = make_binary_classification("synthetic_small", n=800, seed=0)
        clients = partition(X, y, num_clients=8, scheme="iid")
        prob = make_logreg_problem(clients, gamma=1e-3, dtype=jnp.float64)
        yield prob, make_host_mesh()
    finally:
        jax.config.update("jax_enable_x64", was)


def _init(prob, hp, algo="fedosaa_svrg", channel=None, faults=None):
    state = init_state(prob, jax.random.PRNGKey(0), hp, make_channel(channel),
                       algo)
    if faults is not None and faults.active and faults.stale_rate > 0.0:
        state = state._replace(comm=init_fault_comm(
            state.comm, state.params, prob.clients.num_clients))
    return state


def assert_state_allclose(sa, sb, rtol=1e-6, atol=1e-7, what=""):
    for field in sa._fields:
        a, b = getattr(sa, field), getattr(sb, field)
        assert (a is None) == (b is None), f"{what} {field}"
        if a is None or field == "rng":
            continue
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(
                np.asarray(x, np.float64), np.asarray(y, np.float64),
                rtol=rtol, atol=atol, err_msg=f"{what} {field}")


def assert_state_bitwise(sa, sb, what=""):
    for field in sa._fields:
        a, b = getattr(sa, field), getattr(sb, field)
        assert (a is None) == (b is None), f"{what} {field}"
        if a is None:
            continue
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                f"{what} {field}"


#: one plan per fault kind — the matrix the multi-kind tests sweep. The
#: history scale sits well past the clip_rtol=1e-3 screen's keep threshold
#: (so both runtimes make the same drop decision) but well below the f32
#: Gram-overflow scale (~2e19), keeping the faulted round finite.
FAULT_KINDS = [
    ("drop", FaultPlan(seed=11, drop_rate=0.4)),
    ("stale", FaultPlan(seed=11, stale_rate=0.4)),
    ("byz_sign_flip", FaultPlan(byz_clients=2, byz_mode="sign_flip",
                                byz_scale=3.0)),
    ("byz_noise", FaultPlan(byz_clients=2, byz_mode="noise", byz_scale=3.0)),
    ("byz_history", FaultPlan(byz_clients=2, byz_mode="history",
                              byz_scale=1e6)),
    ("dp", FaultPlan(dp_sigma=1e-3)),
]


class TestFaultPlan:
    def test_validation(self):
        with pytest.raises(ValueError):
            FaultPlan(drop_rate=1.5)
        with pytest.raises(ValueError):
            FaultPlan(stale_rate=-0.1)
        with pytest.raises(ValueError):
            FaultPlan(byz_clients=-1)
        with pytest.raises(ValueError):
            FaultPlan(byz_clients=1, byz_mode="nonsense")
        with pytest.raises(ValueError):
            FaultPlan(dp_sigma=-1.0)

    def test_active_property(self):
        assert not FaultPlan().active
        assert FaultPlan(drop_rate=0.1).active
        assert FaultPlan(stale_rate=0.1).active
        assert FaultPlan(byz_clients=1).active
        assert FaultPlan(dp_sigma=0.1).active

    def test_byz_routing_properties(self):
        hist = FaultPlan(byz_clients=1, byz_mode="history")
        wire = FaultPlan(byz_clients=1, byz_mode="sign_flip")
        assert hist.poisons_history and not hist.perturbs_uplink
        assert wire.perturbs_uplink and not wire.poisons_history
        assert not FaultPlan().poisons_history
        assert not FaultPlan().perturbs_uplink


class TestRealize:
    PLAN = FaultPlan(seed=3, drop_rate=0.4, stale_rate=0.4, byz_clients=3)

    def test_deterministic(self):
        a = realize(self.PLAN, jnp.int32(5), 8)
        b = realize(self.PLAN, jnp.int32(5), 8)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_rounds_differ(self):
        a = realize(self.PLAN, jnp.int32(5), 64)
        b = realize(self.PLAN, jnp.int32(6), 64)
        assert not np.array_equal(np.asarray(a.drop), np.asarray(b.drop))

    def test_keyed_by_global_id_not_cohort_position(self):
        """Gathering the realization through a permuted cohort must permute
        the flags — a client's fate this round is its own, wherever it sits
        in the cohort (the property that makes runtimes agree)."""
        full = realize(self.PLAN, jnp.int32(2), 8)
        perm = jnp.array([5, 2, 7, 0], jnp.int32)
        part = realize(self.PLAN, jnp.int32(2), 8, idx=perm)
        rows = np.asarray(perm)
        for name in ("drop", "stale", "byz", "keys"):
            np.testing.assert_array_equal(
                np.asarray(getattr(full, name))[rows],
                np.asarray(getattr(part, name)), err_msg=name)

    def test_byz_set_is_fixed_not_resampled(self):
        """byz_clients marks the lowest ids every round — a byzantine client
        is byzantine for the whole run (persistent-attacker threat model)."""
        a = realize(self.PLAN, jnp.int32(1), 8)
        b = realize(self.PLAN, jnp.int32(9), 8)
        np.testing.assert_array_equal(np.asarray(a.byz), np.asarray(b.byz))
        np.testing.assert_array_equal(np.asarray(a.byz),
                                      np.arange(8) < self.PLAN.byz_clients)


class TestInactivePlan:
    """faults=None and an all-zero FaultPlan compile the same round."""

    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_inactive_plan_bit_identical(self, setup, runtime):
        prob, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        if runtime == "sharded":
            f0 = make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh)
            f1 = make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh,
                                       faults=FaultPlan())
        else:
            f0 = make_round_fn("fedosaa_svrg", prob, hp)
            f1 = make_round_fn("fedosaa_svrg", prob, hp, faults=FaultPlan())
        state = _init(prob, hp)
        s0, m0 = jax.jit(f0)(state)
        s1, m1 = jax.jit(f1)(state)
        assert_state_bitwise(s0, s1, what=runtime)
        np.testing.assert_array_equal(np.asarray(m0.loss), np.asarray(m1.loss))


class TestDropout:
    """Mid-round dropout: the uplink never lands, the client's rows freeze."""

    PLAN = FaultPlan(seed=1, drop_rate=0.5)

    def _run(self, setup, rounds=3):
        prob, _ = setup
        # carry_history makes hist_s/hist_y live so the freeze covers the
        # carried AA columns too; int8 gives the comm dict EF/ref buffers
        hp = AlgoHParams(eta=0.5, local_epochs=3, carry_history=2)
        rf = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, "int8",
                                   faults=self.PLAN))
        states = [_init(prob, hp, "fedosaa_svrg", "int8", self.PLAN)]
        drops = []
        for t in range(rounds):
            drops.append(np.asarray(realize(self.PLAN, jnp.int32(t), 8).drop))
            s, _ = rf(states[-1])
            states.append(s)
        return states, drops

    @staticmethod
    def _rows(tree, rows):
        return [np.asarray(l)[rows] for l in jax.tree.leaves(tree)]

    def test_dropped_rows_bit_frozen(self, setup):
        """A client that dropped in round t carries its pre-round bits
        through round t's output — comm buffers (EF residuals, diff refs)
        AND carried AA history. Distinct from the never-sampled cohort
        contract: these clients DID compute; only the landing was lost."""
        states, drops = self._run(setup)
        checked = 0
        for t, drop in enumerate(drops):
            rows = np.nonzero(drop)[0]
            if len(rows) == 0:
                continue
            checked += 1
            for field in ("comm", "hist_s", "hist_y", "c_k"):
                before = getattr(states[t], field)
                after = getattr(states[t + 1], field)
                assert (before is None) == (after is None)
                if before is None:
                    continue
                for a, b in zip(self._rows(before, rows),
                                self._rows(after, rows)):
                    np.testing.assert_array_equal(
                        a, b, err_msg=f"round {t} {field} rows {rows}")
        assert checked >= 2  # drop_rate=0.5 over 3 rounds of K=8

    def test_surviving_rows_advance(self, setup):
        states, drops = self._run(setup, rounds=1)
        rows = np.nonzero(~drops[0])[0]
        assert len(rows) > 0
        moved = any(
            not np.array_equal(a, b)
            for a, b in zip(self._rows(states[0].hist_y, rows),
                            self._rows(states[1].hist_y, rows)))
        assert moved

    def test_all_dropped_round_keeps_params(self, setup):
        """Every uplink lost => the survivor renormalization guard yields an
        empty aggregate and w^t stays put exactly (no NaN from 0/0)."""
        prob, _ = setup
        plan = FaultPlan(drop_rate=1.0)
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        state = _init(prob, hp, faults=plan)
        rf = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, faults=plan))
        s, m = rf(state)
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(s.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.isfinite(float(m.loss))


class TestStaleAnchor:
    PLAN = FaultPlan(seed=2, stale_rate=0.5)

    def test_anchor_attached_and_refreshed(self, setup):
        """Two rounds, so the refresh branches are distinguishable: after
        round 2, round-2-fresh clients carry round 2's STARTING params
        (s1.params — the model they trained from) while round-2-stale
        clients keep their aged w^0 copy (staleness compounds)."""
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        state = _init(prob, hp, faults=self.PLAN)
        assert FAULT_ANCHOR_KEY in state.comm
        rf = jax.jit(make_round_fn("fedosaa_svrg", prob, hp,
                                   faults=self.PLAN))
        s1, _ = rf(state)
        s2, _ = rf(s1)
        stale = np.asarray(realize(self.PLAN, jnp.int32(1), 8).stale)
        assert stale.any() and not stale.all()  # seed=2 draws a mixed round
        a1 = [np.asarray(l) for l in
              jax.tree.leaves(s1.comm[FAULT_ANCHOR_KEY])]
        a2 = [np.asarray(l) for l in
              jax.tree.leaves(s2.comm[FAULT_ANCHOR_KEY])]
        w1 = [np.asarray(l) for l in jax.tree.leaves(s1.params)]
        for old, new, w in zip(a1, a2, w1):
            np.testing.assert_array_equal(new[stale], old[stale])
            np.testing.assert_array_equal(
                new[~stale], np.broadcast_to(w, new.shape)[~stale])
            # the two branches actually differ (w^1 != w^0 = the aged copy)
            assert not np.array_equal(new[stale][0], new[~stale][0])

    def test_stale_round_differs_from_clean(self, setup):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        clean = run_federated(prob, "fedosaa_svrg", hp, 5, rng=0)
        stale = run_federated(prob, "fedosaa_svrg", hp, 5, rng=0,
                              faults=self.PLAN)
        # round 0 every anchor IS w^0 — the re-basing shift is zero and the
        # rounds coincide; from round 1 the aged anchors bite
        np.testing.assert_allclose(clean.loss[0], stale.loss[0], rtol=1e-6)
        assert abs(clean.loss[-1] - stale.loss[-1]) > 1e-9


class TestDeterminism:
    """Same FaultPlan => bit-identical injected runs, on both runtimes."""

    MIXED = FaultPlan(seed=7, drop_rate=0.3, stale_rate=0.3, byz_clients=1,
                      byz_mode="history", byz_scale=1e6, dp_sigma=1e-3)

    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_repeated_runs_bit_identical(self, setup, runtime):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3,
                         aa=AAConfig(clip_rtol=1e-3))
        runs = [run_federated(prob, "fedosaa_svrg", hp, 3, rng=0,
                              runtime=runtime, channel="int8",
                              faults=self.MIXED) for _ in range(2)]
        np.testing.assert_array_equal(runs[0].loss, runs[1].loss)
        for a, b in zip(jax.tree.leaves(runs[0].final_params),
                        jax.tree.leaves(runs[1].final_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_seed_moves_the_faults(self, setup):
        prob, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        a = run_federated(prob, "fedosaa_svrg", hp, 3, rng=0,
                          faults=FaultPlan(seed=0, drop_rate=0.4))
        b = run_federated(prob, "fedosaa_svrg", hp, 3, rng=0,
                          faults=FaultPlan(seed=1, drop_rate=0.4))
        assert not np.array_equal(a.loss, b.loss)


class TestRuntimeEquivalence:
    """Each fault kind: vmap and sharded produce the same faulted round at
    the runtimes' documented rtol 1e-6, per-round from a shared state."""

    @pytest.mark.parametrize("kind,plan", FAULT_KINDS)
    def test_roundwise(self, setup64, kind, plan):
        prob, mesh = setup64
        hp = AlgoHParams(eta=0.5, local_epochs=3,
                         aa=AAConfig(clip_rtol=1e-3))
        fv = jax.jit(make_round_fn("fedosaa_svrg", prob, hp, faults=plan))
        fs = jax.jit(make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh,
                                           faults=plan))
        state = _init(prob, hp, faults=plan)
        for t in range(3):
            sv, mv = fv(state)
            ss, ms = fs(state)
            assert_state_allclose(sv, ss, what=f"{kind} round {t}")
            np.testing.assert_allclose(
                float(mv.loss), float(ms.loss), rtol=1e-6,
                err_msg=f"{kind} round {t}")
            state = sv

    def test_scaffold_dropout_equivalence(self, setup64):
        """Dropout composes with the control-variate family too (the c_k
        freeze rides the same plumbing) — pin it cross-runtime."""
        prob, mesh = setup64
        plan = FaultPlan(seed=4, drop_rate=0.4)
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        fv = jax.jit(make_round_fn("scaffold", prob, hp, faults=plan))
        fs = jax.jit(make_sharded_round_fn("scaffold", prob, hp, mesh,
                                           faults=plan))
        state = _init(prob, hp, algo="scaffold", faults=plan)
        for t in range(2):
            sv, _ = fv(state)
            ss, _ = fs(state)
            assert_state_allclose(sv, ss, what=f"scaffold drop round {t}")
            state = sv


class TestDefenseEndToEnd:
    def test_clip_defends_history_poison(self, setup):
        """The acceptance pair at test scale: one byzantine history client
        past the f32 Gram-overflow scale drives the undefended run
        non-finite while the defended run keeps converging."""
        prob, _ = setup
        plan = FaultPlan(byz_clients=1, byz_mode="history", byz_scale=1e24)
        und = run_federated(prob, "fedosaa_svrg",
                            AlgoHParams(eta=0.5, local_epochs=5), 5, rng=0,
                            faults=plan)
        dfd = run_federated(
            prob, "fedosaa_svrg",
            AlgoHParams(eta=0.5, local_epochs=5,
                        aa=AAConfig(clip_rtol=1e-3)), 5, rng=0, faults=plan)
        assert not np.isfinite(und.loss[-1])
        assert np.isfinite(dfd.loss).all()
        assert dfd.loss[-1] < dfd.loss[0]

    def test_int8_codec_sanitizes_undefended_history_poison(self, setup):
        """Regression pin for the measured ext_robustness curiosity: the
        SAME byz-history plan that kills the undefended identity-codec run
        (test above) leaves the undefended int8 run finite — the quantizer's
        finite code range never reproduces the poisoned client's non-finite
        delta on the wire, so the aggregate stays finite. The acceptance
        pair is therefore pinned on the identity codec; if this test fails,
        the int8 encode path started forwarding non-finite values and the
        ext_robustness matrix needs re-measuring."""
        prob, _ = setup
        plan = FaultPlan(byz_clients=1, byz_mode="history", byz_scale=1e24)
        hp = AlgoHParams(eta=0.5, local_epochs=5)
        und_int8 = run_federated(prob, "fedosaa_svrg", hp, 5, rng=0,
                                 faults=plan, channel="int8")
        assert np.isfinite(und_int8.loss).all()

    def test_clipped_metric_reaches_sinks(self, setup):
        """aa_clipped_max flows AAStats -> RoundMetrics -> sink rows."""
        from repro.obs.sinks import MemorySink

        prob, _ = setup
        plan = FaultPlan(byz_clients=1, byz_mode="history", byz_scale=1e6)
        hp = AlgoHParams(eta=0.5, local_epochs=5,
                         aa=AAConfig(clip_rtol=1e-3))
        sink = MemorySink()
        run_federated(prob, "fedosaa_svrg", hp, 3, rng=0, faults=plan,
                      sinks=[sink])
        assert "aa_clipped_max" in sink.rows[0]
        assert max(r["aa_clipped_max"] for r in sink.rows) >= 1.0

    def test_clipping_alarm_fires(self, setup):
        from repro.obs.alarms import AlarmMonitor

        prob, _ = setup
        plan = FaultPlan(byz_clients=1, byz_mode="history", byz_scale=1e6)
        hp = AlgoHParams(eta=0.5, local_epochs=5,
                         aa=AAConfig(clip_rtol=1e-3))
        mon = AlarmMonitor()
        run_federated(prob, "fedosaa_svrg", hp, 3, rng=0, faults=plan,
                      sinks=[mon])
        assert any(e["rule"] == "aa_clipping_active" for e in mon.events)
