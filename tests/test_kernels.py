"""Per-kernel allclose tests vs the ref.py oracles (interpret mode on CPU),
with shape/dtype sweeps + hypothesis property tests (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the module runs
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import AlgoHParams
from repro.kernels.anderson.ops import aa_step_flat
from repro.kernels.anderson.ref import aa_step_ref, gram_ref, update_ref
from repro.kernels.anderson.anderson import gram_pallas, update_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant.ops import (
    dequantize_2d,
    int8_sr_roundtrip,
    quantize_2d,
)
from repro.kernels.quant.ref import dequantize_ref, quantize_ref
from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.ssd.ref import ssd_chunk_ref


# ---------------------------------------------------------------------------
# anderson
# ---------------------------------------------------------------------------

class TestAndersonKernel:
    @pytest.mark.parametrize("d", [512, 2048, 4096, 10_000])
    @pytest.mark.parametrize("m", [1, 3, 10])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_aa_step_matches_ref(self, d, m, dtype):
        rng = np.random.default_rng(d + m)
        w = jnp.asarray(rng.standard_normal(d), dtype)
        g = jnp.asarray(rng.standard_normal(d), dtype)
        s = jnp.asarray(rng.standard_normal((m, d)) * 0.1, dtype)
        y = jnp.asarray(rng.standard_normal((m, d)) * 0.1, dtype)
        out = aa_step_flat(w, g, s, y, eta=0.5)
        ref = aa_step_ref(w, g, s, y, 0.5)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol * 10,
        )

    @pytest.mark.parametrize("tile", [256, 512, 2048])
    def test_gram_tile_invariance(self, tile):
        rng = np.random.default_rng(0)
        m, d = 8, 4096
        y = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        g = jnp.asarray(rng.standard_normal(d), jnp.float32)
        gram, yg = gram_pallas(y, g, tile=tile, interpret=True)
        gram_r, yg_r = gram_ref(y, g)
        # f32 accumulation-order noise across tiles: absolute tolerance scaled
        # to the Gram magnitude (~d)
        np.testing.assert_allclose(np.asarray(gram), np.asarray(gram_r), rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(yg_r), rtol=1e-3, atol=1e-2)

    def test_update_kernel_matches_ref(self):
        rng = np.random.default_rng(1)
        m, d = 8, 2048
        w = jnp.asarray(rng.standard_normal(d), jnp.float32)
        g = jnp.asarray(rng.standard_normal(d), jnp.float32)
        s = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        gamma = jnp.asarray(rng.standard_normal(m), jnp.float32)
        out = update_pallas(w, g, s, y, gamma, 0.3, 0.9, tile=512, interpret=True)
        ref = update_ref(w, g, s, y, gamma, 0.3, 0.9)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(d=st.integers(100, 3000), m=st.integers(1, 12), seed=st.integers(0, 99))
    def test_property_aa_step_any_shape(self, d, m, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.standard_normal(d), jnp.float32)
        g = jnp.asarray(rng.standard_normal(d), jnp.float32)
        s = jnp.asarray(rng.standard_normal((m, d)) * 0.1, jnp.float32)
        y = jnp.asarray(rng.standard_normal((m, d)) * 0.1, jnp.float32)
        out = aa_step_flat(w, g, s, y, eta=0.5)
        ref = aa_step_ref(w, g, s, y, 0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=1e-4)

    def test_matches_pytree_multisecant(self):
        """Kernel path == core/anderson.multisecant_update on the flattened
        vector (integration with the FL core)."""
        from repro.core.anderson import AAConfig, multisecant_update
        rng = np.random.default_rng(3)
        m, d = 5, 1500
        w = jnp.asarray(rng.standard_normal(d), jnp.float32)
        g = jnp.asarray(rng.standard_normal(d), jnp.float32)
        s = jnp.asarray(rng.standard_normal((m, d)) * 0.1, jnp.float32)
        y = jnp.asarray(rng.standard_normal((m, d)) * 0.1, jnp.float32)
        out_kernel = aa_step_flat(w, g, s, y, eta=0.7, tikhonov=1e-10)
        out_core, _ = multisecant_update(w, g, s, y, 0.7, AAConfig(tikhonov=1e-10))
        np.testing.assert_allclose(
            np.asarray(out_kernel), np.asarray(out_core), rtol=2e-3, atol=2e-4
        )

    @pytest.mark.parametrize("m", [9, 10, 16, 21])
    def test_flat_passes_m_beyond_one_granule(self, m):
        """m > 8 histories (L>8 local epochs, carried cross-round columns):
        the wrappers pad m to the next 8-sublane granule and the padded
        columns must contribute nothing."""
        from repro.kernels.anderson.ops import flat_gram, flat_update
        from repro.kernels.anderson.ref import gram_ref, update_ref
        rng = np.random.default_rng(m)
        d = 1000
        y = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        g = jnp.asarray(rng.standard_normal(d), jnp.float32)
        gram, yg = flat_gram(y, g, interpret=True)
        gram_r, yg_r = gram_ref(y, g)
        assert gram.shape == (m, m) and yg.shape == (m,)
        np.testing.assert_allclose(np.asarray(gram), np.asarray(gram_r),
                                   rtol=1e-4, atol=1e-2)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(yg_r),
                                   rtol=1e-4, atol=1e-2)
        w = jnp.asarray(rng.standard_normal(d), jnp.float32)
        s = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        gamma = jnp.asarray(rng.standard_normal(m), jnp.float32)
        out = flat_update(w, g, s, y, gamma, 0.3, 0.9, interpret=True)
        ref = update_ref(w, g, s, y, gamma, 0.3, 0.9)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_dtype_ravel_helpers_roundtrip(self):
        """The dtype-preserving ravel helpers: grouped ravel → unravel is the
        identity, dtypes and shapes preserved, mixed-dtype trees split into
        per-dtype groups."""
        from repro.kernels.anderson.ops import (
            dtype_leaf_groups,
            ravel_group,
            ravel_stack_group,
            unravel_group_into,
        )
        rng = np.random.default_rng(0)
        tree = {
            "a": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal(7), jnp.bfloat16),
            "c": jnp.asarray(rng.standard_normal((2, 2)), jnp.float32),
        }
        leaves = jax.tree.leaves(tree)
        groups = dtype_leaf_groups(tree)
        assert len(groups) == 2
        assert sorted(i for _, idxs in groups for i in idxs) == [0, 1, 2]
        out = list(leaves)
        for _, idxs in groups:
            flat = ravel_group(leaves, idxs)
            assert flat.ndim == 1
            unravel_group_into(flat, leaves, idxs, out)
        for orig, rt in zip(leaves, out):
            assert orig.dtype == rt.dtype and orig.shape == rt.shape
            np.testing.assert_allclose(
                np.asarray(orig, np.float32), np.asarray(rt, np.float32))
        # stacked variant keeps the leading history axis
        stack = jax.tree.map(lambda x: jnp.stack([x, x + 1]), tree)
        sleaves = jax.tree.leaves(stack)
        for _, idxs in groups:
            flat = ravel_stack_group(sleaves, idxs)
            assert flat.shape[0] == 2


class TestAndersonRoundParity:
    """Round-level parity of aa_impl="pallas" vs "tree" (interpret mode on
    CPU): the fused kernels wired into the FULL round core — vmapped clients,
    comm channel, metrics — must reproduce the tree path. Both paths share
    the _solve_gram eigh solve; the only difference is the accumulation
    order of the one-pass tiled Gram/update, so parity is tight."""

    @pytest.fixture(scope="class")
    def prob(self):
        from repro.data import make_binary_classification, partition
        from repro.models.logreg import make_logreg_problem
        X, y = make_binary_classification("synthetic_small", n=200, seed=0)
        clients = partition(X, y, num_clients=4, scheme="iid")
        return make_logreg_problem(clients, gamma=1e-3)

    def _roundwise(self, prob, algo, hp, rounds=3, channel=None):
        import dataclasses
        from repro.core import init_state, make_round_fn
        ft = jax.jit(make_round_fn(
            algo, prob, dataclasses.replace(hp, aa_impl="tree"), channel))
        fp = jax.jit(make_round_fn(
            algo, prob, dataclasses.replace(hp, aa_impl="pallas"), channel))
        state = init_state(prob, jax.random.PRNGKey(0), hp, channel, algo)
        for t in range(rounds):
            st, mt = ft(state)
            sp, mp = fp(state)
            for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(sp)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=1e-5, atol=1e-6,
                    err_msg=f"{algo} round {t} state")
            np.testing.assert_allclose(float(mt.loss), float(mp.loss),
                                       rtol=1e-6)
            np.testing.assert_allclose(float(mt.theta_mean),
                                       float(mp.theta_mean), rtol=1e-4)
            state = st

    @pytest.mark.parametrize("algo", ["fedosaa_svrg", "fedosaa_scaffold"])
    def test_round_parity(self, prob, algo):
        self._roundwise(prob, algo,
                        AlgoHParams(eta=0.5, local_epochs=3))

    @pytest.mark.parametrize("algo", ["fedosaa_svrg", "fedosaa_scaffold"])
    def test_round_parity_l_gt_8(self, prob, algo):
        """L > 8 local epochs: the per-client history exceeds one 8-sublane
        granule, exercising the padded-m kernel path inside the round."""
        self._roundwise(prob, algo,
                        AlgoHParams(eta=0.5, local_epochs=10), rounds=2)

    def test_round_parity_carry_history(self, prob):
        """carry_history columns prepend to the per-round history (m = H+L),
        and the carried columns themselves must round-trip identically."""
        from repro.core.anderson import AAConfig
        hp = AlgoHParams(eta=0.5, local_epochs=3, carry_history=2,
                         aa=AAConfig(tikhonov=1e-6, damping=0.7))
        self._roundwise(prob, "fedosaa_svrg", hp, rounds=3)

    def test_round_parity_with_codec(self, prob):
        """The fused path composes with the wire channel (per-client int8
        encode/decode happens before the AA step's ravel)."""
        self._roundwise(prob, "fedosaa_svrg",
                        AlgoHParams(eta=0.5, local_epochs=3), rounds=2,
                        channel="int8")

    def test_sharded_runtime_falls_back_to_tree(self, prob):
        """aa_impl="pallas" under the sharded runtime: automatic fallback to
        the tree path, no error, numerics identical to an explicit "tree"."""
        import dataclasses
        from repro.core import init_state
        from repro.core.sharded import make_sharded_round_fn
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        hp = AlgoHParams(eta=0.5, local_epochs=3, aa_impl="pallas")
        fs = jax.jit(make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh))
        ftree = jax.jit(make_sharded_round_fn(
            "fedosaa_svrg", prob,
            dataclasses.replace(hp, aa_impl="tree"), mesh))
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        sa, ma = fs(state)
        sb, mb = ftree(state)
        for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.isfinite(float(ma.loss))

    def test_auto_resolution(self):
        from repro.core import resolve_aa_impl
        assert resolve_aa_impl("tree") == "tree"
        assert resolve_aa_impl("pallas") == "pallas"
        assert resolve_aa_impl("pallas", "sharded") == "tree"
        assert resolve_aa_impl("auto", "sharded") == "tree"
        expected = "pallas" if jax.default_backend() == "tpu" else "tree"
        assert resolve_aa_impl("auto") == expected
        with pytest.raises(ValueError, match="aa_impl"):
            resolve_aa_impl("fused")


# ---------------------------------------------------------------------------
# quant (int8-SR wire codec, repro/comm)
# ---------------------------------------------------------------------------

class TestQuantKernel:
    @pytest.mark.parametrize("nc,C", [(1, 256), (3, 256), (8, 128), (17, 512)])
    def test_quantize_pallas_matches_ref_bit_exact(self, nc, C):
        """Same uniforms in -> the Pallas kernel (interpret mode on CPU) and
        the jnp oracle must agree EXACTLY: the int8 codes and f32 scales are
        the wire format, so parity is integer equality, not allclose."""
        rng = np.random.default_rng(nc * 1000 + C)
        x = jnp.asarray(rng.standard_normal((nc, C)), jnp.float32)
        u = jnp.asarray(rng.uniform(0, 1, (nc, C)), jnp.float32)
        qp, sp = quantize_2d(x, u, use_pallas=True)
        qr, sr = quantize_ref(x, u)
        np.testing.assert_array_equal(np.asarray(qp), np.asarray(qr))
        np.testing.assert_array_equal(np.asarray(sp), np.asarray(sr))
        dp = dequantize_2d(qp, sp, use_pallas=True)
        dr = dequantize_ref(qr, sr)
        np.testing.assert_array_equal(np.asarray(dp), np.asarray(dr))

    def test_roundtrip_error_bounded_by_chunk_scale(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(1000) * 10.0, jnp.float32)
        out = int8_sr_roundtrip(x, jax.random.PRNGKey(1), chunk=256)
        x_np, err = np.asarray(x), np.abs(np.asarray(out) - np.asarray(x))
        for c0 in range(0, 1000, 256):
            scale = np.abs(x_np[c0:c0 + 256]).max() / 127.0
            assert err[c0:c0 + 256].max() <= scale + 1e-6

    def test_roundtrip_unbiased_over_many_draws(self):
        """Stochastic rounding is unbiased: the empirical mean over draws
        converges to x at the Monte-Carlo rate."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal(512), jnp.float32)
        draws = 500
        outs = jax.vmap(lambda k: int8_sr_roundtrip(x, k))(
            jax.random.split(jax.random.PRNGKey(0), draws))
        mean = np.asarray(jnp.mean(outs, axis=0))
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert np.max(np.abs(mean - np.asarray(x))) < 5 * scale / np.sqrt(draws)

    def test_zero_chunks_and_exact_codes(self):
        # all-zero chunks must decode to exactly zero (scale fallback = 1)
        z = jnp.zeros(300, jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(int8_sr_roundtrip(z, jax.random.PRNGKey(0))), 0.0)
        # a chunk whose values sit exactly on code points is lossless:
        # x = scale * {-127..127} with max 127 -> scale = 1
        x = jnp.asarray(np.arange(-127, 129, 2), jnp.float32)  # 128 values
        out = int8_sr_roundtrip(x, jax.random.PRNGKey(0), chunk=128)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, 2000), chunk=st.sampled_from([64, 128, 256]),
           seed=st.integers(0, 99))
    def test_property_any_shape_bounded(self, n, chunk, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        out = int8_sr_roundtrip(x, jax.random.PRNGKey(seed), chunk=chunk)
        assert out.shape == x.shape
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert float(jnp.max(jnp.abs(out - x))) <= scale + 1e-6


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _ref_model_layout(q, k, v, window):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    kk = jnp.repeat(k, H // KV, 2)
    vv = jnp.repeat(v, H // KV, 2)
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = attention_ref(to_bh(q), to_bh(kk), to_bh(vv), window=window)
    return ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


class TestFlashAttention:
    @pytest.mark.parametrize("S", [64, 128, 200, 384])
    @pytest.mark.parametrize("window", [0, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, S, window, dtype):
        rng = np.random.default_rng(S + window)
        B, H, KV, hd = 2, 4, 2, 64
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
        k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
        v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
        out = flash_attention(q, k, v, window=window)
        ref = _ref_model_layout(q, k, v, window)
        tol = 2e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol,
        )

    @pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
    def test_block_shape_invariance(self, bq, bk):
        rng = np.random.default_rng(7)
        B, S, H, hd = 1, 256, 2, 64
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        out = flash_attention(q, k, v, block_q=bq, block_k=bk)
        ref = _ref_model_layout(q, k, v, 0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)

    @settings(max_examples=8, deadline=None)
    @given(
        S=st.integers(16, 300),
        hd=st.sampled_from([32, 64, 128]),
        window=st.sampled_from([0, 16, 100]),
        seed=st.integers(0, 99),
    )
    def test_property_matches_ref(self, S, hd, window, seed):
        rng = np.random.default_rng(seed)
        B, H = 1, 2
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        out = flash_attention(q, k, v, window=window)
        ref = _ref_model_layout(q, k, v, window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-3, atol=3e-3)

    def test_first_token_attends_itself_only(self):
        rng = np.random.default_rng(0)
        B, S, H, hd = 1, 128, 1, 64
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

class TestSSDKernel:
    @pytest.mark.parametrize("Q", [32, 64, 128, 256])
    @pytest.mark.parametrize("st_dim", [8, 64, 128])
    def test_matches_ref(self, Q, st_dim):
        rng = np.random.default_rng(Q + st_dim)
        B, nc, nh, hd = 1, 2, 2, 32
        xc = jnp.asarray(rng.standard_normal((B, nc, Q, nh, hd)), jnp.float32)
        dtc = jnp.asarray(rng.uniform(0.01, 0.3, (B, nc, Q, nh)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 4.0, (nh,)), jnp.float32)
        da = jnp.cumsum(dtc * A[None, None, None], axis=2)
        Bc = jnp.asarray(rng.standard_normal((B, nc, Q, st_dim)), jnp.float32)
        Cc = jnp.asarray(rng.standard_normal((B, nc, Q, st_dim)), jnp.float32)
        y, s = ssd_chunk(xc, dtc, da, Bc, Cc)
        yr, sr = ssd_chunk_ref(xc, dtc, da, Bc, Cc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4, atol=1e-4)

    def test_model_integration_ssd_fn(self):
        """build_model(ssd_fn=pallas kernel) == build_model(pure jnp) for the
        full mamba2 forward — the kernel is a drop-in replacement."""
        from repro.configs import get_arch
        from repro.models.decoder import build_model
        cfg = get_arch("mamba2-2.7b").reduced()
        m_ref = build_model(cfg)
        m_ker = build_model(cfg, ssd_fn=ssd_chunk)
        params = jax.jit(m_ref.init)(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                    cfg.vocab_size, jnp.int32)
        lr, _ = m_ref.forward(params, tokens, None)
        lk, _ = m_ker.forward(params, tokens, None)
        np.testing.assert_allclose(
            np.asarray(lk, np.float32), np.asarray(lr, np.float32),
            rtol=2e-3, atol=2e-3,
        )

    @settings(max_examples=8, deadline=None)
    @given(
        Q=st.sampled_from([16, 32, 64]),
        nh=st.integers(1, 4),
        seed=st.integers(0, 99),
    )
    def test_property_matches_ref(self, Q, nh, seed):
        rng = np.random.default_rng(seed)
        B, nc, hd, st_dim = 1, 1, 16, 16
        xc = jnp.asarray(rng.standard_normal((B, nc, Q, nh, hd)), jnp.float32)
        dtc = jnp.asarray(rng.uniform(0.01, 0.3, (B, nc, Q, nh)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.1, 2.0, (nh,)), jnp.float32)
        da = jnp.cumsum(dtc * A[None, None, None], axis=2)
        Bc = jnp.asarray(rng.standard_normal((B, nc, Q, st_dim)), jnp.float32)
        Cc = jnp.asarray(rng.standard_normal((B, nc, Q, st_dim)), jnp.float32)
        y, s = ssd_chunk(xc, dtc, da, Bc, Cc)
        yr, sr = ssd_chunk_ref(xc, dtc, da, Bc, Cc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=2e-4, atol=2e-4)
