"""Unit + property tests for the Anderson-acceleration core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import assume, given, settings, strategies as st
except ImportError:  # property tests skip; the rest of the module runs
    from _hypothesis_stub import assume, given, settings, strategies as st

from repro.core.anderson import (
    AAConfig,
    aa_mixing_step,
    lbfgs_two_loop,
    multisecant_update,
    trajectory_to_sy,
)
from repro.utils import tree_math as tm


def quad_setup(d=8, L=5, seed=0, kappa=50.0):
    """A quadratic f(w) = ½wᵀAw − bᵀw with controlled conditioning, plus a GD
    trajectory — ground truth for every closed-form AA identity."""
    rng = np.random.default_rng(seed)
    Q = np.linalg.qr(rng.standard_normal((d, d)))[0]
    evals = np.geomspace(1.0, kappa, d)
    A = (Q * evals) @ Q.T
    b = rng.standard_normal(d)
    eta = 0.9 / evals.max()
    grad = lambda w: A @ w - b
    w = rng.standard_normal(d)
    ws, rs = [w], [grad(w)]
    for _ in range(L):
        w = w - eta * grad(w)
        ws.append(w)
        rs.append(grad(w))
    w_traj = jnp.asarray(np.stack(ws), jnp.float32)
    r_traj = jnp.asarray(np.stack(rs), jnp.float32)
    return A, b, eta, w_traj, r_traj


def rand_traj_setup(d=8, L=5, seed=0, kappa=50.0, eta=0.05):
    """Random-walk trajectory on the same quadratic: w's are random steps and
    r = ∇f(w). S/Y are well-conditioned (unlike GD trajectories, whose Y
    columns align with the dominant eigenvector — that's a conditioning
    stress, not an algebra test)."""
    rng = np.random.default_rng(seed)
    Q = np.linalg.qr(rng.standard_normal((d, d)))[0]
    evals = np.geomspace(1.0, kappa, d)
    A = (Q * evals) @ Q.T
    b = rng.standard_normal(d)
    ws = np.cumsum(rng.standard_normal((L + 1, d)), axis=0) * 0.1
    rs = ws @ A.T - b
    return A, b, eta, jnp.asarray(ws, jnp.float32), jnp.asarray(rs, jnp.float32)


class TestMultisecant:
    def test_exact_newton_on_quadratic_full_history(self):
        """With L=d history columns on a quadratic, the multisecant H⁻¹ IS
        η-scaled GMRES over the full Krylov space => exact Newton solve."""
        d = 6
        A, b, eta, w_traj, r_traj = quad_setup(d=d, L=d, kappa=10.0)
        s, y = trajectory_to_sy(w_traj, r_traj)
        w0 = w_traj[0]
        g0 = r_traj[0]
        w_new, stats = multisecant_update(w0, g0, s, y, eta, AAConfig(tikhonov=0.0))
        w_newton = np.linalg.solve(A, b)
        # f32 Gram limits exactness; require ~Newton (≪ any GD iterate's error)
        err_aa = np.linalg.norm(np.asarray(w_new) - w_newton)
        err_gd = np.linalg.norm(np.asarray(w_traj[-1]) - w_newton)
        assert err_aa < 0.05 * np.linalg.norm(w_newton)
        assert err_aa < 0.2 * err_gd
        assert float(stats.theta) < 5e-2   # full Krylov space => gain ~ 0

    def test_inverse_multisecant_equation(self):
        """H⁻¹ must satisfy H⁻¹ Y = S exactly (paper Eq. 5 property).

        Uses well-conditioned random S, Y (the identity holds for ANY
        full-column-rank Y; GD trajectories make Y numerically rank-deficient
        which tests conditioning, not the identity)."""
        d, L = 10, 4
        rng = np.random.default_rng(0)
        S = rng.standard_normal((d, L))
        Y = rng.standard_normal((d, L))
        eta = 0.3
        Hinv = eta * np.eye(d) + (S - eta * Y) @ np.linalg.pinv(Y.T @ Y) @ Y.T
        np.testing.assert_allclose(Hinv @ Y, S, rtol=1e-8, atol=1e-10)

    def test_matches_dense_formula(self):
        """Pytree implementation == dense Eq. 7 formula."""
        d, L = 12, 5
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=d, L=L, seed=3)
        s, y = trajectory_to_sy(w_traj, r_traj)
        g = r_traj[0]
        w_new, _ = multisecant_update(
            w_traj[0], g, s, y, eta, AAConfig(tikhonov=0.0)
        )
        S = np.asarray(s, np.float64).T
        Y = np.asarray(y, np.float64).T
        Hinv = eta * np.eye(d) + (S - eta * Y) @ np.linalg.pinv(Y.T @ Y) @ Y.T
        expected = np.asarray(w_traj[0], np.float64) - Hinv @ np.asarray(g, np.float64)
        np.testing.assert_allclose(np.asarray(w_new), expected, rtol=1e-4, atol=1e-4)

    def test_pytree_structure_preserved(self):
        """AA over a dict-of-arrays pytree equals AA over the concatenated
        vector — the leaf-wise Gram reduction is exact."""
        d, L = 14, 4
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=d, L=L, seed=5)
        s, y = trajectory_to_sy(w_traj, r_traj)
        split = 5

        def as_tree(x):
            return {"a": x[..., :split], "b": {"c": x[..., split:]}}

        w_new_tree, st_tree = multisecant_update(
            as_tree(w_traj[0]), as_tree(r_traj[0]),
            as_tree(s), as_tree(y), eta,
        )
        w_new_flat, st_flat = multisecant_update(
            w_traj[0], r_traj[0], s, y, eta
        )
        recon = jnp.concatenate([w_new_tree["a"], w_new_tree["b"]["c"]], -1)
        np.testing.assert_allclose(np.asarray(recon), np.asarray(w_new_flat), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(float(st_tree.theta), float(st_flat.theta), rtol=1e-5)

    def test_damping_interpolates(self):
        """damping=0 reduces to the plain gradient step w − ηg."""
        d, L = 8, 3
        A, b, eta, w_traj, r_traj = quad_setup(d=d, L=L)
        s, y = trajectory_to_sy(w_traj, r_traj)
        g = r_traj[0]
        w_new, _ = multisecant_update(
            w_traj[0], g, s, y, eta, AAConfig(damping=0.0)
        )
        np.testing.assert_allclose(
            np.asarray(w_new), np.asarray(w_traj[0] - eta * g), rtol=1e-5, atol=1e-6
        )

    def test_gain_bounded_and_decreasing_in_history(self):
        """θ ∈ [0,1], and more history columns can only shrink the projected
        residual (Krylov nesting)."""
        d = 16
        A, b, eta, w_traj, r_traj = quad_setup(d=d, L=8, seed=7)
        thetas = []
        for L in (2, 4, 8):
            s, y = trajectory_to_sy(w_traj[: L + 1], r_traj[: L + 1])
            _, st = multisecant_update(w_traj[0], r_traj[0], s, y, eta)
            thetas.append(float(st.theta))
        assert all(0.0 <= t <= 1.0 for t in thetas)
        assert thetas[0] >= thetas[1] >= thetas[2] - 1e-6

    def test_filtering_drops_dependent_columns(self):
        d, L = 8, 4
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=d, L=L)
        s, y = trajectory_to_sy(w_traj, r_traj)
        # duplicate a Y column to force exact rank deficiency
        y = y.at[1].set(y[0])
        s = s.at[1].set(s[0])
        w_new, st = multisecant_update(
            w_traj[0], r_traj[0], s, y, eta, AAConfig(filter_rtol=1e-6)
        )
        assert int(st.used_columns) < L
        assert np.isfinite(np.asarray(w_new)).all()


class TestDegenerateGram:
    """_solve_gram's degenerate systems: Γ=0 and the plain damped-gradient
    step, bit-exactly, never NaN — on BOTH implementations."""

    @pytest.mark.parametrize("impl", ["tree", "pallas"])
    def test_rank0_identical_columns_degrades_to_gradient_step(self, impl):
        d, L = 8, 4
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=d, L=L)
        s, y = trajectory_to_sy(w_traj, r_traj)
        # a rank-0 Gram: every history column identical AND zero
        y = jnp.zeros_like(y)
        w_new, st = multisecant_update(
            w_traj[0], r_traj[0], s, y, eta, AAConfig(), impl=impl)
        expect = np.asarray(w_traj[0] - eta * r_traj[0])
        if impl == "tree":
            # Γ=0 makes the tree path's update expression literally
            # w − ηg − β·0: bit-exact
            np.testing.assert_array_equal(np.asarray(w_new), expect)
        else:
            # the fused kernel's arithmetic ordering differs from the plain
            # expression by an ulp even at Γ=0
            np.testing.assert_allclose(np.asarray(w_new), expect,
                                       rtol=1e-6, atol=1e-7)
        assert int(st.used_columns) == 0
        assert float(st.gram_cond) == 1.0
        assert np.isfinite(float(st.theta))

    @pytest.mark.parametrize("impl", ["tree", "pallas"])
    def test_all_clipped_degrades_to_gradient_step(self, impl):
        """clip_rtol screening every column (all non-finite) must fall to the
        same Γ=0 damped-gradient step, not NaN."""
        d, L = 8, 4
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=d, L=L)
        s, y = trajectory_to_sy(w_traj, r_traj)
        y = jnp.full_like(y, jnp.inf)
        w_new, st = multisecant_update(
            w_traj[0], r_traj[0], s, y, eta, AAConfig(clip_rtol=1e-3),
            impl=impl)
        expect = np.asarray(w_traj[0] - eta * r_traj[0])
        if impl == "tree":
            np.testing.assert_array_equal(np.asarray(w_new), expect)
        else:
            np.testing.assert_allclose(np.asarray(w_new), expect,
                                       rtol=1e-6, atol=1e-7)
        assert int(st.clipped_columns) == L
        assert int(st.used_columns) == 0


class TestClipScreen:
    """The clip_rtol byzantine-column screen (repro/robust defense)."""

    def _setup(self):
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=8, L=5)
        s, y = trajectory_to_sy(w_traj, r_traj)
        return s, y, w_traj[0], r_traj[0], eta

    @pytest.mark.parametrize("impl", ["tree", "pallas"])
    def test_clean_history_is_bit_identical(self, impl):
        """Acceptance: on a fault-free history, screen on == screen off,
        bit-exactly (the one-sided screen keeps every honest column)."""
        s, y, w0, g0, eta = self._setup()
        a, _ = multisecant_update(w0, g0, s, y, eta, AAConfig(), impl=impl)
        b_, st = multisecant_update(w0, g0, s, y, eta,
                                    AAConfig(clip_rtol=1e-3), impl=impl)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
        assert int(st.clipped_columns) == 0

    @pytest.mark.parametrize("impl", ["tree", "pallas"])
    @pytest.mark.parametrize("scale", [1e6, 1e24, np.inf])
    def test_poisoned_column_dropped_and_step_finite(self, impl, scale):
        """One byzantine column — huge or overflowed-to-inf — is screened and
        the defended step equals the step computed on the honest columns."""
        s, y, w0, g0, eta = self._setup()
        cfg = AAConfig(clip_rtol=1e-3)
        ypois = y.at[-1].set(y[-1] * scale)
        w_def, st = multisecant_update(w0, g0, s, ypois, eta, cfg, impl=impl)
        assert int(st.clipped_columns) == 1
        assert np.isfinite(np.asarray(w_def)).all()
        assert np.isfinite(float(st.theta))
        # reference: solve on the honest columns only (poisoned zeroed,
        # exactly what the masked system computes)
        yref = ypois.at[-1].set(0.0)
        sref = s.at[-1].set(0.0)
        w_ref, _ = multisecant_update(w0, g0, sref, yref, eta, cfg, impl=impl)
        np.testing.assert_allclose(np.asarray(w_def), np.asarray(w_ref),
                                   rtol=1e-6, atol=1e-6)

    def test_undefended_overflow_goes_nonfinite(self):
        """The control: without the screen the f32 Gram overflow poisons the
        step — documents WHY the defense exists (and keeps the acceptance
        benchmark's failure mode pinned)."""
        s, y, w0, g0, eta = self._setup()
        ypois = y.at[-1].set(y[-1] * 1e24)
        w_und, _ = multisecant_update(w0, g0, s, ypois, eta, AAConfig())
        assert not np.isfinite(np.asarray(w_und)).all()

    def test_tiny_columns_are_kept(self):
        """The screen is ONE-sided: late-trajectory columns with tiny
        residual norms are honest (convergence!) and must never be dropped —
        a two-sided screen would break clean-run parity."""
        s, y, w0, g0, eta = self._setup()
        ysmall = y.at[-1].set(y[-1] * 1e-8)
        _, st = multisecant_update(w0, g0, s, ysmall, eta,
                                   AAConfig(clip_rtol=1e-3))
        assert int(st.clipped_columns) == 0


class TestMixingEquivalence:
    def test_mixing_equals_multisecant(self):
        """Eq. 2–3 (mixing form) == Eq. 4–5 (multisecant form) on the same
        history — the paper's key algebraic identity."""
        d, L = 10, 5
        A, b, eta, w_traj, r_traj = rand_traj_setup(d=d, L=L, seed=11)
        # mixing form consumes newest-first histories of iterates/residuals
        w_hist = w_traj[::-1]
        # residual of the fixed-point map g(w)=w−ηgrad: r = −η grad
        r_hist = -eta * r_traj[::-1]
        w_mix, alpha = aa_mixing_step(w_hist, r_hist, AAConfig(tikhonov=0.0))
        s, y = trajectory_to_sy(w_traj, r_traj)
        w_ms, _ = multisecant_update(
            w_traj[-1], r_traj[-1], s, y, eta, AAConfig(tikhonov=0.0)
        )
        np.testing.assert_allclose(np.asarray(w_mix), np.asarray(w_ms), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(float(jnp.sum(alpha)), 1.0, rtol=1e-5)


class TestLBFGS:
    def test_two_loop_matches_dense_bfgs_single_pair(self):
        """With one (s,y) pair, two-loop == closed-form BFGS inverse update."""
        d = 7
        rng = np.random.default_rng(2)
        s = rng.standard_normal(d).astype(np.float32)
        y = (rng.standard_normal(d) + 2 * s).astype(np.float32)  # sᵀy > 0 likely
        if float(s @ y) <= 0:
            y = y + 3 * s
        g = rng.standard_normal(d).astype(np.float32)
        out = lbfgs_two_loop(
            jnp.asarray(g), jnp.asarray(s)[None], jnp.asarray(y)[None], eta=0.1
        )
        rho = 1.0 / (s @ y)
        gamma0 = (s @ y) / (y @ y)
        V = np.eye(d) - rho * np.outer(s, y)
        H = V @ (gamma0 * np.eye(d)) @ V.T + rho * np.outer(s, s)
        np.testing.assert_allclose(np.asarray(out), H @ g, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(3, 20),
    L=st.integers(1, 6),
    kappa=st.floats(1.5, 1e3),
    seed=st.integers(0, 10_000),
)
def test_property_gain_and_residual_contraction(d, L, kappa, seed):
    """Property (paper Lemma 3, quadratic case): after the AA step the
    corrected-gradient norm satisfies ‖∇f(w⁺)‖ ≤ √(1−ημ)·θ·‖∇f(w)‖ (+ small
    numerical slack), and θ ∈ [0, 1]."""
    L = min(L, d - 1) if d > 1 else 1
    A, b, eta, w_traj, r_traj = quad_setup(d=d, L=L, seed=seed, kappa=kappa)
    s, y = trajectory_to_sy(w_traj, r_traj)
    g0 = r_traj[0]
    w_new, st_ = multisecant_update(
        w_traj[0], g0, s, y, eta, AAConfig(tikhonov=1e-12)
    )
    # Paper Assumption 2: bounded conditioning of the history matrices. In
    # f32 beyond ~1e6 both theta and the update are numerically meaningless --
    # exactly the regime the theory excludes.
    assume(float(st_.gram_cond) < 1e6)
    theta = float(st_.theta)
    assert 0.0 <= theta <= 1.0 + 1e-6
    Anp = np.asarray(A, np.float64)
    g_new = Anp @ np.asarray(w_new, np.float64) - np.asarray(b, np.float64)
    evals = np.linalg.eigvalsh(Anp)
    mu = evals[0]
    bound = np.sqrt(max(1 - eta * mu, 0.0)) * theta * np.linalg.norm(np.asarray(g0))
    # float32 trajectories: allow generous relative slack + absolute floor
    assert np.linalg.norm(g_new) <= 1.25 * bound + 5e-3 * np.linalg.norm(np.asarray(g0)) + 1e-5
