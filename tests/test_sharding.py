"""Sharding-layer tests: spec generation totality + shard_map MoE equivalence
on a 1-device mesh (multi-device lowering is proven by the dry-run suite)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, get_arch, get_shape
from repro.launch.mesh import make_host_mesh
from repro.launch.specs_io import batch_specs_for, caches_shape, effective_cfg, params_shape
from repro.models import layers as Lyr
from repro.models.decoder import build_model
from repro.sharding.specs import cache_specs, make_plan, param_specs


class FakeMesh:
    """Shape-only mesh stand-in for spec generation (no devices)."""
    def __init__(self, multi_pod=False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_total(arch, multi_pod):
    """Every parameter leaf of every arch gets a sharding rule, and the spec
    rank matches the leaf rank."""
    mesh = FakeMesh(multi_pod)
    shape = get_shape("train_4k")
    cfg = effective_cfg(get_arch(arch), shape)
    plan = make_plan(cfg, mesh, multi_pod=multi_pod)
    model = build_model(plan.cfg)
    p_shape = params_shape(model)
    specs = param_specs(p_shape, plan)
    for (kp, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(p_shape)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0],
    ):
        assert len(spec) <= leaf.ndim, (kp, spec, leaf.shape)
        # sharded dims must divide
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, kp, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-2.7b", "zamba2-7b",
                                  "granite-moe-3b-a800m"])
def test_cache_specs_total(arch):
    mesh = FakeMesh()
    shape = get_shape("decode_32k")
    cfg = effective_cfg(get_arch(arch), shape)
    plan = make_plan(cfg, mesh)
    model = build_model(plan.cfg)
    c_shape = caches_shape(model, 128, 1024)
    specs = cache_specs(c_shape, plan, 128)
    assert jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    ) is not None


class TestShardMapMoE:
    def test_moe_sharded_equals_plain_on_host_mesh(self):
        """Expert-parallel shard_map MoE == plain capacity MoE on a (1,1)
        mesh (single 'model' rank => identical routing and arithmetic)."""
        cfg = get_arch("granite-moe-3b-a800m").reduced()
        mesh = make_host_mesh()
        sh = Lyr.Sharder(mesh=mesh, axes={"batch": "data", "experts": "model",
                                          "expert_ff": None})
        p = Lyr.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
        y_plain, aux_plain = Lyr.moe(p, x, cfg, Lyr.Sharder())
        y_shard, aux_shard = Lyr.moe_sharded(p, x, cfg, sh)
        np.testing.assert_allclose(
            np.asarray(y_shard), np.asarray(y_plain), rtol=2e-3, atol=2e-3
        )
        np.testing.assert_allclose(float(aux_shard), float(aux_plain), rtol=1e-3)

    def test_moe_sharded_dropless(self):
        cfg = get_arch("llama4-scout-17b-a16e").reduced()
        mesh = make_host_mesh()
        sh = Lyr.Sharder(mesh=mesh, axes={"batch": "data", "experts": "model"})
        p = Lyr.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
        y_shard, _ = Lyr.moe_sharded(p, x, cfg, sh, dropless=True)
        y_plain, _ = Lyr.moe(p, x, cfg, Lyr.Sharder(), dropless=True)
        np.testing.assert_allclose(
            np.asarray(y_shard), np.asarray(y_plain), rtol=2e-3, atol=2e-3
        )


def test_padded_expert_masking():
    """Dummy (padded) experts must never receive tokens."""
    import dataclasses
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg = dataclasses.replace(cfg, padded_experts=cfg.num_experts + 2)
    p = Lyr.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    y, aux = Lyr.moe(p, x, cfg, Lyr.Sharder())
    assert np.isfinite(np.asarray(y)).all()
    # routing probabilities: recompute and check dummies get ~0 mass
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    dummy = jnp.arange(cfg.eff_experts) >= cfg.num_experts
    probs = jax.nn.softmax(jnp.where(dummy[None], -1e30, logits), -1)
    assert float(probs[:, cfg.num_experts:].max()) < 1e-9
