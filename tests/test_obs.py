"""Round telemetry (repro/obs): streaming sinks, trace capture, alarms.

The load-bearing contracts pinned here:

  * BIT-NEUTRALITY — attaching sinks (or the AlarmMonitor) to a run leaves
    every computed row and the final params bit-identical to the sink-free
    run, in BOTH runtimes including cohort sampling and the int8 wire. Sinks
    consume host data the driver already fetched; they never touch the graph.
  * ONE HOST SYNC PER CHUNK — the engine's single ``jax.device_get`` per
    chunk is counted directly; sinks add zero transfers.
  * LIVE TAP — the opt-in ``jax.debug.callback`` tap observes the compiled
    math's own values: chunk results stay bit-exact with the tapless runner,
    and non-live slots are dropped.
  * TRACE CAPTURE — a static window produces a loadable xplane.pb whose
    string table contains the ``jax.named_scope`` phase annotations
    (fl.cohort_plan / cohort_gather / local_trajectory / aa_step / uplink /
    scatter; fl.psum is sharded-only and checked in the compiled HLO).
  * ROW SCHEMA — the JSONL emission passes scripts/check_metrics_jsonl.py,
    and the engine emits one row per EXECUTED round (header/footer framed).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (
    AlgoHParams,
    init_state,
    make_chunk_runner,
    make_round_fn,
    run_federated,
    run_rounds,
    solve_reference,
)
from repro.core.sharded import make_sharded_round_fn
from repro.data import make_binary_classification, partition
from repro.launch.mesh import make_host_mesh
from repro.models.logreg import make_logreg_problem
from repro.obs import (
    ROW_FIELDS,
    SCHEMA_VERSION,
    AlarmMonitor,
    AlarmRule,
    JsonlSink,
    LiveTap,
    MemorySink,
    MetricsSink,
    StdoutSink,
    TraceCapture,
    TraceConfig,
    find_trace_files,
    make_sink,
    trace_contains,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=400, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    wstar = solve_reference(prob, iters=50)
    return prob, wstar, make_host_mesh()


def _round_fn(prob, mesh, algo, hp, runtime, channel=None):
    if runtime == "sharded":
        return make_sharded_round_fn(algo, prob, hp, mesh, channel=channel)
    return make_round_fn(algo, prob, hp, channel)


def _history_identical(h0, h1, what=""):
    """Sinks must be bit-neutral: EXACT equality, not a tolerance."""
    np.testing.assert_array_equal(h1.loss, h0.loss, err_msg=what)
    np.testing.assert_array_equal(h1.grad_norm, h0.grad_norm, err_msg=what)
    np.testing.assert_array_equal(h1.rel_error, h0.rel_error, err_msg=what)
    np.testing.assert_array_equal(h1.gram_cond_max, h0.gram_cond_max,
                                  err_msg=what)
    np.testing.assert_array_equal(h1.comm_bytes, h0.comm_bytes, err_msg=what)
    for la, lb in zip(jax.tree.leaves(h0.final_params),
                      jax.tree.leaves(h1.final_params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=what)


class TestSinkUnits:
    def test_memory_sink_frames(self):
        s = MemorySink()
        s.open({"kind": "header"})
        s.emit([{"kind": "round", "round": 0}])
        s.emit([{"kind": "round", "round": 1}])
        s.close({"kind": "footer"})
        assert s.header["kind"] == "header"
        assert [r["round"] for r in s.rows] == [0, 1]
        assert s.footer["kind"] == "footer"

    def test_make_sink_specs(self, tmp_path):
        assert isinstance(make_sink("memory"), MemorySink)
        assert isinstance(make_sink("stdout"), StdoutSink)
        assert make_sink("stdout:5").every == 5
        js = make_sink(f"jsonl:{tmp_path}/m.jsonl")
        assert isinstance(js, JsonlSink)
        with pytest.raises(ValueError, match="path"):
            make_sink("jsonl")
        with pytest.raises(ValueError, match="unknown sink"):
            make_sink("carrier_pigeon")

    def test_sinks_satisfy_protocol(self):
        for s in (MemorySink(), StdoutSink(), JsonlSink("x"), AlarmMonitor()):
            assert isinstance(s, MetricsSink)

    def test_jsonl_nonfinite_to_null(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        s = JsonlSink(path)
        s.open({"v": SCHEMA_VERSION, "kind": "header"})
        s.emit([{"v": SCHEMA_VERSION, "kind": "round", "round": 0,
                 "loss": float("nan"), "grad_norm": float("inf")}])
        s.close({"v": SCHEMA_VERSION, "kind": "footer", "rounds": 1})
        lines = open(path).read().splitlines()
        assert len(lines) == 3
        row = json.loads(lines[1], parse_constant=lambda c: pytest.fail(
            f"non-strict constant {c}"))
        assert row["loss"] is None and row["grad_norm"] is None

    def test_jsonl_flushes_per_emit(self, tmp_path):
        """A crashed run must still hold every drained chunk on disk."""
        path = str(tmp_path / "m.jsonl")
        s = JsonlSink(path)
        s.open({"kind": "header"})
        s.emit([{"kind": "round", "round": 0, "loss": 1.0}])
        # file readable BEFORE close
        assert len(open(path).read().splitlines()) == 2
        s.close({"kind": "footer"})


class TestBitNeutrality:
    """Attached sinks leave runs bit-identical — the tentpole invariant."""

    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_engine_with_sinks_bit_identical(self, setup, runtime):
        prob, wstar, _ = setup
        # the adversarial config: cohort sampling + int8 wire + AA history
        hp = AlgoHParams(eta=0.5, local_epochs=3, cohort_size=4)
        kw = dict(w_star=wstar, runtime=runtime, channel="int8", chunk=2)
        h0 = run_federated(prob, "fedosaa_svrg", hp, 4, **kw)
        sink = MemorySink()
        h1 = run_federated(prob, "fedosaa_svrg", hp, 4, **kw,
                           sinks=[sink, AlarmMonitor()])
        _history_identical(h0, h1, what=f"engine/{runtime}")
        assert len(sink.rows) == 4

    def test_loop_with_sinks_bit_identical(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, cohort_size=4)
        kw = dict(w_star=wstar, channel="int8")  # chunk=None: per-round loop
        h0 = run_federated(prob, "fedosaa_svrg", hp, 4, **kw)
        sink = MemorySink()
        h1 = run_federated(prob, "fedosaa_svrg", hp, 4, **kw,
                           sinks=[sink, AlarmMonitor()])
        _history_identical(h0, h1, what="loop/vmap")
        assert len(sink.rows) == 4

    def test_loop_and_engine_emit_matching_metric_rows(self, setup):
        """Same run through both drivers: the sink sees the same metric
        columns (documented rtol 1e-6, like tests/test_engine.py — the two
        paths are separate executables; wall attribution may differ)."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        s_loop, s_eng = MemorySink(), MemorySink()
        run_federated(prob, "fedosaa_svrg", hp, 4, w_star=wstar,
                      sinks=[s_loop])
        run_federated(prob, "fedosaa_svrg", hp, 4, w_star=wstar, chunk=2,
                      sinks=[s_eng])
        for f in ("loss", "grad_norm", "rel_error", "theta_mean",
                  "gram_cond_max", "gram_cond_mean", "aa_used_min",
                  "cohort_ess", "comm_bytes", "comm_bytes_total"):
            a = [r[f] for r in s_loop.rows]
            b = [r[f] for r in s_eng.rows]
            np.testing.assert_allclose(a, b, rtol=1e-5, err_msg=f)


class TestOneSyncPerChunk:
    def test_exactly_one_device_get_per_chunk(self, setup, monkeypatch):
        """Sinks are fed from the chunk's ONE existing host sync — attaching
        them must not add any device→host transfer."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        calls = []
        orig = jax.device_get

        def counting(x):
            calls.append(1)
            return orig(x)

        monkeypatch.setattr(jax, "device_get", counting)
        sink = MemorySink()
        _, trace = run_rounds(rf, state, 8, chunk=4, w_star=wstar,
                              sinks=[sink, AlarmMonitor()])
        assert trace.num_rounds == 8
        assert len(sink.rows) == 8
        assert len(calls) == 2  # 8 rounds / chunk 4 = 2 chunks = 2 syncs

    def test_row_indices_contiguous_and_cumulative(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        sink = MemorySink()
        run_rounds(rf, state, 5, chunk=2, w_star=wstar, sinks=[sink])
        assert [r["round"] for r in sink.rows] == [0, 1, 2, 3, 4]
        for f in ("comm_bytes_total", "wall_time_s"):
            col = [r[f] for r in sink.rows]
            assert all(b >= a for a, b in zip(col, col[1:])), f
        assert sink.header["fields"] == list(ROW_FIELDS)
        assert sink.footer["rounds"] == 5 and sink.footer["stopped"] is False

    def test_start_round_offsets_rows(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        sink = MemorySink()
        run_rounds(rf, state, 3, chunk=2, w_star=wstar, sinks=[sink],
                   start_round=10)
        assert [r["round"] for r in sink.rows] == [10, 11, 12]
        assert sink.header["start_round"] == 10


class TestLiveTap:
    def test_tap_matches_tapless_and_drops_nonlive(self, setup):
        """The debug.callback tap observes the compiled math's own values —
        tap rows equal the SAME run's stacked metrics bit-for-bit — while
        the tapped executable matches the tapless one at the engine's
        documented rtol 1e-6 (the inserted callback shifts XLA fusion by an
        ulp; see make_chunk_runner). Slots past n_live never reach the tap."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        tap = LiveTap()
        r_plain = make_chunk_runner(rf, 4, w_star=wstar, donate=False)
        r_tap = make_chunk_runner(rf, 4, w_star=wstar, donate=False, tap=tap)
        s0 = init_state(prob, jax.random.PRNGKey(0), hp, None, "fedosaa_svrg")
        s1 = init_state(prob, jax.random.PRNGKey(0), hp, None, "fedosaa_svrg")
        out0 = r_plain(s0, np.int32(3))  # short chunk: slot 3 not live
        out1 = r_tap(s1, np.int32(3))
        jax.effects_barrier()
        for la, lb in zip(jax.tree.leaves(out0), jax.tree.leaves(out1)):
            a, b = np.asarray(la), np.asarray(lb)
            if a.dtype.kind == "f":
                mask = ~(np.isnan(a) & np.isnan(b))
                np.testing.assert_allclose(b[mask], a[mask], rtol=1e-6,
                                           atol=1e-7)
            else:
                np.testing.assert_array_equal(a, b)
        assert [r["slot"] for r in tap.rows] == [0, 1, 2]
        # vs the SAME (tapped) executable: exactly the values it computed
        _, _, ms, rels, _ = out1
        for i, row in enumerate(tap.rows):
            assert row["loss"] == float(np.asarray(ms.loss)[i])
            assert row["rel_error"] == float(np.asarray(rels)[i])


class TestTraceCapture:
    def test_static_window_produces_scoped_trace(self, setup, tmp_path):
        """--trace-rounds acceptance: the window yields a loadable xplane.pb
        whose string table holds every vmap round-phase scope."""
        prob, _, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, cohort_size=4)
        rf = make_round_fn("fedosaa_svrg", prob, hp, "int8")
        state = init_state(prob, jax.random.PRNGKey(0), hp, "int8",
                           "fedosaa_svrg")
        tdir = str(tmp_path / "trace")
        tc = TraceCapture(TraceConfig(trace_dir=tdir, start_round=0,
                                      num_rounds=2))
        _, trace = run_rounds(rf, state, 4, chunk=2, trace_capture=tc)
        assert trace.num_rounds == 4
        assert tc.windows == [(0, 2)]
        assert not tc.active
        assert find_trace_files(tdir)
        for scope in ("fl.cohort_plan", "fl.cohort_gather",
                      "fl.local_trajectory", "fl.aa_step", "fl.uplink",
                      "fl.scatter"):
            assert trace_contains(tdir, scope), scope

    def test_psum_scope_in_sharded_hlo(self, setup):
        """fl.psum wraps the sharded all-reduce; cheap compiled-HLO check
        instead of a second profiler run."""
        prob, _, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_sharded_round_fn("fedosaa_svrg", prob, hp, mesh)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        txt = jax.jit(rf).lower(state).compile().as_text()
        assert "fl.psum" in txt
        assert "fl.aa_step" in txt

    def test_trigger_file_arms_one_window(self, tmp_path):
        tdir = str(tmp_path / "trace")
        trigger = str(tmp_path / "TRACE_NOW")
        tc = TraceCapture(TraceConfig(trace_dir=tdir, trigger_file=trigger))
        tc.on_chunk_start(0, 4)   # no trigger yet: stays off
        tc.on_chunk_end(4)
        assert not tc.active and tc.windows == []
        open(trigger, "w").close()
        tc.on_chunk_start(4, 4)   # trigger consumed, window opens
        assert tc.active and not os.path.exists(trigger)
        tc.on_chunk_end(8)
        assert not tc.active and tc.windows == [(4, 8)]
        tc.on_chunk_start(8, 4)   # one touch = one window
        assert not tc.active
        tc.close()

    def test_close_stops_leaked_window(self, tmp_path):
        tc = TraceCapture(TraceConfig(trace_dir=str(tmp_path / "t"),
                                      start_round=0, num_rounds=100))
        tc.on_chunk_start(0, 4)
        assert tc.active
        tc.close()  # early exit: never leak an open profiler session
        assert not tc.active and tc.windows == [(0, -1)]

    def test_disabled_config(self, tmp_path):
        assert not TraceConfig(trace_dir=str(tmp_path)).enabled
        assert TraceConfig(trace_dir=str(tmp_path), num_rounds=2).enabled
        assert TraceConfig(trace_dir=str(tmp_path),
                           trigger_file="x").enabled


def _row(t, **kw):
    # a real row always carries a loss; a missing/null loss IS the
    # loss_nonfinite condition, so give unit tests a healthy default
    base = {"v": SCHEMA_VERSION, "kind": "round", "round": t, "loss": 0.5}
    base.update(kw)
    return base


class TestAlarms:
    def test_nonfinite_loss_requests_stop(self):
        mon = AlarmMonitor()
        mon.emit([_row(0, loss=0.5)])
        assert not mon.stop_requested
        mon.emit([_row(1, loss=float("nan"))])
        assert mon.stop_requested
        assert mon.events[0]["rule"] == "loss_nonfinite"
        # null (serialized non-finite) also counts
        mon2 = AlarmMonitor()
        mon2.emit([_row(0, loss=None)])
        assert mon2.stop_requested

    def test_gram_cond_blowup_warns_not_stops(self, caplog):
        mon = AlarmMonitor()
        with caplog.at_level("WARNING", logger="repro.obs.alarms"):
            mon.emit([_row(0, gram_cond_max=1e13)])
        assert not mon.stop_requested
        assert mon.events[0]["rule"] == "gram_cond_blowup"
        assert "gram_cond_blowup" in caplog.text

    def test_nan_never_satisfies_gt_lt(self):
        """Non-AA algos report nan gram_cond/aa_used — must not alarm."""
        mon = AlarmMonitor()
        mon.emit([_row(0, gram_cond_max=float("nan"),
                       aa_used_min=float("nan"))])
        assert mon.events == []

    def test_aa_column_collapse(self):
        mon = AlarmMonitor()
        mon.emit([_row(0, aa_used_min=0.0)])
        assert mon.events[0]["rule"] == "aa_columns_collapsed"

    def test_plateau_fires_after_window(self):
        rule = AlarmRule("plat", "rel_error", "no_improve", window=5,
                         min_improve=1e-3)
        mon = AlarmMonitor(rules=(rule,))
        mon.emit([_row(t, rel_error=1.0) for t in range(5)])
        assert mon.events == []  # needs window+1 rows
        mon.emit([_row(5, rel_error=1.0)])
        assert mon.events[0]["rule"] == "plat"
        # an improving run never plateaus
        mon2 = AlarmMonitor(rules=(rule,))
        mon2.emit([_row(t, rel_error=1.0 * 0.9 ** t) for t in range(20)])
        assert mon2.events == []

    def test_cooldown_suppresses_refires(self):
        rule = AlarmRule("hot", "loss", "gt", threshold=0.0)
        mon = AlarmMonitor(rules=(rule,), cooldown=10)
        mon.emit([_row(t, loss=1.0) for t in range(12)])
        assert [e["round"] for e in mon.events] == [0, 10]

    def test_rule_validation(self):
        with pytest.raises(ValueError, match="op"):
            AlarmRule("x", "loss", "between")
        with pytest.raises(ValueError, match="threshold"):
            AlarmRule("x", "loss", "gt")
        with pytest.raises(ValueError, match="action"):
            AlarmRule("x", "loss", "nonfinite", action="explode")

    def test_stop_rule_halts_engine_at_chunk_boundary(self, setup):
        """The host-side twin of the in-graph stop criteria: a stop alarm
        ends the run at the next chunk boundary, and the footer records it."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        mon = AlarmMonitor(rules=(
            AlarmRule("tripwire", "loss", "gt", threshold=-1e30,
                      action="stop"),))
        sink = MemorySink()
        _, trace = run_rounds(rf, state, 8, chunk=2, w_star=wstar,
                              sinks=[sink, mon])
        assert mon.stop_requested
        assert trace.num_rounds == 2  # stopped after the first chunk
        assert trace.stopped
        assert sink.footer["stopped"] is True
        assert sink.footer["rounds"] == 2
        assert any(e["rule"] == "tripwire" for e in sink.footer["alarms"])


class TestJsonlEndToEnd:
    def test_engine_jsonl_passes_validator(self, setup, tmp_path):
        """Acceptance: a chunked engine run streams one row per executed
        round to JSONL and the schema validator passes it."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, cohort_size=4)
        path = str(tmp_path / "metrics.jsonl")
        h = run_federated(prob, "fedosaa_svrg", hp, 5, w_star=wstar,
                          channel="int8", chunk=2, sinks=[JsonlSink(path)])
        lines = open(path).read().splitlines()
        assert len(lines) == 7  # header + 5 rounds + footer
        header = json.loads(lines[0])
        assert header["kind"] == "header" and header["v"] == SCHEMA_VERSION
        assert header["algo"] == "fedosaa_svrg"
        assert header["runtime"] == "vmap"
        assert header["channel"] == "int8+ef"  # resolved channel name
        assert header["num_clients"] == 8
        assert header["cohort_size"] == 4
        assert isinstance(header["uplink_bytes"], dict)
        assert sum(header["uplink_bytes"].values()) > 0
        rows = [json.loads(l) for l in lines[1:-1]]
        np.testing.assert_array_equal([r["loss"] for r in rows], h.loss)
        np.testing.assert_array_equal(
            [r["gram_cond_max"] for r in rows], h.gram_cond_max)
        res = subprocess.run(
            [sys.executable, "scripts/check_metrics_jsonl.py", path],
            cwd=REPO_ROOT, capture_output=True, text=True)
        assert res.returncode == 0, res.stderr

    def test_validator_rejects_corrupt_file(self, setup, tmp_path):
        good = str(tmp_path / "good.jsonl")
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        run_federated(prob, "fedsvrg", hp, 3, w_star=wstar, chunk=2,
                      sinks=[JsonlSink(good)])
        lines = open(good).read().splitlines()
        for mutant, expect in [
            (lines[:-1], "footer"),                      # truncated footer
            (lines[:1] + lines[2:], "round"),            # gap in rounds
            (lines[1:], "header"),                       # missing header
            (lines[:-1] + ['{"bad json'], "invalid JSON"),
        ]:
            bad = str(tmp_path / "bad.jsonl")
            with open(bad, "w") as f:
                f.write("\n".join(mutant) + "\n")
            res = subprocess.run(
                [sys.executable, "scripts/check_metrics_jsonl.py", bad],
                cwd=REPO_ROOT, capture_output=True, text=True)
            assert res.returncode == 1, expect
            assert expect in res.stderr


class TestHistoryGramCond:
    @pytest.mark.parametrize("chunk", [None, 3])
    def test_gram_cond_in_history_and_summary(self, setup, chunk):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        h = run_federated(prob, "fedosaa_svrg", hp, 4, w_star=wstar,
                          chunk=chunk)
        assert h.gram_cond_max.shape == (4,)
        assert np.isfinite(h.gram_cond_max).all()
        assert "gcond=" in h.summary()
        assert "wall=" in h.summary()

    def test_non_aa_algo_reports_nan_not_zero(self, setup):
        """fedsvrg has no AA step: gram_cond/aa_used columns are nan (absent)
        rather than a fake 0 — the alarm rules rely on this."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        sink = MemorySink()
        h = run_federated(prob, "fedsvrg", hp, 3, w_star=wstar, chunk=2,
                          sinks=[sink, AlarmMonitor()])
        assert np.isnan(h.gram_cond_max).all()
        assert all(r["aa_used_min"] is None or np.isnan(r["aa_used_min"])
                   for r in sink.rows)
        assert "gcond=nan" in h.summary()
