"""Device-resident round engine (core/engine.py): chunking equivalence and
donation safety.

The contract: a chunked ``run_rounds`` trace matches the per-round Python
loop — same per-round rows, same final state — in BOTH runtimes, including
the carried comm state and cross-round AA history. The engine's scan body
applies the round unconditionally and selects the carried state (see the
module docstring for why not lax.cond), which keeps the chunked rounds
BIT-exact with the sequential jit on this container; the tests assert the
documented rtol 1e-6 so an ulp-level fusion change in a future jax doesn't
flake them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AAConfig,
    AlgoHParams,
    init_state,
    make_chunk_runner,
    make_round_fn,
    run_federated,
    run_rounds,
    solve_reference,
)
from repro.core.sharded import make_sharded_round_fn
from repro.data import make_binary_classification, partition
from repro.launch.mesh import make_host_mesh
from repro.models.logreg import make_logreg_problem


@pytest.fixture(scope="module")
def setup():
    X, y = make_binary_classification("synthetic_small", n=400, seed=0)
    clients = partition(X, y, num_clients=8, scheme="iid")
    prob = make_logreg_problem(clients, gamma=1e-3)
    wstar = solve_reference(prob, iters=50)
    return prob, wstar, make_host_mesh()


def _round_fn(prob, mesh, algo, hp, runtime, channel=None):
    if runtime == "sharded":
        return make_sharded_round_fn(algo, prob, hp, mesh, channel=channel)
    return make_round_fn(algo, prob, hp, channel)


def assert_tree_allclose(a, b, rtol=1e-6, atol=1e-7, what=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol, err_msg=what
        )


def _history_equiv(prob, wstar, algo, hp, runtime, rounds, chunk,
                   channel=None, **kw):
    h0 = run_federated(prob, algo, hp, rounds, w_star=wstar, runtime=runtime,
                       channel=channel, **kw)
    h1 = run_federated(prob, algo, hp, rounds, w_star=wstar, runtime=runtime,
                       channel=channel, chunk=chunk, **kw)
    what = f"{algo}/{runtime}/chunk={chunk}"
    assert len(h0.rounds) == len(h1.rounds), what
    np.testing.assert_allclose(h1.loss, h0.loss, rtol=1e-6, err_msg=what)
    np.testing.assert_allclose(h1.grad_norm, h0.grad_norm, rtol=1e-6,
                               atol=1e-9, err_msg=what)
    np.testing.assert_allclose(h1.rel_error, h0.rel_error, rtol=1e-5,
                               atol=1e-9, err_msg=what)
    np.testing.assert_allclose(h1.comm_bytes, h0.comm_bytes, rtol=1e-6,
                               err_msg=what)
    tm0, tm1 = h0.theta_mean, h1.theta_mean
    np.testing.assert_array_equal(np.isnan(tm0), np.isnan(tm1), err_msg=what)
    np.testing.assert_allclose(tm1[~np.isnan(tm1)], tm0[~np.isnan(tm0)],
                               rtol=1e-4, err_msg=what)
    assert_tree_allclose(h0.final_params, h1.final_params, what=what)
    return h0, h1


class TestChunkingEquivalence:
    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    @pytest.mark.parametrize("algo", ["fedosaa_svrg", "fedosaa_scaffold",
                                      "fedsvrg", "giant"])
    def test_trace_matches_loop(self, setup, algo, runtime):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        _history_equiv(prob, wstar, algo, hp, runtime, rounds=7, chunk=3)

    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_comm_state_matches_loop(self, setup, runtime):
        """The carried comm state (int8 EF residuals + diff-coding refs)
        must round-trip through the donated scan identically — compared
        buffer-for-buffer after the same number of rounds."""
        prob, wstar, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = _round_fn(prob, mesh, "fedosaa_svrg", hp, runtime, channel="int8")
        jf = jax.jit(rf)
        s_loop = init_state(prob, jax.random.PRNGKey(0), hp, "int8",
                            "fedosaa_svrg")
        for _ in range(6):
            s_loop, _ = jf(s_loop)
        s_eng, trace = run_rounds(
            rf, init_state(prob, jax.random.PRNGKey(0), hp, "int8",
                           "fedosaa_svrg"), 6, chunk=4, w_star=wstar)
        assert trace.num_rounds == 6
        assert s_loop.comm is not None
        assert_tree_allclose(s_loop.comm, s_eng.comm, what="comm state")
        assert_tree_allclose(s_loop.params, s_eng.params, what="params")

    @pytest.mark.parametrize("runtime", ["vmap", "sharded"])
    def test_carry_history_matches_loop(self, setup, runtime):
        """Cross-round AA history (App. A opt. 1) rides the scan carry."""
        prob, wstar, mesh = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3, carry_history=2,
                         aa=AAConfig(tikhonov=1e-6, damping=0.7))
        rf = _round_fn(prob, mesh, "fedosaa_svrg", hp, runtime)
        jf = jax.jit(rf)
        s_loop = init_state(prob, jax.random.PRNGKey(0), hp, None,
                            "fedosaa_svrg")
        for _ in range(5):
            s_loop, _ = jf(s_loop)
        s_eng, trace = run_rounds(
            rf, init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg"), 5, chunk=2, w_star=wstar)
        assert trace.num_rounds == 5
        assert s_loop.hist_s is not None
        assert_tree_allclose(s_loop.hist_s, s_eng.hist_s, what="hist_s")
        assert_tree_allclose(s_loop.hist_y, s_eng.hist_y, what="hist_y")
        assert_tree_allclose(s_loop.params, s_eng.params, what="params")

    def test_early_stop_same_round(self, setup):
        """A stop criterion firing mid-chunk truncates the trace at the SAME
        round as the loop's break, and never advances the state past it."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        h0, h1 = _history_equiv(prob, wstar, "fedosaa_svrg", hp, "vmap",
                                rounds=30, chunk=7, stop_rel_error=0.09)
        # the target must actually fire mid-run for this test to bite
        assert len(h0.rounds) < 30
        assert h0.rel_error[-1] < 0.09

    def test_grad_norm_stop(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        h0, h1 = _history_equiv(prob, wstar, "fedsvrg", hp, "vmap",
                                rounds=30, chunk=8, stop_grad_norm=0.05)
        assert len(h0.rounds) < 30

    def test_partial_final_chunk(self, setup):
        """num_rounds not divisible by chunk: the short final chunk reuses
        the same executable via n_live and drops the padding rows."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        _history_equiv(prob, wstar, "fedosaa_svrg", hp, "vmap",
                       rounds=5, chunk=4)

    def test_chunk_larger_than_rounds(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        _history_equiv(prob, wstar, "fedsvrg", hp, "vmap", rounds=3, chunk=16)


class TestDonationSafety:
    def test_input_state_is_consumed(self, setup):
        """donate=True consumes the caller's state buffers (the documented
        engine contract): XLA reuses the K×d client buffers in place."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        out_state, _ = run_rounds(rf, state, 2, chunk=2, w_star=wstar)
        assert any(leaf.is_deleted() for leaf in jax.tree.leaves(state))
        assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(out_state))

    def test_never_reads_consumed_buffer(self, setup):
        """Multi-chunk runs (state re-donated every chunk) and a second
        run_rounds on the returned state: if the engine ever re-read a
        donated buffer, jax would raise 'Array has been deleted'."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        state, trace = run_rounds(rf, state, 6, chunk=2, w_star=wstar)
        assert trace.num_rounds == 6
        jax.block_until_ready(jax.tree.leaves(state.params))
        state, trace2 = run_rounds(rf, state, 4, chunk=2, w_star=wstar)
        assert trace2.num_rounds == 4
        assert np.isfinite(trace2.loss).all()

    def test_runner_second_call_after_block(self, setup):
        """The raw chunk runner: block_until_ready between calls, feed the
        returned state back — the donated executable must never alias a
        buffer the host still reads."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        runner = make_chunk_runner(rf, 3, w_star=wstar)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        state, done, ms, rels, lives = runner(state, np.int32(3))
        jax.block_until_ready(jax.tree.leaves(state.params))
        loss1 = np.asarray(jax.device_get(ms.loss))
        state, done, ms, rels, lives = runner(state, np.int32(3))
        loss2 = np.asarray(jax.device_get(ms.loss))
        assert np.isfinite(loss1).all() and np.isfinite(loss2).all()
        # monotone decrease across the chunk boundary: the second chunk
        # really continued from the first chunk's final state
        assert loss2[0] < loss1[0]

    def test_w0_not_consumed_by_engine_path(self, setup):
        """run_federated(w0=..., chunk=...) must COPY the caller's w0 into
        the donated state — the same w0 arrays stay usable across calls."""
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        w0 = prob.init(jax.random.PRNGKey(7))
        h1 = run_federated(prob, "fedsvrg", hp, 3, w_star=wstar, w0=w0,
                           chunk=2)
        assert not any(l.is_deleted() for l in jax.tree.leaves(w0))
        h2 = run_federated(prob, "fedosaa_svrg", hp, 3, w_star=wstar, w0=w0,
                           chunk=2)
        assert np.isfinite(h1.loss).all() and np.isfinite(h2.loss).all()

    def test_donate_false_preserves_input(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedsvrg", prob, hp)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None, "fedsvrg")
        _, trace = run_rounds(rf, state, 2, chunk=2, w_star=wstar,
                              donate=False)
        assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(state))
        # the preserved input is still usable
        _, trace2 = run_rounds(rf, state, 2, chunk=2, w_star=wstar,
                               donate=False)
        np.testing.assert_allclose(trace2.loss, trace.loss, rtol=1e-6)


class TestEngineMechanics:
    def test_rejects_bad_chunk(self, setup):
        prob, _, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedsvrg", prob, hp)
        with pytest.raises(ValueError, match="chunk"):
            make_chunk_runner(rf, 0)

    def test_run_federated_rejects_chunk_zero(self, setup):
        """The CLIs map 0 to None (per-round loop); a direct chunk=0 must
        error rather than silently picking a path."""
        prob, _, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        with pytest.raises(ValueError, match="chunk"):
            run_federated(prob, "fedsvrg", hp, 2, chunk=0)

    def test_wall_time_monotone_and_rows_cumulative(self, setup):
        prob, wstar, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        h = run_federated(prob, "fedsvrg", hp, 6, w_star=wstar, chunk=3)
        assert (np.diff(h.wall_time) > 0).all()
        assert (np.diff(h.comm_bytes) > 0).all()
        np.testing.assert_array_equal(h.rounds, np.arange(6))

    def test_single_dispatch_per_chunk(self, setup):
        """The whole chunk lowers as ONE XLA computation containing the
        scan: B rounds = one dispatch."""
        prob, _, _ = setup
        hp = AlgoHParams(eta=0.5, local_epochs=3)
        rf = make_round_fn("fedosaa_svrg", prob, hp)
        runner = make_chunk_runner(rf, 4, donate=False)
        state = init_state(prob, jax.random.PRNGKey(0), hp, None,
                           "fedosaa_svrg")
        txt = runner.lower(state, np.int32(4)).compile().as_text()
        assert "while" in txt  # the rounds live in one compiled scan loop
