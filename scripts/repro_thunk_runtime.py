"""Minimal standalone repro of the XLA:CPU thunk-runtime loop-body slowdown.

PR 4's round benchmark found that under XLA:CPU's default *thunk runtime*
the SAME jitted body costs ~1.6x more inside a ``lax.scan`` than dispatched
as a standalone jit (and in-loop collectives degrade ~10x) — enough to
invert the chunked round engine's win, which is why
``benchmarks/bench_round.py`` pins ``--xla_cpu_use_thunk_runtime=false``.
This script is the upstream-reportable repro the ROADMAP asks for: no repro
internals, just a small chain of matmul/elementwise ops (sized like the
quick-covtype round body) timed

  standalone — one jit of the body, called N times (device-synced each call)
  scan       — one jit of ``lax.scan`` over the same body, N iterations

under BOTH runtime settings (each in a fresh subprocess — the flag is read
once at backend init). The regression is the ``thunk_scan_penalty_vs_legacy``
ratio: the SAME compiled scan body per-iteration cost, thunk vs legacy
(~1.2x on this container's einsum body; the real round body shows ~1.6x in
bench_round); scan_over_standalone per setting is recorded too.

  python scripts/repro_thunk_runtime.py            # full (N=100)
  python scripts/repro_thunk_runtime.py --smoke    # CI-sized (N=20)

Writes benchmarks/results/thunk_runtime_repro.json and exits non-zero only
on execution errors — the ratio is recorded, not gated (it is jaxlib-
version dependent; retest on upgrades).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

#: committed artifact (full run); --smoke writes to the gitignored scratch
#: path so CI never clobbers the recorded full-size measurement
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "results", "thunk_runtime_repro.json")
SMOKE_PATH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "results", "thunk_runtime_repro_smoke.json")


def child(n_iters: int) -> None:
    """Runs in a subprocess with XLA_FLAGS already set; prints one JSON."""
    import time

    import jax
    import jax.numpy as jnp

    # ~quick-covtype round-body scale (a few ms/iter, so per-call dispatch
    # overhead is NOT what is measured): L inner corrected-GD steps over a
    # [K, n, d] batch, like one FL round's local trajectories
    K, n, d, L = 10, 2000, 96, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (K, n, d))
    w0 = jax.random.normal(jax.random.PRNGKey(1), (K, d))

    def body(w):
        def gd(w, _):
            z = jnp.einsum("knd,kd->kn", x, w)
            c = jax.nn.sigmoid(z) - 0.5
            g = jnp.einsum("kn,knd->kd", c, x) / n
            return w - 0.5 * (g + 1e-3 * w), None
        return jax.lax.scan(gd, w, None, length=L)[0]

    jit_body = jax.jit(body)

    def scanned(w):
        return jax.lax.scan(lambda c, _: (body(c), None), w, None,
                            length=n_iters)[0]

    jit_scan = jax.jit(scanned)

    jax.block_until_ready(jit_body(w0))       # compile
    jax.block_until_ready(jit_scan(w0))

    def time_standalone():
        t0 = time.perf_counter()
        w = w0
        for _ in range(n_iters):
            w = jit_body(w)
        jax.block_until_ready(w)
        return (time.perf_counter() - t0) / n_iters

    def time_scan():
        t0 = time.perf_counter()
        jax.block_until_ready(jit_scan(w0))
        return (time.perf_counter() - t0) / n_iters

    # interleaved min-of-reps, as in benchmarks/bench_round.py — this
    # shared container's noisy-neighbor spikes exceed the effect size, and
    # interleaving means a spike hits both modes, not just one
    reps = 5
    standalone_t, scan_t = [], []
    for _ in range(reps):
        standalone_t.append(time_standalone())
        scan_t.append(time_scan())
    standalone, scan = min(standalone_t), min(scan_t)
    print(json.dumps({
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "n_iters": n_iters,
        "reps_min_taken": reps,
        "standalone_s_per_iter": standalone,
        "scan_s_per_iter": scan,
        "scan_over_standalone": scan / standalone,
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    n_iters = 20 if args.smoke else 100

    if args.child:
        child(n_iters)
        return

    results = {}
    for thunk in (True, False):
        env = dict(os.environ)
        # scrub any conflicting pre-set flag (bench_round users often pin
        # one in their shell) and append ours LAST — the last occurrence
        # wins in XLA, so a prepended flag would be silently overridden and
        # both children would measure the same runtime
        inherited = [t for t in env.get("XLA_FLAGS", "").split()
                     if not t.startswith("--xla_cpu_use_thunk_runtime")]
        env["XLA_FLAGS"] = " ".join(
            inherited + [f"--xla_cpu_use_thunk_runtime={str(thunk).lower()}"])
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--child"]
            + (["--smoke"] if args.smoke else []),
            env=env, capture_output=True, text=True, check=True)
        results["thunk_runtime" if thunk else "legacy_runtime"] = json.loads(
            out.stdout.strip().splitlines()[-1])

    ratio_thunk = results["thunk_runtime"]["scan_over_standalone"]
    ratio_legacy = results["legacy_runtime"]["scan_over_standalone"]
    summary = {
        "repro": "xla_cpu_thunk_runtime_scan_slowdown",
        "body": "K=10,n=2000,d=96 x L=8 sigmoid-GD steps (round-body scale)",
        "results": results,
        "thunk_scan_penalty_vs_legacy":
            results["thunk_runtime"]["scan_s_per_iter"]
            / results["legacy_runtime"]["scan_s_per_iter"],
        "note": "thunk_scan_penalty_vs_legacy >> 1 is the regression (the "
                "same compiled loop body, slower runtime); bench_round.py "
                "pins the legacy runtime.",
    }
    summary["smoke"] = args.smoke
    path = SMOKE_PATH if args.smoke else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"thunk runtime: scan/standalone = {ratio_thunk:.2f}; "
          f"legacy runtime: {ratio_legacy:.2f}; thunk-vs-legacy scan "
          f"penalty = {summary['thunk_scan_penalty_vs_legacy']:.2f} "
          f"({path})")


if __name__ == "__main__":
    main()
