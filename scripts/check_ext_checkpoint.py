"""Validate the committed checkpoint save-overlap artifact
(benchmarks/results/ext_checkpoint.json).

Shared by scripts/ci.sh and .github/workflows/ci.yml so the gate cannot
drift between the two.

  python scripts/check_ext_checkpoint.py [path]

Checks structure (the none/async/sync_gather rows plus the summary) and
the PR's acceptance invariants:

  * the async checkpoint mode's median per-chunk overhead over the
    no-checkpoint floor is <= the committed budget (10% — "checkpointing
    is effectively free at chunk cadence"),
  * every mode ran the bit-identical math (checkpointing must never
    perturb the training trajectory),
  * the checkpointing runs committed saves, accounted non-zero bytes, and
    recorded zero checkpoint failures in their v4 footers.

Failures raise (never bare `assert`, which python -O strips — this script
is a CI gate).
"""
import json
import math
import sys

args = [a for a in sys.argv[1:] if not a.startswith("--")]
path = args[0] if args else "benchmarks/results/ext_checkpoint.json"


def fail(msg: str):
    raise SystemExit(f"check_ext_checkpoint: {path}: {msg}")


with open(path) as f:
    rows = json.load(f)
by = {r["name"]: r for r in rows}

expected = {
    "ext_checkpoint/none",
    "ext_checkpoint/async",
    "ext_checkpoint/sync_gather",
    "ext_checkpoint/summary",
}
got = {r["name"] for r in rows}
if got != expected:
    fail(f"not the full row set: missing {sorted(expected - got)}, "
         f"unexpected {sorted(got - expected)}")

for r in rows:
    if r["name"].endswith("summary"):
        continue
    if r.get("rounds", 0) < 1:
        fail(f"{r['name']}: no rounds executed")
    if not math.isfinite(r["final_loss"]):
        fail(f"{r['name']}: final loss is non-finite")
    if r.get("chunk_wall_median_s", 0) <= 0:
        fail(f"{r['name']}: no per-chunk wall recorded")
    if r.get("checkpoint_failures", 0) != 0:
        fail(f"{r['name']}: {r['checkpoint_failures']} checkpoint "
             "failures during the benchmark")
    if r["name"] != "ext_checkpoint/none":
        if r.get("checkpoint_bytes", 0) <= 0:
            fail(f"{r['name']}: footer accounted zero checkpoint bytes")
        if r.get("checkpoint_save_ms", 0) <= 0:
            fail(f"{r['name']}: footer accounted zero save time")

if by["ext_checkpoint/async"].get("checkpoints_committed", 0) < 1:
    fail("async mode committed no checkpoints")

s = by["ext_checkpoint/summary"]
budget = s.get("overhead_budget", 0.10)
overhead = s.get("async_overhead")
if overhead is None or not overhead <= budget:
    fail(f"async per-chunk overhead {overhead} exceeds the {budget:.0%} "
         "budget over the no-checkpoint floor")
if not s.get("loss_curves_identical_across_modes"):
    fail("checkpointing modes did not produce bit-identical loss curves — "
         "a save perturbed the math")
if s.get("async_checkpoint_bytes", 0) <= 0:
    fail("summary accounted zero async checkpoint bytes")

print(f"ci: {path} well-formed (async overhead {overhead:+.1%} of "
      f"{1e3 * s['none_chunk_wall_s']:.0f}ms chunks, budget {budget:.0%}; "
      f"sync_gather {s['sync_gather_overhead']:+.1%}; "
      f"{by['ext_checkpoint/async']['checkpoints_committed']} saves, "
      f"{s['async_checkpoint_bytes']} bytes)")
