"""Kill-resume smoke: a real fl_train process hard-killed MID-SAVE (the
crash-injection fs, repro/robust/fs_faults) must leave a directory that
``--resume auto`` turns back into one contiguous run.

Shared by scripts/ci.sh and .github/workflows/ci.yml. The scenario:

  1. segment 1: fl_train with checkpointing every 2 rounds and
     ``--inject-kill-save 2`` — the process os._exit()s with code 43 in
     the middle of its SECOND save (round 4), after the round-2 save
     committed. The checkpoint dir must hold the committed round-2
     checkpoint AND the torn ``.tmp-*`` staging remnant of the fatal save;
     the metrics JSONL must hold a header and contiguous round rows but NO
     footer (the process died mid-run).
  2. segment 2: the same command with ``--resume auto`` — discovery skips
     the torn remnant, restores round 2, and finishes rounds 2..7. Its
     JSONL must pass the FULL v4 contract (scripts/check_metrics_jsonl.py,
     imported — same validator CI runs elsewhere) with start_round=2.
  3. the two segments' round rows must union to one contiguous 0..7 run.

Scratch artifacts only (a temp dir); writes nothing into the repo.

  PYTHONPATH=src python scripts/kill_resume_smoke.py
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from check_metrics_jsonl import check_file  # noqa: E402

from repro.robust.fs_faults import KILL_EXIT_CODE  # noqa: E402

ROUNDS = 8


def fail(msg: str):
    raise SystemExit(f"kill_resume_smoke: {msg}")


def fl_train(ckpt_dir: str, metrics: str, *extra: str) -> int:
    cmd = [
        sys.executable, "-m", "repro.launch.fl_train",
        "--arch", "smollm-135m", "--reduced", "--algo", "fedosaa_svrg",
        "--rounds", str(ROUNDS), "--clients", "4", "--round-chunk", "2",
        "--checkpoint-dir", ckpt_dir, "--checkpoint-every", "2",
        "--metrics-out", metrics, *extra,
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(cmd, env=env).returncode


def check_headless_segment(path: str) -> list[int]:
    """Segment 1 died mid-run: header + contiguous finite rows, no footer."""
    with open(path) as f:
        rows = [json.loads(line) for line in f.read().splitlines()]
    if not rows or rows[0].get("kind") != "header":
        fail(f"{path}: first row is not a header")
    start = int(rows[0].get("start_round", 0))
    if any(r.get("kind") == "footer" for r in rows):
        fail(f"{path}: a killed run must not have written a footer")
    seen = []
    for i, r in enumerate(rows[1:]):
        if r.get("kind") != "round":
            fail(f"{path}: row {i + 2} kind={r.get('kind')!r}")
        if r["round"] != start + i:
            fail(f"{path}: round {r['round']} breaks contiguity at "
                 f"row {i + 2}")
        if r.get("loss") is None:
            fail(f"{path}: round {r['round']} has null loss")
        seen.append(r["round"])
    if not seen:
        fail(f"{path}: the killed run streamed no round rows")
    return seen


def main() -> None:
    work = tempfile.mkdtemp(prefix="kill_resume_")
    ckpt = os.path.join(work, "ckpt")
    seg1 = os.path.join(work, "seg1.jsonl")
    seg2 = os.path.join(work, "seg2.jsonl")
    try:
        # --- segment 1: die during save #2 -------------------------------
        rc = fl_train(ckpt, seg1, "--inject-kill-save", "2")
        if rc != KILL_EXIT_CODE:
            fail(f"segment 1 exited {rc}, expected the injected kill "
                 f"({KILL_EXIT_CODE})")
        names = os.listdir(ckpt)
        committed = sorted(n for n in names if n.startswith("ckpt_"))
        torn = [n for n in names if n.startswith(".tmp-")]
        if committed != ["ckpt_00000002"]:
            fail(f"expected exactly the committed round-2 checkpoint, "
                 f"found {committed}")
        if not torn:
            fail("the mid-save kill left no torn .tmp-* staging remnant")
        rounds1 = check_headless_segment(seg1)

        # --- segment 2: resume auto over the torn directory --------------
        rc = fl_train(ckpt, seg2, "--resume", "auto")
        if rc != 0:
            fail(f"resume run exited {rc}")
        info = check_file(seg2)  # the full v4 JSONL contract
        with open(seg2) as f:
            header = json.loads(f.readline())
        if header.get("start_round") != 2:
            fail(f"resume started at round {header.get('start_round')}, "
                 "expected 2 (the newest COMPLETE checkpoint)")
        rounds2 = list(range(2, 2 + info["rounds"]))

        # --- the union must be one contiguous run ------------------------
        union = sorted(set(rounds1) | set(rounds2))
        if union != list(range(ROUNDS)):
            fail(f"segments union to {union}, expected 0..{ROUNDS - 1}")
        print(f"kill_resume_smoke: OK — killed at save #2 (exit "
              f"{KILL_EXIT_CODE}) with rows {rounds1}, resumed from round 2 "
              f"over the torn remnant, rows {rounds2} complete the run")
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    main()
