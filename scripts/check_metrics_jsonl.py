#!/usr/bin/env python
"""Validate a telemetry JSONL file emitted by ``fl_train --metrics-out``
(or any JsonlSink — repro/obs/sinks.py).

Checks the versioned row contract the sink promises:

  * every line is strict JSON (no NaN/Infinity literals — non-finite values
    must have been serialized as null);
  * line 1 is a header row (kind="header") carrying the schema version,
    field list, and run metadata (algo/runtime/channel/uplink_bytes);
  * the last line is a footer row (kind="footer") whose "rounds" equals the
    number of round rows;
  * every row in between is kind="round" with all ROW_FIELDS present
    (numeric or null), matching schema version, and strictly increasing
    contiguous "round" indices from the header's start_round;
  * cumulative columns (comm_bytes_total, wall_time_s) are non-decreasing;
  * the v3 async triple (arrivals / staleness_mean / staleness_max) is
    internally consistent: arrivals is null exactly when the deadline gate
    is off (the whole run — the gate is a compile-time config, not a
    per-round toggle), a present arrivals is a non-negative count, and
    staleness_mean never exceeds staleness_max when both landed;
  * the v4 footer checkpoint triple (checkpoint_save_ms / checkpoint_bytes /
    checkpoint_failures) is present and sane: all three numeric and
    non-negative (zeros when checkpointing was off), failures an integer,
    and every checkpoint_failed alarm in the footer is reflected by a
    non-zero failure count.

Exit 0 and a one-line summary on success; exit 1 with the first violation
otherwise.

  PYTHONPATH=src python scripts/check_metrics_jsonl.py metrics.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.obs.sinks import ROW_FIELDS, SCHEMA_VERSION  # noqa: E402


def fail(lineno: int, msg: str) -> None:
    print(f"check_metrics_jsonl: line {lineno}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_file(path: str) -> dict:
    with open(path) as f:
        lines = f.read().splitlines()
    if len(lines) < 2:
        fail(len(lines), "need at least a header and a footer row")

    rows = []
    for i, line in enumerate(lines, 1):
        try:
            # strict JSON: the nan->null sanitization is part of the contract
            rows.append(json.loads(line, parse_constant=lambda c: fail(
                i, f"non-strict JSON constant {c}")))
        except json.JSONDecodeError as e:
            fail(i, f"invalid JSON: {e}")

    header, body, footer = rows[0], rows[1:-1], rows[-1]
    if header.get("kind") != "header":
        fail(1, f"first row kind={header.get('kind')!r}, expected 'header'")
    if header.get("v") != SCHEMA_VERSION:
        fail(1, f"schema version {header.get('v')!r} != {SCHEMA_VERSION}")
    if header.get("fields") != list(ROW_FIELDS):
        fail(1, f"header fields {header.get('fields')} != {list(ROW_FIELDS)}")
    for key in ("algo", "runtime", "channel", "num_clients", "uplink_bytes"):
        if key not in header:
            fail(1, f"header missing {key!r}")
    if footer.get("kind") != "footer":
        fail(len(lines), f"last row kind={footer.get('kind')!r}, "
             "expected 'footer'")

    expected_round = int(header.get("start_round", 0))
    prev = {"comm_bytes_total": float("-inf"), "wall_time_s": float("-inf")}
    async_on = None  # per-run constant, learned from the first round row
    for off, row in enumerate(body):
        lineno = off + 2
        if row.get("kind") != "round":
            fail(lineno, f"kind={row.get('kind')!r}, expected 'round'")
        if row.get("v") != SCHEMA_VERSION:
            fail(lineno, f"schema version {row.get('v')!r}")
        if row.get("round") != expected_round:
            fail(lineno, f"round={row.get('round')}, expected "
                 f"{expected_round} (contiguous from start_round)")
        expected_round += 1
        for field in ROW_FIELDS:
            if field not in row:
                fail(lineno, f"missing field {field!r}")
            v = row[field]
            if v is not None and not isinstance(v, (int, float)):
                fail(lineno, f"field {field!r} is {type(v).__name__}, "
                     "expected number or null")
        for field in ("comm_bytes_total", "wall_time_s"):
            v = row[field]
            if v is not None:
                if v < prev[field]:
                    fail(lineno, f"{field} decreased: {v} < {prev[field]}")
                prev[field] = v
        # v3 async triple: the deadline gate is a compile-time config, so
        # arrivals is null on every row or a count on every row
        arrivals = row["arrivals"]
        if async_on is None:
            async_on = arrivals is not None
        elif (arrivals is not None) != async_on:
            fail(lineno, "arrivals flipped between null and numeric "
                 "mid-run (the deadline gate cannot toggle per round)")
        if arrivals is not None and arrivals < 0:
            fail(lineno, f"arrivals={arrivals} is negative")
        s_mean, s_max = row["staleness_mean"], row["staleness_max"]
        if s_mean is not None and s_max is not None and s_mean > s_max:
            fail(lineno, f"staleness_mean {s_mean} > staleness_max {s_max}")

    if footer.get("rounds") != len(body):
        fail(len(lines), f"footer rounds={footer.get('rounds')} but file "
             f"has {len(body)} round rows")
    # v4 footer checkpoint triple
    for field in ("checkpoint_save_ms", "checkpoint_bytes",
                  "checkpoint_failures"):
        v = footer.get(field)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            fail(len(lines), f"footer {field}={v!r}, expected a number "
                 "(zeros when checkpointing is off)")
        if v < 0:
            fail(len(lines), f"footer {field}={v} is negative")
    if footer["checkpoint_failures"] != int(footer["checkpoint_failures"]):
        fail(len(lines), "footer checkpoint_failures="
             f"{footer['checkpoint_failures']} is not an integer count")
    n_failed_alarms = sum(
        1 for a in footer.get("alarms", [])
        if a.get("rule") == "checkpoint_failed")
    if n_failed_alarms and footer["checkpoint_failures"] < 1:
        fail(len(lines), f"{n_failed_alarms} checkpoint_failed alarm(s) in "
             "the footer but checkpoint_failures == 0")
    return {"rounds": len(body), "algo": header.get("algo"),
            "stopped": footer.get("stopped"),
            "alarms": len(footer.get("alarms", []))}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    args = ap.parse_args()
    for path in args.paths:
        info = check_file(path)
        print(f"{path}: OK — {info['rounds']} rounds of {info['algo']}, "
              f"stopped={info['stopped']}, alarms={info['alarms']}")


if __name__ == "__main__":
    main()
