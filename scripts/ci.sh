#!/usr/bin/env bash
# Tier-1 gate, runnable offline: collection errors can never silently reland.
#
#   bash scripts/ci.sh
#
# Installs the dev extras when a network/index is available; without them the
# suite still runs (hypothesis property tests skip via tests/_hypothesis_stub).
set -euo pipefail
cd "$(dirname "$0")/.."

if python -m pip install -q -r requirements-dev.txt 2>/dev/null; then
    echo "ci: dev extras installed"
else
    echo "ci: offline — dev extras skipped (hypothesis tests will skip)"
fi

# --durations=25 surfaces the slowest tests in the workflow log so tier-1
# runtime creep is visible in every CI run, not discovered after the fact.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q --durations=25

# Benchmark smoke: every wire codec (repro/comm) runs end-to-end on a tiny
# config — SVRG family AND the stateful Newton family (giant/newton_gmres
# rows guard the schema'd diff-coded wire) — and int8 stays on the fp32
# convergence track; codec regressions fail CI here instead of surviving
# until the full benchmark run.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.ext_compression --smoke

# Fused local-trajectory kernels: the interpret-mode kernel↔oracle parity
# suite (bit-exact on granule shapes) runs inside tier-1 above; re-select it
# here by name so a kernel regression is called out as such in the CI log,
# not buried in the full-suite dots.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q \
    tests/test_local_update.py -k "KernelParity or MaskedRow"

# Round-engine smoke: the chunked/donated engine, the fused-AA path and the
# fused local_impl rows (tree vs pallas on the eligible vmap cells) run
# end-to-end, emitting a scratch artifact (benchmarks/results/
# BENCH_round_smoke.json — smoke never clobbers the committed trajectory).
# The gate validates the fresh emission AND that the committed repo-root
# BENCH_round.json is still the well-formed FULL grid (which includes the
# fused-beats-tree and headline >2x acceptance bars).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_round --smoke
python scripts/check_bench_round.py benchmarks/results/BENCH_round_smoke.json
python scripts/check_bench_round.py BENCH_round.json --require-full

# Cohort smoke: sampled-cohort engine rounds (C=16 gathered out of the
# K-sized client store, frozen non-sampled rows) run end-to-end on the
# reduced K sweep, including XLA's compiled-memory analysis of the chunk
# executable — exercising the gather/scatter round plan under CI. Scratch
# output only; the committed K∈{32,512,4096} sweep lives in
# benchmarks/results/ext_cohort.json.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.ext_cohort --smoke

# Robustness smoke (repro/robust): every fault kind (dropout / stale /
# byzantine uplink + history / DP noise) executes finitely on both defense
# settings, the clean run is bit-identical defense-on vs -off, a repeated
# FaultPlan is bit-deterministic, and the byz-history acceptance pair holds
# (undefended non-finite, clip_rtol-defended finite). The checker then
# validates the COMMITTED fault-matrix artifact's acceptance invariants
# (smoke writes nothing — the committed matrix is regenerated only by
# `python -m benchmarks.ext_robustness`).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.ext_robustness --smoke
python scripts/check_ext_robustness.py benchmarks/results/ext_robustness.json

# Straggler smoke (repro/robust/async_agg): a deadline-gated run under a
# heavy-tailed latency plan converges finitely, an inactive AsyncConfig is
# bitwise-off on both runtimes, and mixed latency+dropout gated rounds are
# bit-deterministic across repeats and runtimes. The checker then validates
# the COMMITTED straggler artifact's acceptance invariants (gated run
# reaches 1e-6 within 2x the barriered rounds at a fraction of its
# simulated wall-clock; smoke writes nothing — the committed artifact is
# regenerated only by `python -m benchmarks.ext_async`).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.ext_async --smoke
python scripts/check_ext_async.py benchmarks/results/ext_async.json

# XLA:CPU thunk-runtime loop-body repro (ROADMAP item): records the
# scan-body penalty of the default runtime vs the legacy one — the artifact
# to attach upstream and to re-check on jaxlib upgrades. Not gated on a
# threshold (jaxlib-version dependent).
python scripts/repro_thunk_runtime.py --smoke

# Telemetry smoke (repro/obs): a chunked engine run streams per-round rows
# to a JSONL sink (with the default health monitors attached), then the
# schema validator checks the versioned header/round/footer contract — so a
# row-schema or sink regression fails CI before any long run depends on the
# telemetry. Scratch artifact only (gitignored).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.fl_train \
    --arch smollm-135m --reduced --algo fedosaa_svrg --rounds 6 \
    --clients 4 --round-chunk 3 \
    --metrics-out benchmarks/results/metrics_smoke.jsonl
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/check_metrics_jsonl.py \
    benchmarks/results/metrics_smoke.jsonl

# Checkpoint save-overlap smoke (repro/checkpoint): all three boundary
# policies (none / async per-shard / sync_gather baseline) run the
# bit-identical math and the checkpointing modes commit clean saves with
# v4 footers. The checker then validates the COMMITTED save-overlap
# artifact's acceptance invariant (async per-chunk overhead <= 10% of the
# no-checkpoint floor; smoke writes nothing — the committed artifact is
# regenerated only by `python -m benchmarks.ext_checkpoint`).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.ext_checkpoint --smoke
python scripts/check_ext_checkpoint.py benchmarks/results/ext_checkpoint.json

# Kill-resume smoke (the preemption story end-to-end): a real fl_train
# subprocess is hard-killed MID-SAVE by the crash-injection fs (exit 43),
# leaving a committed checkpoint plus a torn staging remnant; `--resume
# auto` must skip the remnant, restore the newest complete checkpoint, and
# finish the run — both segments' JSONL rows unioning to one contiguous
# history (segment 2 passes the full v4 contract). Scratch artifacts only.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/kill_resume_smoke.py
