"""Validate the committed straggler/deadline-gate artifact
(benchmarks/results/ext_async.json).

Shared by scripts/ci.sh and .github/workflows/ci.yml so the gate cannot
drift between the two.

  python scripts/check_ext_async.py [path]

Checks structure (the sync/gated rows plus the summary) and the PR's
acceptance invariants:

  * the deadline-gated FedOSAA-SVRG run reached rel-error 1e-6 within 2x
    the barriered baseline's rounds,
  * while its SIMULATED wall-clock-to-target (sum of effective deadlines,
    replayed exactly from the keyed latency stream) is strictly below the
    barriered run's (sum of per-round max latencies — the tail the barrier
    pays for),
  * an inactive AsyncConfig was bitwise identical to no AsyncConfig on
    BOTH runtimes (off compiles the byte-identical synchronous graph),
  * mixed latency+dropout gated runs were bit-deterministic across repeats
    and their vmap/sharded arrival schedules bit-identical.

Failures raise (never bare `assert`, which python -O strips — this script
is a CI gate).
"""
import json
import math
import sys

args = [a for a in sys.argv[1:] if not a.startswith("--")]
path = args[0] if args else "benchmarks/results/ext_async.json"


def fail(msg: str):
    raise SystemExit(f"check_ext_async: {path}: {msg}")


with open(path) as f:
    rows = json.load(f)
by = {r["name"]: r for r in rows}

expected = {
    "ext_async/sync/clean",
    "ext_async/sync/latency",
    "ext_async/gated/guard",
    "ext_async/gated/noguard",
    "ext_async/summary",
}
got = {r["name"] for r in rows}
if got != expected:
    fail(f"not the full row set: missing {sorted(expected - got)}, "
         f"unexpected {sorted(got - expected)}")

for r in rows:
    if r["name"].endswith("summary"):
        continue
    if r.get("rounds", 0) < 1:
        fail(f"{r['name']}: no rounds executed")
    if r.get("comm_bytes", 0) <= 0:
        fail(f"{r['name']}: no bytes accounted")
    if not math.isfinite(r["final_loss"]):
        fail(f"{r['name']}: final loss is non-finite")
    if r.get("rounds_to_target") is None:
        fail(f"{r['name']}: never reached the rel-error target")
    if r["name"].startswith("ext_async/gated"):
        arr = r.get("arrivals_curve")
        if not arr or max(arr) <= 0:
            fail(f"{r['name']}: no round recorded any arrivals")

s = by["ext_async/summary"]
budget = s.get("round_multiple_budget", 2.0)
ratio = s.get("gated_rounds_vs_barriered")
if ratio is None or not ratio <= budget:
    fail(f"gated run took {ratio}x the barriered run's rounds "
         f"(must be <= {budget})")
if not s.get("gated_wall_below_barriered"):
    fail(f"gated simulated wall {s.get('gated_sim_wall_to_target')} is not "
         f"below the barriered {s.get('barriered_sim_wall_to_target')} — "
         "the deadline gate stopped paying for itself")
if not s.get("inactive_parity_vmap_bit_identical"):
    fail("inactive AsyncConfig is not bitwise-off on the vmap runtime")
if not s.get("inactive_parity_sharded_bit_identical"):
    fail("inactive AsyncConfig is not bitwise-off on the sharded runtime")
if not s.get("repeat_bit_identical"):
    fail("repeated mixed latency+dropout gated runs were not bit-identical")
if not s.get("runtime_schedule_bit_identical"):
    fail("vmap/sharded arrival/staleness schedules differ")

print(f"ci: {path} well-formed (gated {s['gated_rounds_to_target']} vs "
      f"barriered {s['barriered_rounds_to_target']} rounds-to-1e-6, "
      f"sim wall {s['gated_sim_wall_to_target']:.1f} vs "
      f"{s['barriered_sim_wall_to_target']:.1f})")
