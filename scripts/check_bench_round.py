"""Validate a bench_round perf artifact (BENCH_round.json schema).

Shared by scripts/ci.sh and .github/workflows/ci.yml so the gate cannot
drift between the two.

  python scripts/check_bench_round.py <path> [--require-full]

--require-full additionally rejects smoke-mode artifacts and enforces the
full 18-row grid (incl. the cohort cells): the committed repo-root
BENCH_round.json is the curated trajectory and must never be replaced by
2-rep smoke numbers (smoke runs write to
benchmarks/results/BENCH_round_smoke.json).

Failures raise (never bare `assert`, which python -O strips — this script
is a CI gate).
"""
import json
import sys

args = [a for a in sys.argv[1:] if not a.startswith("--")]
path = args[0] if args else "BENCH_round.json"
require_full = "--require-full" in sys.argv


def fail(msg: str):
    raise SystemExit(f"check_bench_round: {path}: {msg}")


with open(path) as f:
    b = json.load(f)
if b.get("bench") != "round_engine":
    fail(f"bench != 'round_engine' (got {b.get('bench')!r})")
if not b.get("rows"):
    fail("no bench rows")
for row in b["rows"]:
    if not (row["engine_s_per_round"] > 0 and row["seed_loop_s_per_round"] > 0):
        fail(f"non-positive timing in row {row['algo']}/{row['runtime']}/"
             f"{row['channel']}")
    if row.get("local_impl") not in ("tree", "pallas"):
        fail(f"row {row['algo']}/{row['runtime']}/{row['channel']} missing "
             f"the local_impl axis (got {row.get('local_impl')!r})")
    if "cohort" not in row or not (row["cohort"] is None
                                   or isinstance(row["cohort"], int)):
        fail(f"row {row['algo']}/{row['runtime']}/{row['channel']} missing "
             f"the cohort axis (got {row.get('cohort')!r})")
if "engine_speedup_vs_seed_loop" not in b.get("headline", {}):
    fail("headline missing engine_speedup_vs_seed_loop")
if "max_abs_param_diff_vs_tree" not in b.get("aa_impl_pallas", {}):
    fail("aa_impl_pallas row missing max_abs_param_diff_vs_tree")
if "trajectory_max_abs_diff_vs_tree" not in b.get("local_impl_pallas", {}):
    fail("local_impl_pallas row missing trajectory_max_abs_diff_vs_tree")
if require_full:
    if b["smoke"]:
        fail("holds SMOKE data — the committed trajectory must be the full "
             "grid (regenerate with: python -m benchmarks.bench_round)")
    # the full grid's cell set (keep in sync with benchmarks/bench_round.py
    # ALGOS × RUNTIMES × CHANNELS × _local_impls — not imported: that module
    # pins XLA flags and initializes jax, far too heavy for this checker).
    # The fused local_impl axis exists on eligible vmap cells only (the
    # Newton family and the sharded runtime have no fused path).
    fused_algos = ("fedosaa_svrg", "fedosaa_scaffold")
    expected = set()
    for a in ("fedosaa_svrg", "fedosaa_scaffold", "giant"):
        for r in ("vmap", "sharded"):
            for c in ("identity", "int8"):
                impls = (("tree", "pallas")
                         if r == "vmap" and a in fused_algos else ("tree",))
                for li in impls:
                    expected.add((a, r, c, li, None))
    # the cohort cells: sampled-cohort rounds (C=4 of K=10) against the same
    # dense seed baseline, headline algo on both runtimes
    for r in ("vmap", "sharded"):
        expected.add(("fedosaa_svrg", r, "identity", "tree", 4))
    got = {(row["algo"], row["runtime"], row["channel"], row["local_impl"],
            row["cohort"]) for row in b["rows"]}
    if got != expected:
        fail(f"not the full grid: missing {sorted(expected - got, key=str)}, "
             f"unexpected {sorted(got - expected, key=str)}")
    # the fused trajectory must WIN on every eligible vmap cell (engine
    # mode, the hot path) — this is the PR's acceptance bar
    by_cell = {(row["algo"], row["runtime"], row["channel"],
                row["local_impl"]): row for row in b["rows"]
               if row["cohort"] is None}
    for a in fused_algos:
        for c in ("identity", "int8"):
            t = by_cell[(a, "vmap", c, "tree")]["engine_s_per_round"]
            p = by_cell[(a, "vmap", c, "pallas")]["engine_s_per_round"]
            if not p < t:
                fail(f"fused local path does not beat tree on {a}/vmap/{c}: "
                     f"{p*1e3:.2f} vs {t*1e3:.2f} ms/round")
    # ordering invariants (machine-state independent): the engine must beat
    # the seed loop on EVERY row, and a sampled-cohort round must beat its
    # dense sibling (it computes C of K clients against the same baseline)
    for row in b["rows"]:
        if not row["engine_speedup_vs_seed_loop"] > 1.0:
            fail(f"engine does not beat the seed loop on {row['algo']}/"
                 f"{row['runtime']}/{row['channel']}/{row['local_impl']}"
                 f"/cohort={row['cohort']}")
        if row["cohort"] is not None:
            dense = by_cell[(row["algo"], row["runtime"], row["channel"],
                             row["local_impl"])]
            if not row["engine_s_per_round"] < dense["engine_s_per_round"]:
                fail(f"cohort={row['cohort']} engine round does not beat the "
                     f"dense round on {row['algo']}/{row['runtime']}: "
                     f"{row['engine_s_per_round']*1e3:.2f} vs "
                     f"{dense['engine_s_per_round']*1e3:.2f} ms/round")
    # absolute headline bar, recalibrated for machine state: the original
    # >2.0x (PR 4/5) encoded a host where per-round dispatch + host-sync
    # overhead dominated (seed loop 11.2 ms/round); on a faster container
    # that overhead shrinks and the ratio compresses FOR EVERY CODE VERSION
    # (A/B-measured: the pre-cohort tree scores 1.44x under the same
    # conditions that score the current tree 1.50x). The ordering invariants
    # above carry the regression-catching load; this bar only rejects a
    # wholesale loss of the engine's win.
    if not b["headline"]["engine_speedup_vs_seed_loop"] > 1.2:
        fail("headline engine+pallas speedup vs the seed loop must exceed "
             f"1.2x (got {b['headline']['engine_speedup_vs_seed_loop']:.2f}x)")
print(f"ci: {path} well-formed "
      f"(headline {b['headline']['engine_speedup_vs_seed_loop']:.2f}x"
      f"{', full grid' if require_full else ''})")
