"""Validate a bench_round perf artifact (BENCH_round.json schema).

Shared by scripts/ci.sh and .github/workflows/ci.yml so the gate cannot
drift between the two.

  python scripts/check_bench_round.py <path> [--require-full]

--require-full additionally rejects smoke-mode artifacts and enforces the
full 12-cell grid: the committed repo-root BENCH_round.json is the curated
trajectory and must never be replaced by 2-rep smoke numbers (smoke runs
write to benchmarks/results/BENCH_round_smoke.json).

Failures raise (never bare `assert`, which python -O strips — this script
is a CI gate).
"""
import json
import sys

args = [a for a in sys.argv[1:] if not a.startswith("--")]
path = args[0] if args else "BENCH_round.json"
require_full = "--require-full" in sys.argv


def fail(msg: str):
    raise SystemExit(f"check_bench_round: {path}: {msg}")


with open(path) as f:
    b = json.load(f)
if b.get("bench") != "round_engine":
    fail(f"bench != 'round_engine' (got {b.get('bench')!r})")
if not b.get("rows"):
    fail("no bench rows")
for row in b["rows"]:
    if not (row["engine_s_per_round"] > 0 and row["seed_loop_s_per_round"] > 0):
        fail(f"non-positive timing in row {row['algo']}/{row['runtime']}/"
             f"{row['channel']}")
if "engine_speedup_vs_seed_loop" not in b.get("headline", {}):
    fail("headline missing engine_speedup_vs_seed_loop")
if "max_abs_param_diff_vs_tree" not in b.get("aa_impl_pallas", {}):
    fail("aa_impl_pallas row missing max_abs_param_diff_vs_tree")
if require_full:
    if b["smoke"]:
        fail("holds SMOKE data — the committed trajectory must be the full "
             "grid (regenerate with: python -m benchmarks.bench_round)")
    # the full grid's cell set (keep in sync with benchmarks/bench_round.py
    # ALGOS × RUNTIMES × CHANNELS — not imported: that module pins XLA flags
    # and initializes jax, far too heavy for this checker)
    expected = {(a, r, c)
                for a in ("fedosaa_svrg", "fedosaa_scaffold", "giant")
                for r in ("vmap", "sharded")
                for c in ("identity", "int8")}
    got = {(row["algo"], row["runtime"], row["channel"]) for row in b["rows"]}
    if got != expected:
        fail(f"not the full grid: missing {sorted(expected - got)}, "
             f"unexpected {sorted(got - expected)}")
print(f"ci: {path} well-formed "
      f"(headline {b['headline']['engine_speedup_vs_seed_loop']:.2f}x"
      f"{', full grid' if require_full else ''})")
