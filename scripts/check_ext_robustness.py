"""Validate the committed robustness fault-matrix artifact
(benchmarks/results/ext_robustness.json).

Shared by scripts/ci.sh and .github/workflows/ci.yml so the gate cannot
drift between the two.

  python scripts/check_ext_robustness.py [path]

Checks structure (the full fault x defense x codec grid plus the summary
row) and the PR's acceptance invariants:

  * the undefended byz-history run on the identity codec FAILED (never
    reached rel-error 1e-4; its final loss is non-finite — the NaN-poison
    attack landed),
  * the clip_rtol-defended run reached the 1e-6 target within 1.5x the
    clean run's rounds,
  * clean-run parity: defense on vs off agree at rtol 1e-6 (measured
    bit-exact, but the gate is the documented contract),
  * repeated runs of the same FaultPlan were bit-identical.

Failures raise (never bare `assert`, which python -O strips — this script
is a CI gate).
"""
import json
import math
import sys

args = [a for a in sys.argv[1:] if not a.startswith("--")]
path = args[0] if args else "benchmarks/results/ext_robustness.json"


def fail(msg: str):
    raise SystemExit(f"check_ext_robustness: {path}: {msg}")


with open(path) as f:
    rows = json.load(f)
by = {r["name"]: r for r in rows}

expected = {
    f"ext_robustness/{c}/{k}/{d}"
    for c in ("identity", "int8")
    for k in ("clean", "drop0.2", "stale0.2", "sign_flip", "noise",
              "history", "dp1e-3")
    for d in ("off", "on")
} | {"ext_robustness/summary"}
got = {r["name"] for r in rows}
if got != expected:
    fail(f"not the full fault matrix: missing {sorted(expected - got)}, "
         f"unexpected {sorted(got - expected)}")

for r in rows:
    if r["name"].endswith("summary"):
        continue
    if r.get("rounds", 0) < 1:
        fail(f"{r['name']}: no rounds executed")
    if r.get("comm_bytes", 0) <= 0:
        fail(f"{r['name']}: no bytes accounted")
    # only the identity-codec byz-history undefended cell may go non-finite
    if not r["name"].endswith("identity/history/off"):
        if not math.isfinite(r["final_loss"]):
            fail(f"{r['name']}: final loss is non-finite")

s = by["ext_robustness/summary"]
if not s.get("byz_history_undefended_failed"):
    fail("undefended byz-history run reached 1e-4 — the attack no longer "
         "lands (did the history-poison injection move?)")
if s.get("undefended_final_finite"):
    fail("undefended byz-history run stayed finite")
if not s.get("byz_history_defended_reached_target"):
    fail("clip_rtol-defended byz-history run did not reach the 1e-6 target")
ratio = s.get("defended_rounds_vs_clean")
if ratio is None or not ratio <= 1.5:
    fail(f"defended run took {ratio}x the clean run's rounds (must be <= 1.5)")
parity = s.get("clean_defense_parity_max_rel")
if parity is None or not parity <= 1e-6:
    fail(f"clean-run defense-on vs -off parity {parity} exceeds rtol 1e-6")
if not s.get("fault_determinism_bit_identical"):
    fail("repeated runs of the same FaultPlan were not bit-identical")

print(f"ci: {path} well-formed (defended {s['defended_rounds_to_target']} "
      f"vs clean {s['clean_rounds_to_target']} rounds-to-1e-6, "
      f"parity {parity:.1e})")
